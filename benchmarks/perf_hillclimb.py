"""§Perf hillclimb driver — hypothesis → change → re-lower → re-analyse.

Targets the three chosen pairs (worst roofline fraction / most
collective-bound / most representative) and, for each, walks a ladder of
named variants, recording the three roofline terms per step.  Output:
experiments/results/perf_<pair>.json + a markdown iteration log on
stdout that EXPERIMENTS.md §Perf quotes directly.

    PYTHONPATH=src python -m benchmarks.perf_hillclimb --pair qwen2_train
    PYTHONPATH=src python -m benchmarks.perf_hillclimb --all
"""
from __future__ import annotations

# XLA flag must precede any jax import (512 fake devices) — noqa: E402
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

import argparse
import json
import sys

# shared benchmark machinery (imports jax AFTER the env override above);
# RESULTS_DIR and the timing policy live in ONE place
from benchmarks.common import RESULTS_DIR, time_best_of


def _patched(arch, **fields):
    import dataclasses
    from repro.configs import get_config
    return dataclasses.replace(get_config(arch), **fields)


def _variants_qwen2_train():
    """qwen2-1.5b × train_4k — most collective-bound pair
    (t_coll 16.0s vs t_comp 0.14s at baseline: 0.9% of roofline).

    H1 (layout): 16-way TP all-reduces ~200MB of activations per layer
    per direction; a 1.5B model needs NO tensor parallelism on 256 chips
    — pure 256-way FSDP turns the per-layer activation all-reduce into a
    per-step param all-gather + grad reduce-scatter (~GB total, not
    ~100s of GB).  Predicted: collective term drops >10×.
    H2 (anchor): batch anchors on attention scores keep SPMD from
    replicating activations under FSDP weights (cheap insurance; expect
    ~neutral here, big win on MLA archs).
    H3 (microbatch): with the layout fixed, 4-way gradient accumulation
    shrinks peak activation memory ~4× at small extra collective cost.
    """
    arch = "qwen2-1.5b"
    return arch, "train_4k", [
        ("baseline fsdp_tp", dict(layout="fsdp_tp", n_micro=1)),
        ("H1 fsdp_only (no TP)", dict(layout="fsdp_only", n_micro=1)),
        ("H2 fsdp_only + batch anchors",
         dict(layout="fsdp_only", n_micro=1,
              cfg_override=_patched(arch, shard_activations=True))),
        ("H3 fsdp_only + anchors + 4 microbatches",
         dict(layout="fsdp_only", n_micro=4,
              cfg_override=_patched(arch, shard_activations=True))),
    ]


def _variants_dsv3_train():
    """deepseek-v3-671b × train_4k — the paper technique's hardest
    deployment target (P2 round = this step at 671B); worst useful-FLOPs
    ratio in the baseline table.

    H1 (anchor): HLO inspection showed attention scores materialized
    with the FULL global batch per chip (dot f32[256,8,4096,4096]) —
    SPMD preferred replicating activations over gathering FSDP weights.
    anchor_batch pins the score tensors; predicted: per-chip score dots
    shrink 16× to [16,8,4096,4096] (verified via HLO), collective
    pattern changes shape.
    H2 (layout): at 671B params FSDP×TP is mandatory — verify fsdp_only
    REGRESSES (param all-gather of 1.3TB/step) — a refutation probe.
    H3 (microbatch): 4-way accumulation cuts activation peak on the
    256-chip pod.
    """
    arch = "deepseek-v3-671b"
    return arch, "train_4k", [
        ("baseline fsdp_tp", dict(layout="fsdp_tp", n_micro=1)),
        ("H1 + batch anchors",
         dict(layout="fsdp_tp", n_micro=1,
              cfg_override=_patched(arch, shard_activations=True))),
        ("H2 fsdp_only (expect REGRESSION)", dict(layout="fsdp_only",
                                                  n_micro=1)),
        ("H3 anchors + 4 microbatches",
         dict(layout="fsdp_tp", n_micro=4,
              cfg_override=_patched(arch, shard_activations=True))),
    ]


def _variants_mamba2_prefill():
    """mamba2-1.3b × prefill_32k — near-collective-bound SSM (attention-
    free: proves the pathology is TP itself, not attention).

    H1 (layout): d_inner=4096 split 16-way makes every in/out projection
    all-reduce (32,32768,2048) activations; fsdp_only removes them.
    Predicted: collective bytes drop >>, bottleneck flips to memory.
    """
    return "mamba2-1.3b", "prefill_32k", [
        ("baseline fsdp_tp", dict(layout="fsdp_tp")),
        ("H1 fsdp_only (no TP)", dict(layout="fsdp_only")),
    ]


PAIRS = {
    "qwen2_train": _variants_qwen2_train,
    "dsv3_train": _variants_dsv3_train,
    "mamba2_prefill": _variants_mamba2_prefill,
}


def run_pair_ladder(name: str) -> dict:
    from repro.launch.dryrun import run_pair

    arch, shape, ladder = PAIRS[name]()
    print(f"\n### {arch} × {shape}\n", flush=True)
    rows = []
    for label, kw in ladder:
        out = {}

        def lower():
            out["r"] = run_pair(arch, shape, verbose=False, save=False, **kw)

        # compile-and-analyse once, timed with the shared best-of policy
        dt = time_best_of(lower, 1)
        r = out["r"]
        if not r.get("ok"):
            print(f"| {label} | FAIL {r.get('error', '')[:80]} |", flush=True)
            rows.append({"label": label, **r})
            continue
        row = {
            "label": label,
            "t_compute_s": r["t_compute_s"], "t_memory_s": r["t_memory_s"],
            "t_collective_s": r["t_collective_s"],
            "bottleneck": r["bottleneck"],
            "dominant_s": max(r["t_compute_s"], r["t_memory_s"],
                              r["t_collective_s"]),
            "peak_bytes_per_device": (r.get("bytes_per_device") or {}).get(
                "peak_bytes"),
            "collective_bytes": r["collective_bytes_per_chip"],
        }
        rows.append(row)
        print(f"| {label} | comp {row['t_compute_s']:.3g}s | "
              f"mem {row['t_memory_s']:.3g}s | "
              f"coll {row['t_collective_s']:.3g}s | -> {row['bottleneck']} "
              f"(compile {dt:.0f}s)", flush=True)
    out = {"arch": arch, "shape": shape, "rows": rows}
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    (RESULTS_DIR / f"perf_{name}.json").write_text(
        json.dumps(out, indent=1, default=str))
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--pair", default=None, choices=list(PAIRS))
    ap.add_argument("--all", action="store_true")
    args = ap.parse_args(argv)
    names = list(PAIRS) if args.all or not args.pair else [args.pair]
    for n in names:
        run_pair_ladder(n)
    return 0


if __name__ == "__main__":
    sys.exit(main())
