"""Shared benchmark machinery: scale presets, runners, result storage.

Every benchmark module reproduces one paper artifact (Table I–IV,
Fig 5–7) on the synthetic federated stand-ins (offline container —
DESIGN.md §1 faithfulness caveat).  Two presets:

  quick : minutes-scale sanity pass (CI / bench_output.txt)
  full  : the EXPERIMENTS.md numbers (tens of minutes per table)

Results append to experiments/results/<name>.json so EXPERIMENTS.md is
reproducible from artifacts.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import time
from typing import Any, Dict, List, Optional

from repro.core.cyclic import CyclicConfig
from repro.core.pipeline import run_cyclic_then_federated
from repro.data.synthetic import DATASETS
from repro.fl.simulation import FLConfig
from repro.fl.task import charlm_task, vision_task

RESULTS_DIR = pathlib.Path(__file__).resolve().parents[1] / "experiments" / "results"


@dataclasses.dataclass(frozen=True)
class Scale:
    """One benchmark scale preset (paper values in comments)."""
    name: str
    n_clients: int          # paper: 100
    n_train: int            # paper: 50k (CIFAR)
    n_test: int
    p1_rounds: int          # paper: 100
    p2_rounds: int          # paper: 900 (1000 total)
    p1_participation: float = 0.25   # paper: 25%
    p2_participation: float = 0.1    # paper: 10%
    p1_local_steps: int = 20         # paper: 20
    p2_local_steps: int = 15         # paper: 5 epochs
    eval_every: int = 2


QUICK = Scale("quick", n_clients=20, n_train=2400, n_test=600,
              p1_rounds=4, p2_rounds=10, p1_participation=0.25,
              p2_participation=0.15, p1_local_steps=10, p2_local_steps=10,
              eval_every=2)
FULL = Scale("full", n_clients=50, n_train=8000, n_test=1500,
             p1_rounds=12, p2_rounds=36, p1_local_steps=15,
             p2_local_steps=12, eval_every=3)

SCALES = {"quick": QUICK, "full": FULL}

# default benchmark dataset: 20-class + heavy noise so tiny-round runs
# retain headroom (cifar10-like saturates to 1.0 in <15 rounds)
DEFAULT_VISION = ("cifar100c-hard", 20)


def make_vision_setup(scale: Scale, beta: Optional[float], *, model="lenet5",
                      dataset=None, n_classes=None, seed=0):
    if dataset is None:
        dataset, n_classes = DEFAULT_VISION
    data = DATASETS.get(dataset)(
        n_clients=scale.n_clients, beta=beta, seed=seed,
        n_train=scale.n_train, n_test=scale.n_test)
    in_ch = data.x.shape[-1]
    task = vision_task(model, n_classes=n_classes or data.n_classes,
                       in_ch=in_ch)
    return task, data


def make_charlm_setup(scale: Scale, seed=0):
    data = DATASETS.get("shakespeare-like")(
        n_clients=max(scale.n_clients // 2, 8), seed=seed,
        n_seq_per_client=48, n_test=min(scale.n_test, 256))
    task = charlm_task(vocab=64)
    return task, data


def cyclic_cfg(scale: Scale, seed=0, rounds: Optional[int] = None) -> CyclicConfig:
    return CyclicConfig(
        rounds=rounds if rounds is not None else scale.p1_rounds,
        participation=scale.p1_participation,
        local_steps=scale.p1_local_steps, eval_every=scale.eval_every,
        seed=seed)


def fl_cfg(scale: Scale, algorithm: str, seed=0,
           rounds: Optional[int] = None, compression=None,
           peft=None, trainable_filter=None) -> FLConfig:
    # the trainable-slice partition lives on the fused flat path only
    impl = "fused" if (peft or trainable_filter) else "tree"
    return FLConfig(
        algorithm=algorithm,
        rounds=rounds if rounds is not None else scale.p2_rounds,
        participation=scale.p2_participation,
        local_steps=scale.p2_local_steps, eval_every=scale.eval_every,
        seed=seed, compression=compression, update_impl=impl,
        peft=peft, trainable_filter=trainable_filter)


def run_method(task, data, scale: Scale, *, algorithm: str, cyclic: bool,
               seed=0, p1_rounds: Optional[int] = None,
               p2_rounds: Optional[int] = None, compression=None,
               peft=None, trainable_filter=None, verbose=False):
    """One (method × setting) cell.  Baselines get the FULL round budget
    (P1+P2) in P2, matching the paper's equal-total-rounds protocol.
    ``compression``/``peft``/``trainable_filter`` apply to the P2
    uploads only (P1 relays the model itself, which must stay exact —
    see repro.fl.compression / repro.fl.local)."""
    p1 = (p1_rounds if p1_rounds is not None else scale.p1_rounds) if cyclic else 0
    p2 = p2_rounds if p2_rounds is not None else scale.p2_rounds
    total = (scale.p1_rounds if p1_rounds is None else p1_rounds) + \
        (scale.p2_rounds if p2_rounds is None else p2_rounds)
    if not cyclic:
        p2 = total
    res = run_cyclic_then_federated(
        task, data,
        cyclic_cfg(scale, seed=seed, rounds=p1) if cyclic else None,
        fl_cfg(scale, algorithm, seed=seed, rounds=p2,
               compression=compression, peft=peft,
               trainable_filter=trainable_filter),
        verbose=verbose)
    return res


def summarize(res, target_acc: Optional[float] = None) -> Dict[str, Any]:
    best = res.best_acc()
    out = {
        "best_acc": round(best.get("acc", 0.0), 4),
        "best_round": best.get("round", -1),
        "final_acc": round(
            [h for h in res.history if "acc" in h][-1]["acc"], 4),
        "comm": res.ledger.summary(),
    }
    if target_acc is not None:
        out["rounds_to_target"] = res.rounds_to_acc(target_acc)
    return out


def time_best_of(fn, repeats: int) -> float:
    """Best-of-N wall-clock seconds for ``fn()`` — the perf benchmarks'
    shared timing policy (min over repeats suppresses CPU noise)."""
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def save_result(name: str, payload: Dict[str, Any]) -> pathlib.Path:
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    path = RESULTS_DIR / f"{name}.json"
    payload = dict(payload, _written_at=time.strftime("%Y-%m-%d %H:%M:%S"))
    path.write_text(json.dumps(payload, indent=1, default=str))
    return path


def load_result(name: str) -> Optional[Dict[str, Any]]:
    path = RESULTS_DIR / f"{name}.json"
    if path.exists():
        return json.loads(path.read_text())
    return None


def fmt_table(rows: List[Dict[str, Any]], cols: List[str]) -> str:
    widths = {c: max(len(c), *(len(str(r.get(c, ""))) for r in rows)) for c in cols}
    header = "  ".join(c.ljust(widths[c]) for c in cols)
    lines = [header, "  ".join("-" * widths[c] for c in cols)]
    for r in rows:
        lines.append("  ".join(str(r.get(c, "")).ljust(widths[c]) for c in cols))
    return "\n".join(lines)
