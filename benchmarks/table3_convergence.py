"""Table III — convergence: maximum accuracy and rounds-to-target.

Paper artifact: max test accuracy + the round at which it is reached,
plus the dramatic rounds-to-target speedups of Cyclic+FedAvg (e.g.
CIFAR-10 β=0.5: 61.08% at round 107 vs FedAvg 54.99% at 516).  Here the
metric is rounds to reach a fixed target accuracy (chosen as ~90% of the
best baseline accuracy) on cifar10-like.
"""
from __future__ import annotations

import argparse
import time

from benchmarks import common as C

METHODS = [("fedavg", False), ("fedprox", False), ("scaffold", False),
           ("moon", False), ("fedavg", True)]


def run(scale: C.Scale, beta: float = 0.5, seed: int = 0):
    task, data = C.make_vision_setup(scale, beta, seed=seed)
    results = []
    for algorithm, cyclic in METHODS:
        t0 = time.time()
        res = C.run_method(task, data, scale, algorithm=algorithm,
                           cyclic=cyclic, seed=seed)
        results.append((algorithm, cyclic, res))
        print(f"[table3] {'cyclic+' if cyclic else ''}{algorithm}: "
              f"best={res.best_acc().get('acc', 0):.4f} "
              f"({time.time() - t0:.0f}s)", flush=True)
    # target = 90% of best baseline best-acc
    base_best = max(r.best_acc().get("acc", 0.0)
                    for a, c, r in results if not c)
    target = round(0.9 * base_best, 4)
    rows = []
    for algorithm, cyclic, res in results:
        b = res.best_acc()
        rows.append({
            "method": f"cyclic+{algorithm}" if cyclic else algorithm,
            "max_acc": round(b.get("acc", 0.0), 4),
            "at_round": b.get("round", -1),
            f"rounds_to_{target}": res.rounds_to_acc(target),
        })
    return rows, target


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scale", default="quick", choices=list(C.SCALES))
    ap.add_argument("--beta", type=float, default=0.5)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    scale = C.SCALES[args.scale]
    rows, target = run(scale, beta=args.beta, seed=args.seed)
    cols = ["method", "max_acc", "at_round", f"rounds_to_{target}"]
    print(C.fmt_table(rows, cols))
    C.save_result(f"table3_{args.scale}",
                  {"rows": rows, "target": target, "beta": args.beta})
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
