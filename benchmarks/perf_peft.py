"""Trainable-slice (PEFT) round path: wire savings and throughput vs
full fine-tuning, end to end.

Two claims, two gates:

  1. WIRE — LoRA r=8 on the full qwen1.5-0.5b config uploads the
     adapter slice only.  The ratio is a closed form over the abstract
     param tree (eval_shape, no allocation): dtype-aware model bytes /
     trainable-slice bytes, gated at ≥ 30×.  The measured run asserts
     the CommLedger's upload accounting equals the same closed form
     EXACTLY at the bench scale — the ratio is an accounting identity,
     not a sampled estimate.
  2. COMPUTE — at a qwen-like reduced scale the LoRA round sustains
     ≥ 1.5× the full-fine-tune host rounds/s: the backward skips the
     frozen dW einsums, and the clip/step-tail/aggregation/server
     kernels and the donated carry shrink to the trainable slice
     (~1% of the elements here).

Both modes run the SAME engine program shape — K vmapped local runs, a
scan over chunked rounds, fused flat-buffer aggregation — differing
only in the trainable-filter partition (repro.fl.local / utils.flatten).

    PYTHONPATH=src python -m benchmarks.perf_peft
    PYTHONPATH=src python -m benchmarks.perf_peft --scale full
"""
from __future__ import annotations

import argparse
import dataclasses
import math
import sys
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import fmt_table, save_result, time_best_of
from repro.configs import get_config, get_reduced, with_peft
from repro.core import comm_accounting as acc
from repro.core.comm_accounting import CommLedger
from repro.data.synthetic import make_synthetic_tokenlm
from repro.fl.engine import AggregateStrategy, RoundSchedule, run_rounds
from repro.fl.local import LocalSpec
from repro.fl.task import lm_task
from repro.models.transformer import init_lm
from repro.sharding import rules

RATIO_GATE = 30.0           # full qwen1.5-0.5b bytes / LoRA r=8 slice bytes
SPEED_GATE = 1.5            # LoRA rounds/s over full fine-tune rounds/s

# bench scale: qwen-like shape reduced to CPU size, with the embedding /
# head kept fat so the frozen base dominates the param count the way it
# does at full scale (the step tail and the carry ride param bytes)
N_CLIENTS = {"quick": 8, "full": 16}
N_STEPS = {"quick": 2, "full": 4}


def _bench_cfg():
    base = get_reduced("qwen1.5-0.5b")
    return dataclasses.replace(base, name="qwen-peft-bench", n_layers=2,
                               d_model=128, n_heads=4, n_kv_heads=4,
                               head_dim=32, d_ff=256, vocab_size=4096)


def _slice_bytes(cfg, filter_spec: Optional[str]):
    """(model_bytes, trainable_bytes) closed form over the abstract
    param tree — dtype-aware, no allocation."""
    p_specs = jax.eval_shape(lambda k: init_lm(k, cfg), jax.random.PRNGKey(0))
    leaves = jax.tree_util.tree_leaves(p_specs)
    mask = rules.trainable_mask(p_specs, filter_spec) or (True,) * len(leaves)
    total = sum(np.dtype(l.dtype).itemsize * int(np.prod(l.shape))
                for l in leaves)
    train = sum(np.dtype(l.dtype).itemsize * int(np.prod(l.shape))
                for l, m in zip(leaves, mask) if m)
    return int(total), int(train)


def _bench_one(cfg, data, peft: Optional[str], *, clients_per_round: int,
               rounds: int, chunk: int, steps: int, repeats: int,
               seed: int) -> Dict:
    task = lm_task(cfg)
    lspec = LocalSpec(n_steps=steps, batch_size=4, lr=0.05, variant="plain",
                      update_impl="fused_interpret", peft=peft)
    strat = AggregateStrategy(spec=lspec, algorithm="fedavg",
                              participation=clients_per_round
                              / data.n_clients)
    sched = RoundSchedule(rounds=rounds, lr_decay=1.0, eval_every=0,
                          seed=seed, chunk_size=chunk, sampling="host",
                          host_rng_offset=17)
    ledger = CommLedger()
    res = run_rounds(task, data, strat, sched, ledger=ledger)  # warm
    secs = time_best_of(
        lambda: jax.block_until_ready(jax.tree_util.tree_leaves(
            run_rounds(task, data, strat, sched).params)), repeats)
    assert np.isfinite(res.history[-1]["local_loss"])
    return {"secs": secs, "rounds_per_sec": rounds / secs,
            "dispatches": res.dispatches, "ledger": ledger}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scale", default="quick", choices=("quick", "full"))
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--chunk", type=int, default=2)
    ap.add_argument("--clients-per-round", type=int, default=2)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    ok = True

    # --- gate 1: closed-form wire ratio at FULL qwen1.5-0.5b scale -------
    full_cfg = with_peft(get_config("qwen1.5-0.5b"), "lora:8")
    x_full, slice_full = _slice_bytes(full_cfg, "lora")
    ratio_full = x_full / slice_full
    print(f"[perf_peft] qwen1.5-0.5b + lora:8: model {x_full / 1e6:.1f} MB, "
          f"slice {slice_full / 1e6:.2f} MB → upload ratio "
          f"{ratio_full:.1f}x (gate ≥ {RATIO_GATE}x)", flush=True)
    if ratio_full < RATIO_GATE:
        print(f"[perf_peft] REGRESSION: upload ratio {ratio_full:.1f}x "
              f"< {RATIO_GATE}x", file=sys.stderr)
        ok = False

    # --- measured runs at bench scale -------------------------------------
    cfg = _bench_cfg()
    lora_cfg = with_peft(cfg, "lora:8")
    data = make_synthetic_tokenlm(
        n_clients=N_CLIENTS[args.scale], seq_len=32, n_seq_per_client=8,
        vocab=cfg.vocab_size, beta=0.5, seed=args.seed)
    steps = N_STEPS[args.scale]
    want_dispatches = math.ceil(args.rounds / args.chunk)

    results: Dict[str, Dict] = {}
    rows: List[Dict] = []
    for mode, mcfg, peft in (("full_ft", cfg, None),
                             ("lora8", lora_cfg, "lora:8")):
        r = _bench_one(mcfg, data, peft,
                       clients_per_round=args.clients_per_round,
                       rounds=args.rounds, chunk=args.chunk, steps=steps,
                       repeats=args.repeats, seed=args.seed)
        x_bytes, s_bytes = _slice_bytes(mcfg, "lora" if peft else None)
        led = r["ledger"].summary()
        results[mode] = dict(r, x_bytes=x_bytes, slice_bytes=s_bytes)
        rows.append({"mode": mode,
                     "rounds_per_sec": round(r["rounds_per_sec"], 2),
                     "dispatches": r["dispatches"],
                     "upload_ratio": round(led["payload_ratio"], 2)})
        print(f"  {mode:8s} {r['rounds_per_sec']:7.2f} r/s  "
              f"upload ratio {led['payload_ratio']:.2f}", flush=True)

    # --- gates at bench scale ---------------------------------------------
    for mode, r in results.items():
        if r["dispatches"] != want_dispatches:
            print(f"[perf_peft] REGRESSION: {mode} ran {r['dispatches']} "
                  f"dispatches, want {want_dispatches}", file=sys.stderr)
            ok = False
    # ledger == closed form, exactly: uploads pay the slice, downloads X
    lora = results["lora8"]
    led = lora["ledger"]
    k, rounds = args.clients_per_round, args.rounds
    if led.p2_upload_bytes != rounds * k * lora["slice_bytes"]:
        print(f"[perf_peft] REGRESSION: ledger uploads "
              f"{led.p2_upload_bytes} != closed form "
              f"{rounds * k * lora['slice_bytes']}", file=sys.stderr)
        ok = False
    if led.p2_bytes != rounds * acc.compressed_round_bytes(
            "fedavg", k, lora["x_bytes"], lora["slice_bytes"]):
        print("[perf_peft] REGRESSION: ledger round bytes != closed form",
              file=sys.stderr)
        ok = False
    speedup = (results["lora8"]["rounds_per_sec"]
               / results["full_ft"]["rounds_per_sec"])
    print(f"[perf_peft] lora8 at {speedup:.2f}x full-ft rounds/s "
          f"(gate ≥ {SPEED_GATE}x)", flush=True)
    if speedup < SPEED_GATE:
        print(f"[perf_peft] REGRESSION: speedup {speedup:.2f}x "
              f"< {SPEED_GATE}x", file=sys.stderr)
        ok = False

    print()
    print(fmt_table(rows, ["mode", "rounds_per_sec", "dispatches",
                           "upload_ratio"]))
    save_result(f"perf_peft_{args.scale}",
                {"config": vars(args),
                 "full_model_upload_ratio": round(ratio_full, 2),
                 "speedup": round(speedup, 3), "rows": rows})
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
