"""§Roofline report — aggregate the dry-run artifacts into the
per-(arch × shape × mesh) three-term roofline table.

Reads experiments/dryrun/*.json (written by repro.launch.dryrun) and
emits a markdown table with:
  compute / memory / collective terms (seconds), dominant bottleneck,
  MODEL_FLOPS = 6·N(_active)·D, useful-FLOPs ratio.

A second table covers the fused FL-update kernels
(repro.kernels.fused_update): analytic TPU roofline terms per model
size (they are pure-elementwise, so t_memory dominates by construction)
plus a measured interpret-mode wall time on this host (timed with the
shared ``benchmarks/common.py:time_best_of`` policy).

    PYTHONPATH=src python -m benchmarks.roofline_report [--mesh 16x16]
    PYTHONPATH=src python -m benchmarks.roofline_report --no-update-kernels
"""
from __future__ import annotations

import argparse
import json
import pathlib

DRYRUN_DIR = pathlib.Path(__file__).resolve().parents[1] / "experiments" / "dryrun"

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]

# fused-update kernel traffic models: (name, reads, writes) in units of
# one n_params f32 buffer — e.g. local_step reads p,g,m and writes p,m
UPDATE_KERNELS = [
    ("local_step",      3, 2),     # p,g,m -> p,m  (momentum variant)
    ("delta_accum",     3, 1),     # d,w,p -> d
    ("server_momentum", 3, 2),     # p,delta,m -> p,m
    ("server_adam",     4, 3),     # p,delta,mu,nu -> p,mu,nu
]


def load_rows(mesh: str):
    rows = []
    for f in sorted(DRYRUN_DIR.glob(f"*_{mesh}.json")):
        r = json.loads(f.read_text())
        if r.get("mesh") == mesh:
            rows.append(r)
    rows.sort(key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"])
                             if r["shape"] in SHAPE_ORDER else 99))
    return rows


def _fmt_s(x) -> str:
    if x is None:
        return "-"
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.1f}ms"
    return f"{x * 1e6:.0f}us"


def to_markdown(rows) -> str:
    head = ("| arch | shape | t_compute | t_memory | t_collective | "
            "bottleneck | useful_flops | status |")
    sep = "|" + "---|" * 8
    lines = [head, sep]
    for r in rows:
        if not r.get("ok"):
            lines.append(f"| {r['arch']} | {r['shape']} | - | - | - | - | - | "
                         f"FAIL: {r.get('error', '?')[:60]} |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {_fmt_s(r['t_compute_s'])} | "
            f"{_fmt_s(r['t_memory_s'])} | {_fmt_s(r['t_collective_s'])} | "
            f"{r['bottleneck']} | {r['useful_flops_ratio']:.2f} | ok |")
    return "\n".join(lines)


def update_kernel_rows(n_params_list, repeats: int = 3):
    """Roofline rows for the fused FL-update kernels: analytic TPU terms
    (HBM/flops constants from repro.launch.mesh — elementwise kernels,
    so memory-bound by construction) plus a measured interpret-mode
    wall time on this host for the local_step kernel."""
    import jax
    import jax.numpy as jnp

    from benchmarks.common import time_best_of
    from repro.kernels import ops
    from repro.launch.mesh import HBM_BW, PEAK_FLOPS_BF16

    rows = []
    for n in n_params_list:
        for name, reads, writes in UPDATE_KERNELS:
            bytes_moved = (reads + writes) * 4 * n
            flops = 8 * n          # ~a handful of FMA-class ops per elem
            row = {"kernel": name, "n_params": n,
                   "bytes": bytes_moved, "flops": flops,
                   "t_compute_s": flops / PEAK_FLOPS_BF16,
                   "t_memory_s": bytes_moved / HBM_BW,
                   "bottleneck": "memory"}
            if name == "local_step":
                p = jnp.zeros((n,), jnp.float32)
                g = jnp.ones((n,), jnp.float32)
                m = jnp.zeros((n,), jnp.float32)
                fn = lambda: jax.block_until_ready(ops.fused_local_step(  # noqa: E731
                    p, g, m, None, 1.0, 0.01, momentum=0.9,
                    interpret=True)[0])
                fn()
                row["t_host_interpret_s"] = time_best_of(fn, repeats)
            rows.append(row)
    return rows


def update_kernels_markdown(rows) -> str:
    head = ("| kernel | n_params | bytes | t_compute | t_memory | "
            "bottleneck | t_host_interpret |")
    lines = [head, "|" + "---|" * 7]
    for r in rows:
        host = r.get("t_host_interpret_s")
        lines.append(
            f"| {r['kernel']} | {r['n_params']:.0e} | {r['bytes']:.2e} | "
            f"{_fmt_s(r['t_compute_s'])} | {_fmt_s(r['t_memory_s'])} | "
            f"{r['bottleneck']} | {_fmt_s(host) if host else '-'} |")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--no-update-kernels", action="store_true",
                    help="skip the fused-update kernel roofline section")
    args = ap.parse_args(argv)
    rows = load_rows(args.mesh)
    rc = 0
    if not rows:
        print(f"[roofline] no dry-run artifacts for mesh {args.mesh} — run "
              "PYTHONPATH=src python -m repro.launch.dryrun --all first")
        rc = 1
    else:
        print(to_markdown(rows))
        n_ok = sum(1 for r in rows if r.get("ok"))
        by_bneck = {}
        for r in rows:
            if r.get("ok"):
                by_bneck[r["bottleneck"]] = by_bneck.get(r["bottleneck"], 0) + 1
        print(f"\n[roofline] {n_ok}/{len(rows)} pairs ok on {args.mesh}; "
              f"bottlenecks: {by_bneck}")
    if not args.no_update_kernels:
        print("\n### fused FL-update kernels (repro.kernels.fused_update)\n")
        print(update_kernels_markdown(
            update_kernel_rows([10 ** 5, 10 ** 6, 10 ** 7])))
        print("\n[roofline] update kernels are elementwise — memory-bound "
              "at every size; the host column is CPU interpret mode "
              "(correctness vehicle), not TPU time")
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
