"""§Roofline report — aggregate the dry-run artifacts into the
per-(arch × shape × mesh) three-term roofline table.

Reads experiments/dryrun/*.json (written by repro.launch.dryrun) and
emits a markdown table with:
  compute / memory / collective terms (seconds), dominant bottleneck,
  MODEL_FLOPS = 6·N(_active)·D, useful-FLOPs ratio.

    PYTHONPATH=src python -m benchmarks.roofline_report [--mesh 16x16]
"""
from __future__ import annotations

import argparse
import json
import pathlib

DRYRUN_DIR = pathlib.Path(__file__).resolve().parents[1] / "experiments" / "dryrun"

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load_rows(mesh: str):
    rows = []
    for f in sorted(DRYRUN_DIR.glob(f"*_{mesh}.json")):
        r = json.loads(f.read_text())
        if r.get("mesh") == mesh:
            rows.append(r)
    rows.sort(key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"])
                             if r["shape"] in SHAPE_ORDER else 99))
    return rows


def _fmt_s(x) -> str:
    if x is None:
        return "-"
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.1f}ms"
    return f"{x * 1e6:.0f}us"


def to_markdown(rows) -> str:
    head = ("| arch | shape | t_compute | t_memory | t_collective | "
            "bottleneck | useful_flops | status |")
    sep = "|" + "---|" * 8
    lines = [head, sep]
    for r in rows:
        if not r.get("ok"):
            lines.append(f"| {r['arch']} | {r['shape']} | - | - | - | - | - | "
                         f"FAIL: {r.get('error', '?')[:60]} |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {_fmt_s(r['t_compute_s'])} | "
            f"{_fmt_s(r['t_memory_s'])} | {_fmt_s(r['t_collective_s'])} | "
            f"{r['bottleneck']} | {r['useful_flops_ratio']:.2f} | ok |")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args(argv)
    rows = load_rows(args.mesh)
    if not rows:
        print(f"[roofline] no dry-run artifacts for mesh {args.mesh} — run "
              "PYTHONPATH=src python -m repro.launch.dryrun --all first")
        return 1
    print(to_markdown(rows))
    n_ok = sum(1 for r in rows if r.get("ok"))
    by_bneck = {}
    for r in rows:
        if r.get("ok"):
            by_bneck[r["bottleneck"]] = by_bneck.get(r["bottleneck"], 0) + 1
    print(f"\n[roofline] {n_ok}/{len(rows)} pairs ok on {args.mesh}; "
          f"bottlenecks: {by_bneck}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
