"""Overlapped round pipeline: ``RoundSchedule(overlap=True)`` vs the
synchronous path on the sparse client-state store.

With ``overlap=False`` every dispatch serializes host residency
planning (eviction choice, spill gather, refill ``device_put``) against
device compute.  With ``overlap=True`` the engine stages chunk N+1's
residency while dispatch N runs: ``stage_chunk`` plans on numpy mirrors
of the slot indices and enqueues one stacked non-blocking transfer from
a pinned staging buffer; ``commit_chunk`` splices the staged rows
against the latest table right before dispatch.  Both paths consume the
identical host-rng stream, so results are bitwise equal — this
benchmark measures the throughput side and gates on it.

Reported per population (scaffold mlp, K=64, host sampling, eval off):

  rounds/s (sync / overlap), overlap speedup, and the pipeline timing
  breakdown from ``EngineResult.timing`` (host-residency ms, staged
  transfer ms, dispatch-enqueue ms, device-wait ms).

Regression gates (exit 1):
  1. dispatch counts are exact — ceil(rounds / chunk) for BOTH modes
     (the pipeline must not split or merge chunks);
  2. overlap throughput ≥ 0.9× sync at every population (staging off
     the critical path can't cost more than measurement noise);
  3. final params bitwise equal between the two modes.

    PYTHONPATH=src python -m benchmarks.perf_pipeline
    PYTHONPATH=src python -m benchmarks.perf_pipeline --scale full
"""
from __future__ import annotations

import argparse
import math
import sys
from typing import Dict, List

import jax
import numpy as np

from benchmarks.common import fmt_table, save_result, time_best_of
from benchmarks.perf_client_store import _make_data
from repro.fl.engine import (
    AggregateStrategy,
    RoundSchedule,
    SparseClientStateStore,
    run_rounds,
)
from repro.fl.local import LocalSpec
from repro.fl.task import vision_task

POPULATIONS = {"quick": (10_000,), "full": (100_000, 1_000_000)}
IMG = 4
D_HIDDEN = 128
PER_CLIENT = 2

TIMING_KEYS = ("host_residency_ms", "staged_transfer_ms",
               "dispatch_enqueue_ms", "device_wait_ms")


def _bench_one(task, data, *, overlap: bool, capacity: int,
               clients_per_round: int, rounds: int, chunk: int,
               repeats: int, seed: int) -> Dict:
    spec = LocalSpec(n_steps=2, batch_size=PER_CLIENT, lr=0.05,
                     variant="scaffold")
    strat = AggregateStrategy(
        spec=spec, algorithm="scaffold",
        participation=clients_per_round / data.n_clients,
        state_store=SparseClientStateStore(capacity=capacity))
    sched = RoundSchedule(rounds=rounds, lr_decay=1.0, eval_every=0,
                          seed=seed, chunk_size=chunk, sampling="host",
                          host_rng_offset=17, overlap=overlap)
    res = run_rounds(task, data, strat, sched)          # compile + warm
    secs = time_best_of(
        lambda: jax.block_until_ready(jax.tree_util.tree_leaves(
            run_rounds(task, data, strat, sched).params)), repeats)
    assert np.isfinite(res.history[-1]["local_loss"])
    return {"secs": secs, "rounds_per_sec": rounds / secs,
            "dispatches": res.dispatches,
            "timing": dict(res.timing or {}),
            "params": jax.tree_util.tree_map(np.asarray, res.params)}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scale", default="quick", choices=("quick", "full"))
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--chunk", type=int, default=2)
    ap.add_argument("--clients-per-round", type=int, default=64)
    ap.add_argument("--capacity", type=int, default=256)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    task = vision_task("mlp", in_ch=1,
                       seed_kwargs={"img": IMG, "d_hidden": D_HIDDEN})
    want_dispatches = math.ceil(args.rounds / args.chunk)
    print(f"[perf_pipeline] K={args.clients_per_round}, "
          f"capacity={args.capacity}, rounds={args.rounds}, "
          f"chunk={args.chunk} → {want_dispatches} dispatches", flush=True)

    ok = True
    rows: List[Dict] = []
    for n in POPULATIONS[args.scale]:
        data = _make_data(n, args.seed)
        bench = dict(capacity=args.capacity,
                     clients_per_round=args.clients_per_round,
                     rounds=args.rounds, chunk=args.chunk,
                     repeats=args.repeats, seed=args.seed)
        sync = _bench_one(task, data, overlap=False, **bench)
        ovl = _bench_one(task, data, overlap=True, **bench)

        speedup = ovl["rounds_per_sec"] / sync["rounds_per_sec"]
        for mode, r in (("sync", sync), ("overlap", ovl)):
            rows.append({"mode": mode, "n_clients": n,
                         "rounds_per_sec": round(r["rounds_per_sec"], 2),
                         "dispatches": r["dispatches"],
                         **{k: round(r["timing"].get(k, 0.0), 2)
                            for k in TIMING_KEYS}})
        print(f"  n={n:>9,d}  sync {sync['rounds_per_sec']:7.2f} r/s  "
              f"overlap {ovl['rounds_per_sec']:7.2f} r/s  "
              f"({speedup:.2f}x)", flush=True)

        # --- gates --------------------------------------------------------
        for mode, r in (("sync", sync), ("overlap", ovl)):
            if r["dispatches"] != want_dispatches:
                print(f"[perf_pipeline] REGRESSION: {mode} at n={n:,d} ran "
                      f"{r['dispatches']} dispatches, want {want_dispatches}",
                      file=sys.stderr)
                ok = False
        if speedup < 0.9:
            print(f"[perf_pipeline] REGRESSION: overlap at n={n:,d} is "
                  f"{speedup:.2f}x sync — staging is on the critical path",
                  file=sys.stderr)
            ok = False
        for a, b in zip(jax.tree_util.tree_leaves(sync["params"]),
                        jax.tree_util.tree_leaves(ovl["params"])):
            if not np.array_equal(a, b):
                print(f"[perf_pipeline] REGRESSION: overlap != sync params "
                      f"at n={n:,d} (bitwise)", file=sys.stderr)
                ok = False
                break

    print()
    print(fmt_table(rows, ["mode", "n_clients", "rounds_per_sec",
                           "dispatches", *TIMING_KEYS]))
    save_result(f"perf_pipeline_{args.scale}",
                {"config": vars(args), "want_dispatches": want_dispatches,
                 "rows": rows})
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
