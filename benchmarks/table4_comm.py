"""Table IV — communication overhead: measured ledger vs closed forms.

The paper derives per-algorithm communication totals:
    FedAvg/FedProx/Moon  w/o cyclic : 2·K_P2·T_tot·X
    SCAFFOLD             w/o cyclic : 4·K_P2·T_tot·X
    FedAvg/FedProx/Moon  w/ cyclic  : 2·[K_P1·T_cyc + K_P2·T_res]·X
    SCAFFOLD             w/ cyclic  : 2·[K_P1·T_cyc + 2·K_P2·T_res]·X

Compressed P2 uploads (repro.fl.compression) change the per-round cost
to ``K_P2·legs·(X + payload)`` — downloads still ship the full model —
so the compressed rows check
    w/o cyclic : T_tot·compressed_round_bytes(algo, K_P2, X, payload)
    w/ cyclic  : 2·K_P1·T_cyc·X + T_res·compressed_round_bytes(...)
(P1 relays the model itself and is never compressed).

Trainable-slice (PEFT) P2 rounds change the upload the same way: the
download legs still ship the full model X but each client uploads its
trainable slice only, so the per-round cost is
``K_P2·legs·(X + payload_peft)`` with ``payload_peft`` the dtype-aware
byte count of the trainable leaves — and a lossy spec on top compresses
THAT slice, so the two ratios compose multiplicatively.  The PEFT rows
recompute the payload independently (trainable_mask over the abstract
param tree, not the engine's FlatView) and assert the measured ledger
equals the closed form exactly.

We run a short pipeline per (algorithm × cyclic × compression) under a
byte ledger and assert the measured totals equal the closed forms
EXACTLY (this is an accounting identity, not a statistical claim — a
tiny scale suffices).
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from benchmarks import common as C
from repro.core import comm_accounting as acc
from repro.fl import compression as comp
from repro.fl.compression import CompressionSpec
from repro.fl.local import host_flat_ops
from repro.sharding import rules

# the compressed column's wire spec: int8 blocks + 25% top-k, the
# highest-leverage point of the sweep (BENCHMARKS.md 'Compression')
COMPRESSED = CompressionSpec(bits=8, density=0.25, error_feedback=True)

# head-only fine-tune of the vision model: a verbatim path regex
# (resolve_trainable_filter passes unregistered names through) keeping
# only the classifier head f3 trainable — the vision-scale stand-in for
# a LoRA slice (the LLM LoRA ratio gates in benchmarks/perf_peft.py)
PEFT_FILTER = r"(^|/)f3/(w|b)$"


def _peft_payload_bytes(task, filter_spec, spec=None) -> int:
    """Closed-form upload payload of one client's trainable slice,
    computed from the abstract param tree — independent of the engine's
    FlatView bookkeeping it is checked against."""
    p_specs = jax.eval_shape(task.init, jax.random.PRNGKey(0))
    mask = rules.trainable_mask(p_specs, filter_spec)
    leaves = jax.tree_util.tree_leaves(p_specs)
    trainable = [l for l, m in zip(leaves, mask) if m]
    if comp.compression_on(spec):
        sizes = {}
        for l in trainable:
            sizes[np.dtype(l.dtype).name] = \
                sizes.get(np.dtype(l.dtype).name, 0) + int(np.prod(l.shape))
        return comp.payload_bytes(spec, tuple(sizes.values()))
    return int(sum(np.dtype(l.dtype).itemsize * np.prod(l.shape)
                   for l in trainable))


def run(scale: C.Scale, seed: int = 0):
    # the identity is exact at any scale — use a micro run regardless of
    # preset so Table IV costs seconds, not a full training sweep
    scale = C.Scale("micro", n_clients=12, n_train=480, n_test=120,
                    p1_rounds=2, p2_rounds=3, p1_local_steps=2,
                    p2_local_steps=2, eval_every=10)
    task, data = C.make_vision_setup(scale, beta=0.5, seed=seed)
    rows = []
    k_p1 = C.cyclic_cfg(scale).n_selected(data.n_clients)
    k_p2 = C.fl_cfg(scale, "fedavg").n_selected(data.n_clients)
    t_cyc, t_res = scale.p1_rounds, scale.p2_rounds
    t_tot = t_cyc + t_res
    sizes = tuple(host_flat_ops(task, True).view.buffer_sizes.values())
    payload = comp.payload_bytes(COMPRESSED, sizes)
    for algo in ("fedavg", "fedprox", "moon", "scaffold"):
        for cyclic in (False, True):
            for spec in (None, COMPRESSED):
                res = C.run_method(task, data, scale, algorithm=algo,
                                   cyclic=cyclic, seed=seed,
                                   compression=spec)
                led = res.ledger.summary()
                x = led["model_bytes"]
                if spec is None:
                    if cyclic:
                        closed = acc.overhead_with_cyclic(
                            algo, k_p1, t_cyc, k_p2, t_res, x)
                    else:
                        closed = acc.overhead_without_cyclic(
                            algo, k_p2, t_tot, x)
                else:
                    # P1 (if any) stays exact; every P2 round pays the
                    # compressed form
                    p2_rounds = t_res if cyclic else t_tot
                    closed = (2 * k_p1 * t_cyc * x if cyclic else 0) + \
                        p2_rounds * acc.compressed_round_bytes(
                            algo, k_p2, x, payload)
                rows.append({
                    "algorithm": algo, "cyclic": cyclic,
                    "compressed": spec is not None,
                    "measured_bytes": led["total_bytes"],
                    "closed_form_bytes": closed,
                    "payload_ratio": round(led["payload_ratio"], 3),
                    "match": led["total_bytes"] == closed,
                })
                print(f"[table4] {algo:9s} cyclic={cyclic} "
                      f"compressed={spec is not None} "
                      f"measured={led['total_bytes']:.3e} "
                      f"closed={closed:.3e} "
                      f"match={rows[-1]['match']}", flush=True)
    # trainable-slice (PEFT) column: head-only uploads, alone and
    # composed with the lossy wire spec — the compression ratio applies
    # to the SLICE, so the two reductions multiply
    for algo in ("fedavg", "scaffold"):
        for cyclic in (False, True):
            for spec in (None, COMPRESSED):
                res = C.run_method(task, data, scale, algorithm=algo,
                                   cyclic=cyclic, seed=seed,
                                   compression=spec,
                                   trainable_filter=PEFT_FILTER)
                led = res.ledger.summary()
                x = led["model_bytes"]
                p_bytes = _peft_payload_bytes(task, PEFT_FILTER, spec)
                p2_rounds = t_res if cyclic else t_tot
                closed = (2 * k_p1 * t_cyc * x if cyclic else 0) + \
                    p2_rounds * acc.compressed_round_bytes(
                        algo, k_p2, x, p_bytes)
                rows.append({
                    "algorithm": algo, "cyclic": cyclic,
                    "compressed": spec is not None, "peft": True,
                    "measured_bytes": led["total_bytes"],
                    "closed_form_bytes": closed,
                    "payload_ratio": round(led["payload_ratio"], 4),
                    "match": led["total_bytes"] == closed,
                })
                print(f"[table4] {algo:9s} cyclic={cyclic} "
                      f"compressed={spec is not None} peft=True "
                      f"measured={led['total_bytes']:.3e} "
                      f"closed={closed:.3e} "
                      f"match={rows[-1]['match']}", flush=True)
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scale", default="quick", choices=list(C.SCALES))
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    scale = C.SCALES[args.scale]
    rows = run(scale, seed=args.seed)
    for r in rows:
        r.setdefault("peft", False)
    print(C.fmt_table(rows, ["algorithm", "cyclic", "compressed", "peft",
                             "measured_bytes", "closed_form_bytes",
                             "payload_ratio", "match"]))
    C.save_result(f"table4_{args.scale}", {"rows": rows})
    n_match = sum(1 for r in rows if r["match"])
    print(f"[table4] ledger == closed form: {n_match}/{len(rows)}")
    return 0 if n_match == len(rows) else 1


if __name__ == "__main__":
    raise SystemExit(main())
