"""Compressed communication on the fused round path: wire savings vs
compute cost, end to end.

Each mode runs the SAME engine program shape — K vmapped local runs, a
scan over chunked rounds, fused flat-buffer aggregation — differing only
in the per-client upload transform (repro.fl.compression):

  baseline : compression=None (the pre-compression program, verbatim)
  identity : CompressionSpec(bits=32, density=1.0) — must compile to the
             exact baseline program (the identity spec is statically off)
  int8     : blockwise symmetric int8 quantization, bf16 block scales
  int8+topk+ef : + 25% magnitude top-k + error feedback residuals

Reported per mode: rounds/s, dispatch count, and the ledger's
upload-side ``payload_ratio``.

Regression gates (exit 1):
  1. dispatch counts are exact — ceil(rounds / chunk) in every mode;
  2. every compressed mode sustains ≥ 0.9× baseline rounds/s — the
     compress kernels ride the already-fused flat pass, so they may not
     dominate the round;
  3. identity final params == baseline, BITWISE;
  4. ledger payload_ratio ≥ 3.9 at int8 dense (bf16 block scales:
     4 bytes → 1 + 2/128 per element).

    PYTHONPATH=src python -m benchmarks.perf_compression
    PYTHONPATH=src python -m benchmarks.perf_compression --scale full
"""
from __future__ import annotations

import argparse
import math
import sys
from typing import Dict, List, Optional

import jax
import numpy as np

from benchmarks.common import fmt_table, save_result, time_best_of
from repro.core.comm_accounting import CommLedger
from repro.data.federated import FederatedDataset
from repro.fl.compression import CompressionSpec
from repro.fl.engine import AggregateStrategy, RoundSchedule, run_rounds
from repro.fl.local import LocalSpec
from repro.fl.task import vision_task

IMG = 4
D_HIDDEN = 128
PER_CLIENT = 4
# paper-scale local work (≈20 steps/round): the compress transform runs
# once per client per round, so the gate below measures its cost
# AMORTIZED against a representative round, not against a near-empty one
N_STEPS = 10

MODES = (
    ("baseline", None),
    ("identity", CompressionSpec()),
    ("int8", CompressionSpec(bits=8)),
    ("int8+topk+ef", CompressionSpec(bits=8, density=0.25,
                                     error_feedback=True)),
)

# full scale grows the population and round count, not the model — the
# compress kernels scale with model bytes, the engine with K·rounds
N_CLIENTS = {"quick": 32, "full": 256}


def _make_data(n_clients: int, seed: int) -> FederatedDataset:
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n_clients, PER_CLIENT, IMG, IMG, 1)) \
        .astype(np.float32)
    y = rng.integers(0, 10, size=(n_clients, PER_CLIENT)).astype(np.int32)
    return FederatedDataset(x=x, y=y,
                            n_real=np.full((n_clients,), PER_CLIENT,
                                           np.int32),
                            test_x=x[0], test_y=y[0], n_classes=10,
                            name=f"perf-compression-{n_clients}")


def _bench_one(task, data, spec: Optional[CompressionSpec], *,
               clients_per_round: int, rounds: int, chunk: int,
               repeats: int, seed: int) -> Dict:
    lspec = LocalSpec(n_steps=N_STEPS, batch_size=PER_CLIENT, lr=0.05,
                      variant="plain", update_impl="fused_interpret",
                      compression=spec)
    strat = AggregateStrategy(spec=lspec, algorithm="fedavg",
                              participation=clients_per_round
                              / data.n_clients)
    sched = RoundSchedule(rounds=rounds, lr_decay=1.0, eval_every=0,
                          seed=seed, chunk_size=chunk, sampling="host",
                          host_rng_offset=17)
    ledger = CommLedger()
    res = run_rounds(task, data, strat, sched, ledger=ledger)  # warm
    secs = time_best_of(
        lambda: jax.block_until_ready(jax.tree_util.tree_leaves(
            run_rounds(task, data, strat, sched).params)), repeats)
    assert np.isfinite(res.history[-1]["local_loss"])
    return {"secs": secs, "rounds_per_sec": rounds / secs,
            "dispatches": res.dispatches,
            "payload_ratio": ledger.summary()["payload_ratio"],
            "params": jax.tree_util.tree_map(np.asarray, res.params)}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scale", default="quick", choices=("quick", "full"))
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--chunk", type=int, default=2)
    ap.add_argument("--clients-per-round", type=int, default=8)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    task = vision_task("mlp", in_ch=1,
                       seed_kwargs={"img": IMG, "d_hidden": D_HIDDEN})
    data = _make_data(N_CLIENTS[args.scale], args.seed)
    want_dispatches = math.ceil(args.rounds / args.chunk)
    print(f"[perf_compression] n={data.n_clients}, "
          f"K={args.clients_per_round}, rounds={args.rounds}, "
          f"chunk={args.chunk} → {want_dispatches} dispatches", flush=True)

    ok = True
    rows: List[Dict] = []
    results: Dict[str, Dict] = {}
    for mode, spec in MODES:
        r = _bench_one(task, data, spec,
                       clients_per_round=args.clients_per_round,
                       rounds=args.rounds, chunk=args.chunk,
                       repeats=args.repeats, seed=args.seed)
        results[mode] = r
        rows.append({"mode": mode,
                     "rounds_per_sec": round(r["rounds_per_sec"], 2),
                     "dispatches": r["dispatches"],
                     "payload_ratio": round(r["payload_ratio"], 3)})
        print(f"  {mode:13s} {r['rounds_per_sec']:7.2f} r/s  "
              f"ratio {r['payload_ratio']:.3f}", flush=True)

    # --- gates ------------------------------------------------------------
    base = results["baseline"]
    for mode, r in results.items():
        if r["dispatches"] != want_dispatches:
            print(f"[perf_compression] REGRESSION: {mode} ran "
                  f"{r['dispatches']} dispatches, want {want_dispatches}",
                  file=sys.stderr)
            ok = False
        rel = r["rounds_per_sec"] / base["rounds_per_sec"]
        if mode != "baseline" and rel < 0.9:
            print(f"[perf_compression] REGRESSION: {mode} at {rel:.2f}x "
                  f"baseline — compression dominates the round",
                  file=sys.stderr)
            ok = False
    for a, b in zip(jax.tree_util.tree_leaves(base["params"]),
                    jax.tree_util.tree_leaves(results["identity"]["params"])):
        if not np.array_equal(a, b):
            print("[perf_compression] REGRESSION: identity != baseline "
                  "params (bitwise)", file=sys.stderr)
            ok = False
            break
    if results["int8"]["payload_ratio"] < 3.9:
        print(f"[perf_compression] REGRESSION: int8 dense payload_ratio "
              f"{results['int8']['payload_ratio']:.3f} < 3.9",
              file=sys.stderr)
        ok = False

    print()
    print(fmt_table(rows, ["mode", "rounds_per_sec", "dispatches",
                           "payload_ratio"]))
    save_result(f"perf_compression_{args.scale}",
                {"config": vars(args), "want_dispatches": want_dispatches,
                 "rows": rows})
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
