"""Table I — test-accuracy comparison under Dirichlet non-IID.

Paper artifact: FedAvg / FedProx / SCAFFOLD / Moon vs Cyclic+FedAvg on
vision benchmarks at β ∈ {0.1, 0.5, 1.0}.  Here: synthetic cifar10-like
(class-conditional templates, Dirichlet-partitioned) — the claim under
test is the ORDERING (Cyclic+FedAvg ≥ baselines, gap grows as β
shrinks), not absolute CIFAR numbers (offline container).
"""
from __future__ import annotations

import argparse
import time

from benchmarks import common as C

METHODS = [
    ("fedavg", False), ("fedprox", False), ("scaffold", False),
    ("moon", False), ("fedavg", True),          # Cyclic+FedAvg
]


def method_name(algorithm: str, cyclic: bool) -> str:
    return f"cyclic+{algorithm}" if cyclic else algorithm


def run(scale: C.Scale, betas, seed: int = 0, verbose: bool = False):
    rows = []
    for beta in betas:
        task, data = make_setup(scale, beta, seed)
        for algorithm, cyclic in METHODS:
            t0 = time.time()
            res = C.run_method(task, data, scale, algorithm=algorithm,
                               cyclic=cyclic, seed=seed, verbose=verbose)
            s = C.summarize(res)
            rows.append({
                "beta": beta, "method": method_name(algorithm, cyclic),
                "best_acc": s["best_acc"], "final_acc": s["final_acc"],
                "seconds": round(time.time() - t0, 1),
            })
            print(f"[table1] beta={beta} {rows[-1]['method']:16s} "
                  f"best={s['best_acc']:.4f} ({rows[-1]['seconds']}s)",
                  flush=True)
    return rows


def make_setup(scale, beta, seed):
    return C.make_vision_setup(scale, beta, model="lenet5", seed=seed)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scale", default="quick", choices=list(C.SCALES))
    ap.add_argument("--betas", default="0.1,0.5")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    scale = C.SCALES[args.scale]
    betas = [float(b) for b in args.betas.split(",")]
    rows = run(scale, betas, seed=args.seed)
    print(C.fmt_table(rows, ["beta", "method", "best_acc", "final_acc",
                             "seconds"]))
    C.save_result(f"table1_{args.scale}", {"rows": rows, "scale": scale.name,
                                           "betas": betas})
    # headline check: cyclic+fedavg beats fedavg at every beta
    ok = all(
        next(r for r in rows if r["beta"] == b and r["method"] == "cyclic+fedavg")["best_acc"]
        >= next(r for r in rows if r["beta"] == b and r["method"] == "fedavg")["best_acc"]
        for b in betas)
    print(f"[table1] cyclic+fedavg >= fedavg at every beta: {ok}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
