"""Fig 5/6 — impact of cyclic-training duration (the P1→P2 switch point).

Paper artifact: final accuracy as a function of rounds spent in P1 with
the TOTAL budget fixed — a rise-then-slow-descent curve with a knee
(switching strictly beats never switching; very long P1 wastes budget).
We sweep T_cyc over a grid and record best/final accuracy per point.
"""
from __future__ import annotations

import argparse
import time

from benchmarks import common as C


def run(scale: C.Scale, beta: float = 0.5, seed: int = 0, grid=None):
    task, data = C.make_vision_setup(scale, beta, seed=seed)
    total = scale.p1_rounds + scale.p2_rounds
    if grid is None:
        grid = sorted({0, max(total // 8, 1), scale.p1_rounds,
                       total // 2, total - 2})
    rows = []
    for t_cyc in grid:
        t0 = time.time()
        res = C.run_method(task, data, scale, algorithm="fedavg",
                           cyclic=t_cyc > 0, seed=seed,
                           p1_rounds=t_cyc, p2_rounds=total - t_cyc)
        s = C.summarize(res)
        rows.append({"t_cyc": t_cyc, "t_p2": total - t_cyc,
                     "best_acc": s["best_acc"], "final_acc": s["final_acc"],
                     "seconds": round(time.time() - t0, 1)})
        print(f"[fig5] T_cyc={t_cyc:3d} best={s['best_acc']:.4f} "
              f"final={s['final_acc']:.4f} ({rows[-1]['seconds']}s)",
              flush=True)
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scale", default="quick", choices=list(C.SCALES))
    ap.add_argument("--beta", type=float, default=0.5)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    scale = C.SCALES[args.scale]
    rows = run(scale, beta=args.beta, seed=args.seed)
    print(C.fmt_table(rows, ["t_cyc", "t_p2", "best_acc", "final_acc"]))
    C.save_result(f"fig5_{args.scale}", {"rows": rows, "beta": args.beta})
    # qualitative check: some intermediate switch beats both extremes
    mid = max((r["best_acc"] for r in rows[1:-1]), default=0.0)
    print(f"[fig5] intermediate switch best={mid:.4f} "
          f"vs no-P1={rows[0]['best_acc']:.4f} "
          f"vs near-all-P1={rows[-1]['best_acc']:.4f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
