"""Client-state store scaling: dense population tables vs the
participation-indexed sparse store.

Stateful FL algorithms (scaffold, moon) keep per-client state.  The
dense ``DenseClientStateStore`` materializes it as ``(n_clients, …)``
stacks — O(population) device memory, which caps single-host simulation
around 10^5 clients for even a toy model.  The sparse
``SparseClientStateStore`` keeps a bounded ``(capacity, …)`` active-set
table plus O(n_clients) int32 residency indices, spilling evicted rows
to host memory — so device state scales with *participation*
(``capacity`` ≳ chunk_size × K) instead of population.

This benchmark sweeps n_clients ∈ {1e3, 1e4} (quick) ∪ {1e5, 1e6}
(full) at fixed K=64 on a scaffold mlp sim and reports, per population:

  state_mb   : actual bytes held by the c_clients store after a run
               (dense: the stack; sparse: table + residency indices)
  rounds/s   : end-to-end engine throughput, eval off, host sampling

Dense rows above the device-state budget (1 GiB) are *gated*: reported
analytically, not run — that infeasibility is the point.  At full
scale the 10^6-client sparse row therefore runs where dense cannot.

Regression gates (exit 1):
  1. the sparse active-set table is byte-identical across populations —
     memory O(capacity), not O(n_clients);
  2. at the largest population, sparse total state (table + indices)
     is ≥10× below the dense analytic requirement;
  3. sparse throughput at the largest population stays within 2× of
     dense at ITS largest feasible population (residency management
     must not dominate the round loop).

    PYTHONPATH=src python -m benchmarks.perf_client_store
    PYTHONPATH=src python -m benchmarks.perf_client_store --scale full
"""
from __future__ import annotations

import argparse
import sys
from typing import Dict, List

import jax
import numpy as np

from benchmarks.common import fmt_table, save_result, time_best_of
from repro.data.federated import FederatedDataset
from repro.fl.engine import (
    AggregateStrategy,
    RoundSchedule,
    SparseClientStateStore,
    run_rounds,
)
from repro.fl.local import LocalSpec
from repro.fl.task import vision_task

POPULATIONS = {"quick": (1_000, 10_000),
               "full": (1_000, 10_000, 100_000, 1_000_000)}
IMG = 4                       # 4×4×1 synthetic images: data stays small
PER_CLIENT = 2                # samples per client
D_HIDDEN = 128                # ≈3.5k params → dense scaffold state crosses
                              # the 1 GiB budget between 1e4 and 1e5 clients
DENSE_BUDGET_BYTES = 1 << 30


def _make_data(n_clients: int, seed: int) -> FederatedDataset:
    """Hand-built dataset — from_arrays' Dirichlet partition is O(n²)-ish
    bookkeeping and pointless at 10^6 synthetic clients."""
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(
        (n_clients, PER_CLIENT, IMG, IMG, 1), dtype=np.float32)
    y = rng.integers(0, 10, size=(n_clients, PER_CLIENT)).astype(np.int32)
    return FederatedDataset(
        x=x, y=y, n_real=np.full((n_clients,), PER_CLIENT, np.int32),
        test_x=x[0], test_y=y[0], n_classes=10,
        name=f"store-bench-{n_clients}")


def _state_row_bytes(task) -> int:
    """Per-client scaffold state (a zeros_like-params row), in bytes."""
    shapes = jax.eval_shape(task.init, jax.random.PRNGKey(0))
    return sum(int(np.prod(l.shape)) * l.dtype.itemsize
               for l in jax.tree_util.tree_leaves(shapes))


def _store_bytes(store_state) -> Dict[str, int]:
    if isinstance(store_state, dict) and "table" in store_state:
        table = sum(l.nbytes for l in
                    jax.tree_util.tree_leaves(store_state["table"]))
        index = sum(store_state[k].nbytes
                    for k in ("slot_of", "owner", "stamp"))
        return {"table": table, "index": index, "total": table + index}
    total = sum(l.nbytes for l in jax.tree_util.tree_leaves(store_state))
    return {"table": total, "index": 0, "total": total}


def _bench_one(task, data, store, *, clients_per_round: int, rounds: int,
               repeats: int, seed: int) -> Dict:
    spec = LocalSpec(n_steps=2, batch_size=PER_CLIENT, lr=0.05,
                     variant="scaffold")
    kwargs = {"state_store": store} if store is not None else {}
    # fixed K at any population: participation = K / n
    strat = AggregateStrategy(spec=spec, algorithm="scaffold",
                              participation=clients_per_round / data.n_clients,
                              **kwargs)
    assert strat.n_selected(data.n_clients) == clients_per_round
    sched = RoundSchedule(rounds=rounds, lr_decay=1.0, eval_every=0,
                          seed=seed, chunk_size=2, sampling="host",
                          host_rng_offset=17)
    res = run_rounds(task, data, strat, sched)          # compile + warm
    secs = time_best_of(
        lambda: jax.block_until_ready(jax.tree_util.tree_leaves(
            run_rounds(task, data, strat, sched).params)), repeats)
    bytes_ = _store_bytes(res.algo_state["c_clients"])
    assert np.isfinite(res.history[-1]["local_loss"])
    timing = {k: round(v, 2) for k, v in (res.timing or {}).items()}
    return {"secs": secs, "rounds_per_sec": rounds / secs,
            "timing": timing, **bytes_}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scale", default="quick", choices=("quick", "full"))
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--clients-per-round", type=int, default=64)
    ap.add_argument("--capacity", type=int, default=256,
                    help="sparse active-set slots; must cover one dispatch "
                    "(chunk_size × K distinct clients)")
    ap.add_argument("--repeats", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.capacity < 2 * args.clients_per_round:
        ap.error("--capacity must cover one dispatch (2 chunked rounds × K)")

    task = vision_task("mlp", in_ch=1,
                       seed_kwargs={"img": IMG, "d_hidden": D_HIDDEN})
    row_bytes = _state_row_bytes(task)
    print(f"[perf_client_store] scaffold row = {row_bytes} B/client, "
          f"K={args.clients_per_round}, capacity={args.capacity}, "
          f"dense budget = {DENSE_BUDGET_BYTES >> 20} MiB", flush=True)

    rows: List[Dict] = []
    for n in POPULATIONS[args.scale]:
        data = _make_data(n, args.seed)
        bench = dict(clients_per_round=args.clients_per_round,
                     rounds=args.rounds, repeats=args.repeats,
                     seed=args.seed)

        dense_analytic = n * row_bytes
        if dense_analytic <= DENSE_BUDGET_BYTES:
            r = _bench_one(task, data, None, **bench)
            rows.append({"store": "dense", "n_clients": n, "gated": False,
                         "state_mb": round(r["total"] / 2**20, 2),
                         "rounds_per_sec": round(r["rounds_per_sec"], 2)})
        else:
            rows.append({"store": "dense", "n_clients": n, "gated": True,
                         "state_mb": round(dense_analytic / 2**20, 2),
                         "rounds_per_sec": None})

        r = _bench_one(task, data,
                       SparseClientStateStore(capacity=args.capacity),
                       **bench)
        rows.append({"store": "sparse", "n_clients": n, "gated": False,
                     "state_mb": round(r["total"] / 2**20, 2),
                     "table_mb": round(r["table"] / 2**20, 2),
                     "index_mb": round(r["index"] / 2**20, 2),
                     "rounds_per_sec": round(r["rounds_per_sec"], 2),
                     "timing": r["timing"]})
        for row in rows[-2:]:
            tag = "GATED (analytic)" if row["gated"] else \
                f"{row['rounds_per_sec']:8.2f} rounds/s"
            print(f"  {row['store']:6s} n={row['n_clients']:>9,d} "
                  f"state={row['state_mb']:10.2f} MB  {tag}", flush=True)
        # where the sparse round time goes (EngineResult.timing, last run)
        t = rows[-1]["timing"]
        if t:
            print("         " + "  ".join(f"{k}={t[k]}" for k in sorted(t)),
                  flush=True)

    print()
    print(fmt_table(rows, ["store", "n_clients", "gated", "state_mb",
                           "table_mb", "index_mb", "rounds_per_sec"]))
    save_result(f"perf_client_store_{args.scale}",
                {"config": vars(args), "row_bytes": row_bytes, "rows": rows})

    # --- regression gates -------------------------------------------------
    ok = True
    sparse = [r for r in rows if r["store"] == "sparse"]
    dense_run = [r for r in rows if r["store"] == "dense" and not r["gated"]]
    dense_all = [r for r in rows if r["store"] == "dense"]

    tables = {r["table_mb"] for r in sparse}
    if len(tables) != 1:
        print(f"[perf_client_store] REGRESSION: sparse table bytes vary "
              f"with population {sorted(tables)} — the active set is no "
              "longer O(capacity)", file=sys.stderr)
        ok = False

    big_sparse = max(sparse, key=lambda r: r["n_clients"])
    big_dense = max(dense_all, key=lambda r: r["n_clients"])
    if big_sparse["state_mb"] * 10 > big_dense["state_mb"]:
        print(f"[perf_client_store] REGRESSION: sparse state "
              f"{big_sparse['state_mb']} MB not ≥10× under dense "
              f"{big_dense['state_mb']} MB at n={big_dense['n_clients']:,d}",
              file=sys.stderr)
        ok = False

    ref = max(dense_run, key=lambda r: r["n_clients"])
    if big_sparse["rounds_per_sec"] < 0.5 * ref["rounds_per_sec"]:
        print(f"[perf_client_store] REGRESSION: sparse at "
              f"n={big_sparse['n_clients']:,d} runs "
              f"{big_sparse['rounds_per_sec']} rounds/s — more than 2× "
              f"slower than dense at n={ref['n_clients']:,d} "
              f"({ref['rounds_per_sec']} rounds/s)", file=sys.stderr)
        ok = False
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
