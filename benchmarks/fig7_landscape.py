"""Fig 7/8/9 — loss-landscape flatness of pre-trained vs random models.

Paper artifact: loss-landscape surfaces (Li et al. filter-normalized
projection) showing cyclic-pre-trained global models in flatter, lower
basins.  Quantified here (no plotting on this container) as:

  sharpness@α  : E_d[L(w + α·d) − L(w)] over random filter-normalized
                 directions (smaller = flatter),
  hessian_top  : top Hessian eigenvalue via HVP power iteration,
  slice        : 1-D filter-normalized loss slice (the numeric Fig 7).

Compared across three model states: random init, cyclic-pre-trained
(P1), and the final global models trained from each.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from benchmarks import common as C
from repro.core import diagnostics as diag
from repro.core.cyclic import cyclic_pretrain
from repro.fl.simulation import run_federated


def probe(task, data, params, key, tag):
    n = min(512, len(data.test_y))
    loss_fn = diag.make_batch_loss(task, data.test_x[:n], data.test_y[:n])
    sharp = diag.sharpness_probe(loss_fn, params, key, n_dirs=6,
                                 alphas=(0.1, 0.5, 1.0))
    eig = diag.hessian_top_eig(loss_fn, params, key, n_iter=10)
    row = {"state": tag, "base_loss": round(sharp["base_loss"], 4),
           "sharp@0.5": round(sharp["sharpness@0.5"], 4),
           "sharp@1.0": round(sharp["sharpness@1.0"], 4),
           "hessian_top": round(eig, 4)}
    print(f"[fig7] {tag:22s} loss={row['base_loss']:.4f} "
          f"sharp@1.0={row['sharp@1.0']:.4f} eig={row['hessian_top']:.4f}",
          flush=True)
    return row


def run(scale: C.Scale, beta: float = 0.5, seed: int = 0):
    task, data = C.make_vision_setup(scale, beta, seed=seed)
    key = jax.random.PRNGKey(seed + 100)
    rows = []

    w_rand = task.init(jax.random.PRNGKey(seed))
    rows.append(probe(task, data, w_rand, key, "random-init"))

    cyc = cyclic_pretrain(task, data, C.cyclic_cfg(scale, seed=seed))
    rows.append(probe(task, data, cyc.params, key, "cyclic-pretrained"))

    fed_rand = run_federated(task, data, C.fl_cfg(scale, "fedavg", seed=seed),
                             init_params=w_rand)
    rows.append(probe(task, data, fed_rand.params, key, "final-from-random"))

    fed_cyc = run_federated(task, data, C.fl_cfg(scale, "fedavg", seed=seed),
                            init_params=cyc.params)
    rows.append(probe(task, data, fed_cyc.params, key, "final-from-cyclic"))

    # numeric Fig-7 slice for both final models
    n = min(512, len(data.test_y))
    loss_fn_r = diag.make_batch_loss(task, data.test_x[:n], data.test_y[:n])
    slices = {}
    for tag, params in (("final-from-random", fed_rand.params),
                        ("final-from-cyclic", fed_cyc.params)):
        sl = diag.landscape_slice(loss_fn_r, params, key, n_points=9,
                                  radius=1.0)
        slices[tag] = {"alpha": np.round(sl["alpha"], 3).tolist(),
                       "loss": np.round(sl["loss"], 4).tolist()}
    return rows, slices


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scale", default="quick", choices=list(C.SCALES))
    ap.add_argument("--beta", type=float, default=0.5)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    scale = C.SCALES[args.scale]
    rows, slices = run(scale, beta=args.beta, seed=args.seed)
    print(C.fmt_table(rows, ["state", "base_loss", "sharp@0.5", "sharp@1.0",
                             "hessian_top"]))
    C.save_result(f"fig7_{args.scale}",
                  {"rows": rows, "slices": slices, "beta": args.beta})
    by = {r["state"]: r for r in rows}
    flatter = (by["final-from-cyclic"]["sharp@1.0"]
               <= by["final-from-random"]["sharp@1.0"])
    print(f"[fig7] final-from-cyclic flatter than final-from-random: {flatter}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
