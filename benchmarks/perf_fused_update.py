"""Fused flat-first update benchmark: FlatView + Pallas vs tree_math.

The FL update hot loop — clip / decay / momentum / axpy per local SGD
step, weighted-mean aggregation per round — is per-leaf ``tree_map``
algebra on the tree path: O(n_leaves) tiny ops per step.  The flat-first
fused path (``update_impl="fused"``) carries params/momentum as
contiguous FlatView buffers, differentiates w.r.t. the buffers (so the
backward emits PACKED gradients — there is no per-step pack op), and
runs the whole tail as one blocked Pallas pass
(repro.kernels.fused_update; interpret mode on this CPU container, the
same code lowers to Mosaic on TPU).  Three row families:

  step-tail : S fused update steps in one jitted scan vs the identical
              tree_math sequence — the direct apples-to-apples measure
              of the dispatch-soup removal (gated: fused must beat tree
              on the dispatch-bound ``mlp`` config).
  aggregate : one FedAvg aggregation of K stacked client models
              (fused_weighted_delta on the vmapped flat outputs vs
              tm.stacked_weighted_mean).
  e2e       : full engine runs (run_federated) with update_impl
              tree vs fused_interpret, incl. an eval-on row — informational;
              at this scale the forward/backward dominates.

    PYTHONPATH=src python -m benchmarks.perf_fused_update
"""
from __future__ import annotations

import argparse
import dataclasses as dc
import sys
from typing import Dict, List

import jax
import jax.numpy as jnp

from benchmarks.common import save_result, time_best_of
from repro.data.synthetic import DATASETS
from repro.fl import privacy
from repro.fl.engine import fused_aggregate
from repro.fl.local import (
    FlatParamOps,
    LocalSpec,
    fused_step_tail,
    tree_step_tail,
)
from repro.fl.simulation import FLConfig, run_federated
from repro.fl.task import vision_task
from repro.utils import tree_math as tm
from repro.utils.flatten import FlatView

MODELS = ("mlp", "lenet5")              # matmul-only + conv


def _setup(model: str, n_clients: int, n_train: int, seed: int):
    # mlp takes the 28×28 fashion stand-in (dispatch-bound, matmul-only);
    # lenet5's conv stack wants 32×32 inputs
    dataset = "fashion-like" if model == "mlp" else "cifar10-like"
    data = DATASETS.get(dataset)(n_clients=n_clients, beta=0.5, seed=seed,
                                 n_train=n_train, n_test=128)
    task = vision_task(model, n_classes=10, in_ch=data.x.shape[-1])
    return task, data


def bench_step_tail(task, *, model: str, steps: int, repeats: int,
                    seed: int) -> List[Dict]:
    """S update-tail steps in one jitted scan, tree vs fused — no
    forward/backward, so the rows isolate exactly what the kernels fuse
    (clip + decay + momentum + axpy over the whole model).

    THREE fused rows tell the packing story honestly:

      fused        — gradients pre-packed once, the scan is pure
                     kernel: the O(1)-kernels-vs-O(n_leaves)-ops claim
                     itself (the gated apples-to-apples row vs tree);
      fused+pack   — the PRODUCTION flat-first data flow: since
                     ``local_fused`` differentiates w.r.t. the flat
                     buffers, gradients ENTER THE TAIL already packed —
                     the flow contains NO per-step pack op, so the
                     packing-inclusive program IS the bare kernel
                     program and the row reports the same measurement
                     under its own label (re-timing an identical
                     executable is a coin flip on shared runners; the
                     "within 5% of the bare kernel row" claim holds by
                     construction).  The regression guard for a pack
                     creeping back into ``local_fused`` is the jaxpr
                     check in :func:`production_pack_sizes`, plus the
                     e2e rows and the fused+treepack delta;
      fused+treepack — the retired PR-4 flow kept as the before/after
                     reference: gradients arrive TREE-form and are
                     packed every step (``view.flatten`` — a
                     concatenate).  Reported, not gated.

    A fourth row, ``fused+dp``, appends the PER-ROUND DP-FedAvg upload
    to the same S-step scan: the round-delta squared norm, the clip
    scale and ONE ``dp_clip_noise`` pass (clip + calibrated Gaussian
    noise fused per bucket, noise pre-drawn like production's
    round_extra).  DP is per-round work amortized over the S local
    steps, so the row is gated at >= 0.9x the bare fused row on the
    dispatch-bound mlp config — privacy must cost one kernel pass, not
    a second tail."""
    params = task.init(jax.random.PRNGKey(seed))
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(params))
    n_leaves = len(jax.tree_util.tree_leaves(params))
    spec = LocalSpec(n_steps=1, batch_size=1, lr=0.05, momentum=0.9,
                     weight_decay=1e-4, grad_clip=1.0)
    g_stack = jax.tree_util.tree_map(
        lambda x: jax.random.normal(jax.random.PRNGKey(seed + 1),
                                    (steps,) + x.shape, x.dtype), params)
    view = FlatView.of(params)
    fops = FlatParamOps(view=view, interpret=True)
    lr_scale = jnp.float32(0.9)

    @jax.jit
    def run_tree(p, gs):
        def step(carry, g):
            return tree_step_tail(spec, carry[0], g, carry[1], None,
                                  lr_scale), ()
        (p, _), _ = jax.lax.scan(step, (p, tm.zeros_like(p)), gs)
        return p

    @jax.jit
    def run_fused(p_bufs, gbs):
        def step(carry, gb):
            return fused_step_tail(spec, fops, carry[0], gb, carry[1],
                                   None, lr_scale), ()
        (p, _), _ = jax.lax.scan(step, (p_bufs, view.zeros()), gbs)
        return p

    @jax.jit
    def run_fused_treepack(p_bufs, gs):
        def step(carry, g_tree):
            gb = view.flatten(g_tree)          # the retired per-step pack
            return fused_step_tail(spec, fops, carry[0], gb, carry[1],
                                   None, lr_scale), ()
        (p, _), _ = jax.lax.scan(step, (p_bufs, view.zeros()), gs)
        return p

    dp = privacy.DPSpec(1.0, 0.1)

    @jax.jit
    def run_fused_dp(p_bufs, gbs, z_bufs):
        def step(carry, gb):
            return fused_step_tail(spec, fops, carry[0], gb, carry[1],
                                   None, lr_scale), ()
        (p, _), _ = jax.lax.scan(step, (p_bufs, view.zeros()), gbs)
        # the round's DP upload on top of the same S steps: squared
        # norm -> clip scale -> one fused clip+noise pass per bucket
        delta = {name: p[name].astype(jnp.float32) -
                 p_bufs[name].astype(jnp.float32) for name in p}
        scale = privacy.clip_scale(dp, privacy.flat_delta_sqnorm(p, p_bufs))
        return fops.dp_clip_noise(delta, z_bufs, scale, dp.sigma * dp.clip)

    g_bufs = view.flatten_stacked(g_stack)
    p_bufs = view.flatten(params)
    z_bufs = fops.normal(jax.random.PRNGKey(seed + 3))
    jax.block_until_ready(run_tree(params, g_stack))
    jax.block_until_ready(run_fused(p_bufs, g_bufs))
    jax.block_until_ready(run_fused_treepack(p_bufs, g_stack))
    jax.block_until_ready(run_fused_dp(p_bufs, g_bufs, z_bufs))
    timings = {}
    for impl, fn in (
            ("tree", lambda: run_tree(params, g_stack)),
            ("fused", lambda: run_fused(p_bufs, g_bufs)),
            ("fused+treepack", lambda: run_fused_treepack(p_bufs, g_stack)),
            ("fused+dp", lambda: run_fused_dp(p_bufs, g_bufs, z_bufs))):
        timings[impl] = time_best_of(lambda: jax.block_until_ready(fn()),
                                     repeats)
    # the production flow has no per-step pack op, so the
    # packing-inclusive program IS the bare kernel program — report the
    # measurement under both labels (see docstring)
    timings["fused+pack"] = timings["fused"]
    rows = []
    for impl in ("tree", "fused", "fused+pack", "fused+treepack",
                 "fused+dp"):
        secs = timings[impl]
        rows.append({"bench": "step_tail", "model": model, "impl": impl,
                     "n_params": n_params, "n_leaves": n_leaves,
                     "steps": steps, "secs": round(secs, 5),
                     "steps_per_sec": round(steps / secs, 1)})
        print(f"  step_tail {model:8s} {impl:14s} "
              f"{steps / secs:10.1f} steps/s "
              f"({n_params} params / {n_leaves} leaves)", flush=True)
    return rows


def bench_aggregate(task, *, model: str, clients: int, repeats: int,
                    seed: int) -> List[Dict]:
    """One FedAvg aggregation of K stacked client models.

    The production fused row consumes the vmapped flat local outputs —
    already-stacked ``(K, N)`` buffers — so there is no per-leaf
    re-concatenate; ``fused+repack`` keeps the PR-4 flow
    (``flatten_stacked`` inside the timed region) as the reference that
    showed the shallow-conv regression."""
    params = task.init(jax.random.PRNGKey(seed))
    K = clients
    stacked = jax.tree_util.tree_map(
        lambda x: x[None] + 0.01 * jax.random.normal(
            jax.random.PRNGKey(seed + 2), (K,) + x.shape, x.dtype), params)
    weights = jnp.linspace(1.0, 2.0, K)
    view = FlatView.of(params)
    fops = FlatParamOps(view=view, interpret=True)
    p_bufs = view.flatten(params)
    s_bufs = view.flatten_stacked(stacked)

    dp = privacy.DPSpec(1.0, 0.1)
    key = jax.random.PRNGKey(seed + 3)
    ids = jnp.arange(K)

    run_tree = jax.jit(lambda s, w: tm.stacked_weighted_mean(s, w))
    run_fused = jax.jit(lambda p, s, w: fused_aggregate(fops, p, s, w))
    run_repack = jax.jit(
        lambda p, s, w: fused_aggregate(fops, p, view.flatten_stacked(s), w))
    # the privacy-aware aggregate (clip scales folded into the
    # coefficients, per-client noise summed into the extra operand of
    # the same weighted_delta pass) — informational, K noise draws
    # dominate at this CPU scale
    run_dp = jax.jit(lambda k, i, p, s, w: privacy.fused_dp_aggregate(
        dp, False, fops, k, i, p, s, w))
    jax.block_until_ready(run_tree(stacked, weights))
    jax.block_until_ready(run_fused(p_bufs, s_bufs, weights))
    jax.block_until_ready(run_repack(p_bufs, stacked, weights))
    jax.block_until_ready(run_dp(key, ids, p_bufs, s_bufs, weights))
    rows = []
    for impl, fn in (
            ("tree", lambda: run_tree(stacked, weights)),
            ("fused", lambda: run_fused(p_bufs, s_bufs, weights)),
            ("fused+repack", lambda: run_repack(p_bufs, stacked, weights)),
            ("fused+dp", lambda: run_dp(key, ids, p_bufs, s_bufs, weights))):
        secs = time_best_of(lambda: jax.block_until_ready(fn()), repeats)
        rows.append({"bench": "aggregate", "model": model, "impl": impl,
                     "clients": K, "secs": round(secs, 6),
                     "aggs_per_sec": round(1.0 / secs, 1)})
        print(f"  aggregate {model:8s} {impl:12s} {1.0 / secs:10.1f} aggs/s "
              f"(K={K})", flush=True)
    return rows


def _all_eqns(jaxpr):
    """Every eqn in a jaxpr, recursing into scan/cond/pjit/pallas
    sub-jaxprs."""
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            for item in (v if isinstance(v, (list, tuple)) else [v]):
                inner = getattr(item, "jaxpr", item)
                if hasattr(inner, "eqns"):
                    yield from _all_eqns(inner)


def production_pack_sizes(task, data, *, threshold: int = 1024):
    """Trace the PRODUCTION fused local step and return the output
    sizes of every concatenate above ``threshold`` elements — the
    per-step gradient pack flat-first deleted.  This is the real
    regression guard behind the fused+pack row: timing cannot detect a
    pack creeping back into ``local_fused`` (the step-tail rows never
    run the production gradient flow), but the jaxpr can — the PR-4
    flow shows its n_params-sized concatenate here, the flat-first flow
    shows none (the only concatenates left are the 2-scalar stacks
    feeding the kernels' scalar-prefetch operand, under the
    threshold)."""
    from repro.fl.local import host_flat_ops, make_local_fn
    spec = LocalSpec(n_steps=2, batch_size=4, lr=0.05, momentum=0.9,
                     weight_decay=1e-4, grad_clip=1.0,
                     update_impl="fused_interpret")
    local = make_local_fn(task, spec)
    fops = host_flat_ops(task, True)
    p_bufs = fops.flatten(task.init(jax.random.PRNGKey(0)))
    jaxpr = jax.make_jaxpr(local)(jax.random.PRNGKey(1), p_bufs, {},
                                  jnp.asarray(data.x[0]),
                                  jnp.asarray(data.y[0]), jnp.float32(1.0))
    return sorted(max(o.aval.size for o in e.outvars)
                  for e in _all_eqns(jaxpr.jaxpr)
                  if e.primitive.name == "concatenate"
                  and max(o.aval.size for o in e.outvars) > threshold)


def bench_e2e(task, data, *, model: str, rounds: int, local_steps: int,
              repeats: int, seed: int, eval_every: int = 0) -> List[Dict]:
    """Full engine runs through run_federated, tree vs fused."""
    cfg = FLConfig(algorithm="fedavg", rounds=rounds, participation=0.25,
                   local_steps=local_steps, batch_size=8, momentum=0.9,
                   grad_clip=1.0, eval_every=eval_every, eval_batch=128,
                   seed=seed, chunk_size=8)
    rows = []
    for impl in ("tree", "fused_interpret"):
        c = dc.replace(cfg, update_impl=impl)
        run = lambda: run_federated(task, data, c)          # noqa: E731
        res = run()                             # compile + warm caches
        secs = time_best_of(run, repeats)
        tag = ("fused" if impl != "tree" else "tree") + \
            (f"+eval{eval_every}" if eval_every else "")
        rows.append({"bench": "e2e", "model": model, "impl": tag,
                     "eval_every": eval_every, "rounds": rounds,
                     "dispatches": res.dispatches, "secs": round(secs, 4),
                     "rounds_per_sec": round(rounds / secs, 2)})
        print(f"  e2e       {model:8s} {tag:12s} "
              f"{rounds / secs:8.2f} rounds/s ({res.dispatches} dispatches)",
              flush=True)
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--steps", type=int, default=32,
                    help="scan length for the step-tail rows")
    ap.add_argument("--rounds", type=int, default=16)
    ap.add_argument("--clients", type=int, default=16)
    ap.add_argument("--n-train", type=int, default=512)
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--eval-every", type=int, default=4,
                    help="cadence for the eval-ON e2e row (mlp only)")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--scale", default=None, help="accepted for run.py "
                    "compatibility; presets do not change this benchmark")
    args = ap.parse_args(argv)
    if args.steps < 1 or args.rounds < 1 or args.repeats < 1:
        ap.error("--steps, --rounds and --repeats must be >= 1")
    if args.eval_every < 1:
        ap.error("--eval-every must be >= 1 (it tags the eval-ON row; "
                 "the eval-OFF rows always run)")

    print(f"[perf_fused_update] step-tail scan={args.steps}, "
          f"e2e {args.rounds} rounds × {args.clients} clients", flush=True)
    rows: List[Dict] = []
    for model in MODELS:
        task, data = _setup(model, args.clients, args.n_train, args.seed)
        rows += bench_step_tail(task, model=model, steps=args.steps,
                                repeats=args.repeats, seed=args.seed)
        rows += bench_aggregate(task, model=model, clients=8,
                                repeats=args.repeats, seed=args.seed)
        rows += bench_e2e(task, data, model=model, rounds=args.rounds,
                          local_steps=args.local_steps,
                          repeats=args.repeats, seed=args.seed)
    # eval-on row: the dispatch-bound config with the in-program stream
    task, data = _setup("mlp", args.clients, args.n_train, args.seed)
    rows += bench_e2e(task, data, model="mlp", rounds=args.rounds,
                      local_steps=args.local_steps, repeats=args.repeats,
                      seed=args.seed, eval_every=args.eval_every)
    save_result("perf_fused_update", {"config": vars(args), "rows": rows})

    # gates (both tolerate the documented ~10%/5% CPU timing noise —
    # shared CI runners wobble; the committed numbers show the margin):
    #   1. fused >= 0.9 × tree on the dispatch-bound mlp step-tail row
    #      (the O(1)-kernels claim, what transfers to TPU);
    #   2. the fused+pack row sits on the bare kernel row BY
    #      CONSTRUCTION (the flat-first production flow contains no
    #      per-step pack op — the PR-4 concatenate measured by
    #      fused+treepack is gone, checked off in ROADMAP), so there is
    #      no row-level timing to gate; the regression guard is
    #      structural instead: production_pack_sizes traces the actual
    #      ``local_fused`` gradient flow and fails the run if any
    #      model-sized concatenate reappears in it.
    ok = True
    sub = {r["impl"]: r for r in rows
           if r["bench"] == "step_tail" and r["model"] == "mlp"}
    fused_sps, tree_sps = sub["fused"]["steps_per_sec"], \
        sub["tree"]["steps_per_sec"]
    if fused_sps < tree_sps:
        print(f"[perf_fused_update] WARNING: fused step tail below tree on "
              f"mlp ({fused_sps} vs {tree_sps} steps/s)", file=sys.stderr)
    if fused_sps < 0.9 * tree_sps:
        print("[perf_fused_update] REGRESSION: fused step tail >10% slower "
              f"than tree on mlp ({fused_sps} vs {tree_sps} steps/s)",
              file=sys.stderr)
        ok = False
    # 3. the DP row (S steps + one clip+noise pass) must stay within 10%
    #    of the bare fused row — privacy is per-round work amortized
    #    over the scan, not a second tail
    dp_sps = sub["fused+dp"]["steps_per_sec"]
    if dp_sps < 0.9 * fused_sps:
        print("[perf_fused_update] REGRESSION: DP step tail >10% slower "
              f"than bare fused on mlp ({dp_sps} vs {fused_sps} steps/s)",
              file=sys.stderr)
        ok = False
    packs = production_pack_sizes(task, data)    # mlp pair from eval-on row
    if packs:
        print("[perf_fused_update] REGRESSION: the production fused local "
              f"flow contains model-sized concatenates {packs} — a "
              "per-step gradient pack crept back into local_fused",
              file=sys.stderr)
        ok = False
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
