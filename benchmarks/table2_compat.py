"""Table II — Cyclic+Y compatibility: accuracy improvement of adding
cyclic pre-training to each of the four FL algorithms (paper: CIFAR-10
β=0.5; here cifar10-like β=0.5).
"""
from __future__ import annotations

import argparse
import time

from benchmarks import common as C

ALGOS = ("fedavg", "fedprox", "moon", "scaffold")


def run(scale: C.Scale, beta: float = 0.5, seed: int = 0):
    task, data = C.make_vision_setup(scale, beta, seed=seed)
    rows = []
    for algo in ALGOS:
        cell = {"algorithm": algo}
        for cyclic in (False, True):
            t0 = time.time()
            res = C.run_method(task, data, scale, algorithm=algo,
                               cyclic=cyclic, seed=seed)
            s = C.summarize(res)
            key = "with_cyclic" if cyclic else "without_cyclic"
            cell[key] = s["best_acc"]
            print(f"[table2] {algo:9s} cyclic={cyclic} best={s['best_acc']:.4f}"
                  f" ({time.time() - t0:.0f}s)", flush=True)
        cell["delta"] = round(cell["with_cyclic"] - cell["without_cyclic"], 4)
        rows.append(cell)
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scale", default="quick", choices=list(C.SCALES))
    ap.add_argument("--beta", type=float, default=0.5)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    scale = C.SCALES[args.scale]
    rows = run(scale, beta=args.beta, seed=args.seed)
    print(C.fmt_table(rows, ["algorithm", "without_cyclic", "with_cyclic",
                             "delta"]))
    C.save_result(f"table2_{args.scale}", {"rows": rows, "beta": args.beta})
    improved = sum(1 for r in rows if r["delta"] > 0)
    print(f"[table2] cyclic improves {improved}/{len(rows)} algorithms")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
