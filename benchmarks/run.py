"""Benchmark orchestrator — one entry per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run                # quick preset
    PYTHONPATH=src python -m benchmarks.run --scale full
    PYTHONPATH=src python -m benchmarks.run --only table1,table4

Each sub-benchmark writes experiments/results/<name>_<scale>.json; the
roofline report additionally requires dry-run artifacts
(repro.launch.dryrun --all).
"""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks import (
    fig5_switch_point, fig7_landscape, perf_client_store, perf_compression,
    perf_fused_update, perf_peft, perf_pipeline, perf_pod_round,
    perf_round_engine, roofline_report, table1_accuracy, table2_compat,
    table3_convergence, table4_comm,
)

BENCHES = {
    "perf_engine": lambda scale: perf_round_engine.main(["--scale", scale]),
    "perf_pod": lambda scale: perf_pod_round.main(["--scale", scale]),
    "perf_fused": lambda scale: perf_fused_update.main(["--scale", scale]),
    "perf_store": lambda scale: perf_client_store.main(["--scale", scale]),
    "perf_pipeline": lambda scale: perf_pipeline.main(["--scale", scale]),
    "perf_compress": lambda scale: perf_compression.main(["--scale", scale]),
    "perf_peft": lambda scale: perf_peft.main(["--scale", scale]),
    "table1": lambda scale: table1_accuracy.main(["--scale", scale,
                                                  "--betas", "0.1,0.5"]),
    "table2": lambda scale: table2_compat.main(["--scale", scale]),
    "table3": lambda scale: table3_convergence.main(["--scale", scale]),
    "table4": lambda scale: table4_comm.main(["--scale", scale]),
    "fig5": lambda scale: fig5_switch_point.main(["--scale", scale]),
    "fig7": lambda scale: fig7_landscape.main(["--scale", scale]),
    "roofline": lambda scale: roofline_report.main([]),
}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scale", default="quick", choices=("quick", "full"))
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of: " + ",".join(BENCHES))
    args = ap.parse_args(argv)
    names = list(BENCHES) if not args.only else args.only.split(",")
    rc = 0
    for name in names:
        if name not in BENCHES:
            print(f"[run] unknown benchmark {name!r}", file=sys.stderr)
            return 2
        print(f"\n===== {name} (scale={args.scale}) =====", flush=True)
        t0 = time.time()
        try:
            r = BENCHES[name](args.scale)
            rc = rc or (r or 0)
        except Exception as e:  # noqa: BLE001 — keep the sweep alive
            print(f"[run] {name} FAILED: {type(e).__name__}: {e}",
                  file=sys.stderr)
            rc = 1
        print(f"[run] {name} done in {time.time() - t0:.0f}s", flush=True)
    return rc


if __name__ == "__main__":
    sys.exit(main())
