"""Pod round-dispatch benchmark: chunked engine vs per-round dispatch.

The pre-PR-2 pod driver dispatched ONE XLA program per federated round
and pre-sampled every batch on the host with NumPy, so at reduced scale
the host round-trip bounds throughput exactly like it did for the host
simulator.  The engine-backed pod path samples clients AND batches on
device and scans ``chunk_size`` rounds per dispatch with donated sharded
carries; this benchmark measures rounds/sec for

  per-round : the legacy loop (jit(make_pod_*_round) once per round,
              host-side sample_round_batches) — the seed pod driver,
  chunk=1   : the engine with one dispatch per round,
  chunk=8   : the engine with 8 rounds fused into one dispatch,

for both the P1 relay and the P2 fedavg round on a 1-device host mesh
(the same programs the real mesh runs — see tests/test_pod_engine.py for
the multi-device layout checks).  Each engine row also runs with the
in-program eval stream ON (eval_every=2) and records the dispatch
count, asserting that evaluation no longer degrades chunked dispatch to
per-round dispatch (pre-eval-stream, any eval_every pinned chunks to
the eval cadence; ``run_pod_training(eval_fn=...)`` pinned
eval_every=1).

    PYTHONPATH=src python -m benchmarks.perf_pod_round
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save_result, time_best_of
from repro.configs import get_reduced
from repro.data.synthetic import DATASETS
from repro.fl.engine import RoundSchedule, run_rounds
from repro.fl.pod import PodAggregateStrategy, PodFLSpec, PodRelayStrategy
from repro.fl.task import lm_task
from repro.launch.mesh import make_host_mesh
from repro.launch.train import (
    make_pod_cyclic_round,
    make_pod_fl_round,
    sample_round_batches,
)
from repro.sharding import rules

CHUNKS = (1, 8)


def _micro_cfg():
    # dispatch-bound on purpose: the benchmark isolates host round-trip
    # overhead, so per-round device compute is kept tiny
    base = get_reduced("tinyllama-1.1b")
    return dataclasses.replace(base, name="tinyllama-micro", d_model=64,
                               n_heads=2, n_kv_heads=2, head_dim=32,
                               d_ff=128)


def _setup(n_clients: int, seed: int):
    cfg = _micro_cfg()
    data = DATASETS.get("tokenlm-bigram")(
        n_clients=n_clients, seed=seed, seq_len=16, n_seq_per_client=16,
        vocab=cfg.vocab_size, n_test=32)
    return cfg, lm_task(cfg), data


def bench_legacy(cfg, data, mesh, *, kind: str, rounds: int, K: int,
                 spec: PodFLSpec, seed: int, repeats: int) -> Dict:
    """The seed pod loop: one jit dispatch + host batch sampling per
    round."""
    from repro.models.transformer import init_lm
    p_specs = jax.eval_shape(lambda k: init_lm(k, cfg), jax.random.PRNGKey(0))
    p_sh = rules.param_shardings(p_specs, mesh)
    if kind == "relay":
        round_j = jax.jit(make_pod_cyclic_round(cfg, spec),
                          in_shardings=(p_sh, None, None),
                          out_shardings=(p_sh, None))
    else:
        round_j = jax.jit(make_pod_fl_round(cfg, spec),
                          in_shardings=(p_sh, None, None, None),
                          out_shardings=(p_sh, None))

    def run():
        rng = np.random.default_rng(seed)
        params = init_lm(jax.random.PRNGKey(seed), cfg)
        for _ in range(rounds):
            ids = rng.choice(data.n_clients, size=K, replace=False)
            batches = sample_round_batches(data, ids, spec.local_steps,
                                           spec.batch_size, rng)
            if kind == "relay":
                params, m = round_j(params, batches, jnp.float32(1.0))
            else:
                weights = jnp.asarray(data.n_real[ids], jnp.float32)
                params, m = round_j(params, batches, weights,
                                    jnp.float32(1.0))
        jax.block_until_ready(m["local_loss"])

    run()                                       # compile + warm caches
    secs = time_best_of(run, repeats)
    return {"strategy": kind, "dispatch": "per-round", "rounds": rounds,
            "eval_every": 0, "dispatches": rounds,
            "secs": round(secs, 4),
            "rounds_per_sec": round(rounds / secs, 2)}


def bench_engine(task, data, mesh, *, kind: str, rounds: int, K: int,
                 spec: PodFLSpec, seed: int, repeats: int,
                 eval_every: int = 0) -> List[Dict]:
    rows = []
    if kind == "relay":
        strat = PodRelayStrategy(spec=spec.local_spec("plain"), mesh=mesh,
                                 clients_per_round=K)
    else:
        strat = PodAggregateStrategy(spec=spec.local_spec(),
                                     algorithm=spec.algorithm, mesh=mesh,
                                     clients_per_round=K)
    for chunk in CHUNKS:
        sched = RoundSchedule(rounds=rounds, lr_decay=1.0,
                              eval_every=eval_every, eval_batch=32,
                              seed=seed, chunk_size=chunk)
        run = lambda: run_rounds(task, data, strat, sched)   # noqa: E731
        res = run()                             # compile + warm caches
        secs = time_best_of(run, repeats)
        tag = f"chunk={chunk}" + (f"+eval{eval_every}" if eval_every else "")
        rows.append({"strategy": kind, "dispatch": tag,
                     "rounds": rounds, "eval_every": eval_every,
                     "dispatches": res.dispatches,
                     "secs": round(secs, 4),
                     "rounds_per_sec": round(rounds / secs, 2)})
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--rounds", type=int, default=24)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--clients-per-round", type=int, default=2)
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--eval-every", type=int, default=2,
                    help="cadence for the eval-ON engine rows")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--scale", default=None, help="accepted for run.py "
                    "compatibility; presets do not change this benchmark")
    args = ap.parse_args(argv)
    if args.rounds < 1 or args.repeats < 1:
        ap.error("--rounds and --repeats must be >= 1")
    if args.eval_every < 1:
        ap.error("--eval-every must be >= 1 (it tags the eval-ON rows; "
                 "the eval-OFF sweep always runs)")

    cfg, task, data = _setup(args.clients, args.seed)
    mesh = make_host_mesh()
    spec = PodFLSpec(local_steps=args.local_steps, batch_size=args.batch,
                     lr=0.01)
    print(f"[perf_pod_round] {args.rounds} rounds × {args.clients} clients "
          f"(K={args.clients_per_round}), local_steps={args.local_steps}",
          flush=True)
    rows: List[Dict] = []
    for kind in ("relay", "fedavg"):
        rows.append(bench_legacy(cfg, data, mesh, kind=kind,
                                 rounds=args.rounds,
                                 K=args.clients_per_round, spec=spec,
                                 seed=args.seed, repeats=args.repeats))
        rows += bench_engine(task, data, mesh, kind=kind, rounds=args.rounds,
                             K=args.clients_per_round, spec=spec,
                             seed=args.seed, repeats=args.repeats)
        rows += bench_engine(task, data, mesh, kind=kind, rounds=args.rounds,
                             K=args.clients_per_round, spec=spec,
                             seed=args.seed, repeats=args.repeats,
                             eval_every=args.eval_every)
        n_new = 1 + 2 * len(CHUNKS)
        base = rows[-n_new]["rounds_per_sec"]
        for r in rows[-n_new:]:
            r["speedup_vs_per_round"] = round(r["rounds_per_sec"] / base, 2)
            nd = r.get("dispatches", r["rounds"])
            print(f"  {r['strategy']:8s} {r['dispatch']:14s} "
                  f"{r['rounds_per_sec']:8.2f} rounds/s "
                  f"({r['secs']:.3f}s / {r['rounds']} rounds, "
                  f"{nd} dispatches)", flush=True)
    save_result("perf_pod_round", {"config": vars(args), "rows": rows})

    ok = True
    chunk = max(CHUNKS)
    want = -(-args.rounds // chunk)             # ceil(rounds / chunk)
    for kind in ("relay", "fedavg"):
        sub = {r["dispatch"]: r for r in rows if r["strategy"] == kind}
        # the chunked-vs-per-round margin at this micro scale is only a
        # few percent (see experiments/results/perf_pod_round.json), so
        # the throughput gate tolerates the documented ~10-15% CPU
        # timing noise; the DISPATCH-COUNT gate below is exact
        if sub[f"chunk={chunk}"]["rounds_per_sec"] < \
                0.9 * sub["per-round"]["rounds_per_sec"]:
            print(f"[perf_pod_round] REGRESSION: {kind} chunk={chunk} "
                  f">10% slower than per-round dispatch", file=sys.stderr)
            ok = False
        ev = sub[f"chunk={chunk}+eval{args.eval_every}"]
        if ev["dispatches"] != want:
            print(f"[perf_pod_round] REGRESSION: {kind} eval-on run took "
                  f"{ev['dispatches']} dispatches for {args.rounds} rounds "
                  f"(want {want}: evaluation must not split chunks)",
                  file=sys.stderr)
            ok = False
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
