"""Round-engine dispatch benchmark: rounds/sec vs chunk size.

The seed drivers dispatched ONE XLA program per round, so at simulation
scale the host round-trip (argument flattening, dispatch, result fetch,
Python bookkeeping) bounds throughput.  The engine scans ``chunk_size``
rounds per dispatch with donated carries; this benchmark measures the
resulting rounds/sec for both strategies at chunk ∈ {1, 4, 16} — chunk=1
IS the seed per-round dispatch path, so the speedup column reads as
"engine vs seed".  A second sweep runs with the in-program eval stream
ON (eval_every=4) and records dispatch counts, asserting evaluation
does not split chunks (pre-eval-stream, chunks broke at every eval
boundary).

    PYTHONPATH=src python -m benchmarks.perf_round_engine
"""
from __future__ import annotations

import argparse
import sys
from typing import Dict, List

from benchmarks.common import save_result, time_best_of
from repro.core.cyclic import CyclicConfig, cyclic_pretrain
from repro.data.synthetic import DATASETS
from repro.fl.simulation import FLConfig, run_federated
from repro.fl.task import vision_task

CHUNKS = (1, 4, 16)


def _setup(n_clients: int, n_train: int, seed: int):
    # dispatch-bound scale on purpose: the benchmark isolates host
    # round-trip overhead, so per-round device compute is kept tiny
    # (matmul-only MLP — conv cost would mask the dispatch effect)
    data = DATASETS.get("fashion-like")(n_clients=n_clients, beta=0.5,
                                        seed=seed, n_train=n_train,
                                        n_test=128)
    task = vision_task("mlp", n_classes=10, in_ch=data.x.shape[-1])
    return task, data


def bench_strategy(task, data, *, kind: str, rounds: int, local_steps: int,
                   seed: int, repeats: int,
                   eval_every: int = 0) -> List[Dict]:
    rows = []
    for chunk in CHUNKS:
        if kind == "relay":
            cfg = CyclicConfig(rounds=rounds, participation=0.25,
                               local_steps=local_steps, batch_size=8,
                               eval_every=eval_every, eval_batch=128,
                               seed=seed, chunk_size=chunk)
            run = lambda: cyclic_pretrain(task, data, cfg)        # noqa: E731
        else:
            cfg = FLConfig(algorithm=kind, rounds=rounds, participation=0.25,
                           local_steps=local_steps, batch_size=8,
                           eval_every=eval_every, eval_batch=128,
                           seed=seed, chunk_size=chunk)
            run = lambda: run_federated(task, data, cfg)          # noqa: E731
        res = run()                             # compile + warm caches
        secs = time_best_of(run, repeats)
        tag = f"{chunk}" + (f"+eval{eval_every}" if eval_every else "")
        rows.append({"strategy": kind, "chunk": chunk, "label": tag,
                     "eval_every": eval_every,
                     "dispatches": res.dispatches,
                     "rounds": rounds, "secs": round(secs, 4),
                     "rounds_per_sec": round(rounds / secs, 2)})
        print(f"  {kind:8s} chunk={tag:<10s} {rounds / secs:8.2f} rounds/s "
              f"({secs:.3f}s / {rounds} rounds, {res.dispatches} dispatches)",
              flush=True)
    base = rows[0]["rounds_per_sec"]
    for r in rows:
        r["speedup_vs_chunk1"] = round(r["rounds_per_sec"] / base, 2)
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--rounds", type=int, default=48)
    ap.add_argument("--clients", type=int, default=16)
    ap.add_argument("--n-train", type=int, default=512)
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--eval-every", type=int, default=4,
                    help="cadence for the eval-ON rows")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--scale", default=None, help="accepted for run.py "
                    "compatibility; presets do not change this benchmark")
    args = ap.parse_args(argv)
    if args.rounds < 1 or args.repeats < 1:
        ap.error("--rounds and --repeats must be >= 1")
    if args.eval_every < 1:
        ap.error("--eval-every must be >= 1 (it tags the eval-ON rows; "
                 "the eval-OFF sweep always runs)")

    task, data = _setup(args.clients, args.n_train, args.seed)
    print(f"[perf_round_engine] {args.rounds} rounds × {args.clients} clients,"
          f" local_steps={args.local_steps}", flush=True)
    rows = []
    for kind in ("relay", "fedavg"):
        rows += bench_strategy(task, data, kind=kind, rounds=args.rounds,
                               local_steps=args.local_steps, seed=args.seed,
                               repeats=args.repeats)
        rows += bench_strategy(task, data, kind=kind, rounds=args.rounds,
                               local_steps=args.local_steps, seed=args.seed,
                               repeats=args.repeats,
                               eval_every=args.eval_every)
    save_result("perf_round_engine", {
        "config": vars(args), "rows": rows})

    ok = True
    top = max(CHUNKS)
    for kind in ("relay", "fedavg"):
        sub = {r["label"]: r for r in rows if r["strategy"] == kind}
        if not sub[str(top)]["rounds_per_sec"] > sub["1"]["rounds_per_sec"]:
            print(f"[perf_round_engine] REGRESSION: {kind} chunk={top} "
                  f"not faster than chunk=1", file=sys.stderr)
            ok = False
        ev = sub[f"{top}+eval{args.eval_every}"]
        want = -(-args.rounds // top)           # ceil(rounds / chunk)
        if ev["dispatches"] != want:
            print(f"[perf_round_engine] REGRESSION: {kind} eval-on run took "
                  f"{ev['dispatches']} dispatches for {args.rounds} rounds "
                  f"(want {want}: evaluation must not split chunks)",
                  file=sys.stderr)
            ok = False
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
