"""Docs reference checker — keeps README/docs pointers from rotting.

Scans markdown files for backticked code references and verifies them
against the source tree:

  `src/repro/fl/engine.py`            file must exist
  `src/repro/fl/engine.py:run_rounds` file must exist AND define the
                                      symbol (def / class / assignment /
                                      dataclass field / Make target)

Only backticked spans that look like repo paths (contain a ``/`` or name
a known root file, with a recognised extension) are checked, so prose
code snippets (`lax.scan`, `eval_every=4`) are ignored.

    python tools/check_docs.py              # README.md + docs/*.md
    python tools/check_docs.py FILE [...]   # explicit files
"""
from __future__ import annotations

import ast
import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

# backticked `path` or `path:symbol` spans
_BACKTICK = re.compile(r"`([^`\n]+)`")
_REF = re.compile(
    r"^(?P<path>[\w./-]+\.(?:py|md|ini|txt|json|toml|cfg|sh))"
    r"(?::(?P<symbol>[A-Za-z_]\w*))?$")
_ROOT_FILES = ("Makefile", "pytest.ini", "requirements-dev.txt")


def extract_refs(text: str):
    """Yield (path, symbol-or-None) for every checkable backtick span."""
    for span in _BACKTICK.findall(text):
        if span in _ROOT_FILES:
            yield span, None
            continue
        m = _REF.match(span)
        if m and "/" in m.group("path"):
            yield m.group("path"), m.group("symbol")


def _py_definitions(tree: ast.Module) -> set:
    """Names actually DEFINED at module level or directly in a class
    body (functions, classes, assignments, annotated fields, methods) —
    not locals or keyword arguments, which a regex would false-match."""
    names: set = set()

    def collect(body, top: bool):
        for node in body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                names.add(node.name)
                if isinstance(node, ast.ClassDef) and top:
                    collect(node.body, False)
            elif isinstance(node, ast.Assign):
                names.update(t.id for t in node.targets
                             if isinstance(t, ast.Name))
            elif isinstance(node, ast.AnnAssign) and \
                    isinstance(node.target, ast.Name):
                names.add(node.target.id)

    collect(tree.body, True)
    return names


def symbol_defined(target: Path, symbol: str) -> bool:
    text = target.read_text()
    if target.suffix == ".py":
        try:
            return symbol in _py_definitions(ast.parse(text))
        except SyntaxError:
            pass
    # non-Python targets: a line-leading `symbol =` / `symbol:`
    # (Makefile targets, config keys)
    return bool(re.search(rf"^\s*{re.escape(symbol)}\s*[:=]", text, re.M))


def check_file(md: Path) -> list:
    errors = []
    name = str(md.relative_to(ROOT) if md.is_relative_to(ROOT) else md)
    for path, symbol in extract_refs(md.read_text()):
        target = ROOT / path
        if not target.is_file():
            errors.append(f"{name}: `{path}` does not exist")
            continue
        if symbol is not None and not symbol_defined(target, symbol):
            errors.append(f"{name}: `{path}:{symbol}` — "
                          f"symbol not found in {path}")
    return errors


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    if argv:
        files = [Path(a).resolve() for a in argv]
    else:
        files = [ROOT / "README.md"] + sorted((ROOT / "docs").glob("*.md"))
    missing = [f for f in files if not f.is_file()]
    if missing:
        for f in missing:
            print(f"[docs-check] missing doc file: {f}", file=sys.stderr)
        return 1
    errors = []
    n_refs = 0
    for f in files:
        n_refs += sum(1 for _ in extract_refs(f.read_text()))
        errors += check_file(f)
    for e in errors:
        print(f"[docs-check] {e}", file=sys.stderr)
    print(f"[docs-check] {len(files)} files, {n_refs} refs, "
          f"{len(errors)} broken")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
