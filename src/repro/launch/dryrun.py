import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture × input shape) pair, lower + compile the
production step program against ``ShapeDtypeStruct`` stand-ins (zero
allocation) on the 16×16 single-pod mesh and the 2×16×16 multi-pod mesh,
then extract the three roofline terms from the compiled artifact.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-1.5b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all                 # single-pod, all pairs
    PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod     # pod-axis pass

Results are appended as JSON to ``experiments/dryrun/<tag>.json`` so the
roofline table in EXPERIMENTS.md §Roofline is reproducible.

NOTE the XLA_FLAGS line above MUST run before any jax import — jax locks
the device count on first init.  Do not import this module from tests.
"""
import argparse
import json
import pathlib
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import (
    ASSIGNED, SHAPES, batch_specs, get_config, list_archs, param_count,
    active_param_count, params_specs, shape_applicable,
)
from repro.launch import mesh as meshlib
from repro.launch.hlo_analysis import analyze_compiled
from repro.launch.steps import (
    TrainSpec, make_prefill_step, make_serve_step, make_train_step,
    momentum_specs,
)
from repro.sharding import rules

OUT_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def model_flops_estimate(cfg, shape) -> float:
    """MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE); decode D = B·1."""
    n = active_param_count(cfg) if cfg.is_moe else param_count(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def build_lowered(cfg, shape, mesh, *, n_micro: int = 1,
                  layout: str = "fsdp_tp"):
    """Lower the step program for (cfg, shape) on ``mesh``.  Returns
    (lowered, meta) — no compilation yet."""
    p_specs = params_specs(cfg)
    p_sh = rules.param_shardings(p_specs, mesh, layout)
    b_specs = batch_specs(cfg, shape)

    if shape.kind == "train":
        m_specs = momentum_specs(p_specs, dtype=jnp.float32)
        m_sh = rules.param_shardings(m_specs, mesh, layout)
        b_sh = rules.batch_shardings(b_specs, mesh, layout)
        step = make_train_step(cfg, TrainSpec(n_micro=n_micro))
        jitted = jax.jit(step, in_shardings=(p_sh, m_sh, b_sh),
                         out_shardings=(p_sh, m_sh, None))
        lowered = jitted.lower(p_specs, m_specs, b_specs)
    elif shape.kind == "prefill":
        b_sh = rules.batch_shardings(b_specs, mesh)
        step = make_prefill_step(cfg, max_len=shape.seq_len)
        # cache output layout: same generic rule the decode inputs use
        from repro.models.transformer import init_decode_cache
        cache_spec = jax.eval_shape(
            lambda: init_decode_cache(cfg, shape.global_batch, shape.seq_len))
        c_sh = rules.cache_shardings(cache_spec, mesh, shape.global_batch)
        jitted = jax.jit(step, in_shardings=(p_sh, b_sh),
                         out_shardings=(None, c_sh))
        lowered = jitted.lower(p_specs, b_specs)
    else:  # decode
        tok, cache, clen = b_specs["token"], b_specs["cache"], b_specs["cache_len"]
        t_sh = rules.batch_shardings(tok, mesh)
        c_sh = rules.cache_shardings(cache, mesh, shape.global_batch)
        step = make_serve_step(cfg)
        jitted = jax.jit(step, in_shardings=(p_sh, t_sh, c_sh, None),
                         out_shardings=(None, c_sh))
        lowered = jitted.lower(p_specs, tok, cache, clen)
    return lowered


def _layer_variant(cfg, n_scan_layers: int):
    """Same config with ``n_scan_layers`` scanned layers, fully unrolled —
    XLA cost_analysis counts a while body ONCE regardless of trip count
    (verified empirically), so scanned stacks undercount FLOPs/bytes/
    collectives by ~L.  Costs are linear in the scanned-layer count, so
    two tiny unrolled compiles (1 and 2 layers) give exact per-layer cost
    by differencing; run_pair extrapolates to the real depth."""
    import dataclasses as _dc
    return _dc.replace(cfg, n_layers=cfg.n_dense_layers + n_scan_layers,
                       scan_unroll=True)


def _scan_cost_correction(cfg, shape, mesh, n_chips, *, n_micro=1,
                          layout="fsdp_tp"):
    """Return (flops, bytes, collective_bytes) corrected for the layer-scan
    undercount via 1-layer/2-layer unrolled extrapolation."""
    costs = []
    for n in (1, 2):
        lowered = build_lowered(_layer_variant(cfg, n), shape, mesh,
                                n_micro=n_micro, layout=layout)
        compiled = lowered.compile()
        t = analyze_compiled(compiled, arch=cfg.name, shape=shape.name,
                             mesh_name="corr", n_chips=n_chips)
        costs.append((t.hlo_flops, t.hlo_bytes, t.collective_bytes))
    (f1, b1, c1), (f2, b2, c2) = costs
    L = cfg.n_layers - cfg.n_dense_layers
    return (f1 + (L - 1) * (f2 - f1),
            b1 + (L - 1) * (b2 - b1),
            int(c1 + (L - 1) * (c2 - c1)))


def run_pair(arch: str, shape_name: str, *, multi_pod: bool = False,
             n_micro: int = 1, verbose: bool = True, save: bool = True,
             cfg_override=None, correct_scan: bool = True,
             layout: str = "fsdp_tp", tag: str = "") -> dict:
    shape = SHAPES[shape_name]
    cfg = cfg_override or get_config(arch)
    mesh = meshlib.make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    n_chips = 512 if multi_pod else 256
    t0 = time.time()
    row = {"arch": arch, "shape": shape_name, "mesh": mesh_name, "ok": False,
           "layout": layout}
    try:
        with mesh:
            lowered = build_lowered(cfg, shape, mesh, n_micro=n_micro,
                                    layout=layout)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        terms = analyze_compiled(
            compiled, arch=arch, shape=shape_name, mesh_name=mesh_name,
            n_chips=n_chips,
            model_flops=model_flops_estimate(cfg, shape))
        if correct_scan and cfg.n_layers > cfg.n_dense_layers + 1:
            raw = (terms.hlo_flops, terms.hlo_bytes, terms.collective_bytes)
            with mesh:
                fc, bc, cc = _scan_cost_correction(cfg, shape, mesh, n_chips,
                                                   n_micro=n_micro,
                                                   layout=layout)
            # keep whichever is LARGER per term: the full compile already
            # counts non-layer cost exactly and the extrapolation can only
            # add layer-body repetitions it missed
            terms.hlo_flops = max(terms.hlo_flops, fc)
            terms.hlo_bytes = max(terms.hlo_bytes, bc)
            terms.collective_bytes = max(terms.collective_bytes, cc)
            row["raw_uncorrected"] = {
                "hlo_flops": raw[0], "hlo_bytes": raw[1],
                "collective_bytes": raw[2]}
        row.update(terms.to_dict())
        row.update(ok=True, t_lower_s=round(t_lower, 1),
                   t_compile_s=round(t_compile, 1))
        if verbose:
            print(f"[dryrun] {arch:22s} {shape_name:12s} {mesh_name:8s} OK  "
                  f"flops={terms.hlo_flops:.3e} coll={terms.collective_bytes:.3e}B "
                  f"bottleneck={terms.bottleneck} "
                  f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)", flush=True)
    except Exception as e:  # noqa: BLE001 — report, don't crash the sweep
        row["error"] = f"{type(e).__name__}: {e}"
        if verbose:
            print(f"[dryrun] {arch:22s} {shape_name:12s} {mesh_name:8s} FAIL "
                  f"{row['error'][:200]}", flush=True)
            traceback.print_exc()
    if save:
        OUT_DIR.mkdir(parents=True, exist_ok=True)
        stem = f"{arch}_{shape_name}_{mesh_name}".replace("/", "-")
        if tag:
            stem += f"_{tag}"
        (OUT_DIR / f"{stem}.json").write_text(json.dumps(row, indent=1))
    return row


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, help="architecture id (see --list)")
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--all", action="store_true",
                    help="sweep every applicable (arch × shape) pair")
    ap.add_argument("--multi-pod", action="store_true",
                    help="use the 2×16×16 pod-axis mesh (512 chips)")
    ap.add_argument("--n-micro", type=int, default=1,
                    help="gradient-accumulation microbatches for train shapes")
    ap.add_argument("--layout", default="fsdp_tp",
                    choices=("fsdp_tp", "fsdp_only"),
                    help="parameter/batch layout (EXPERIMENTS.md §Perf)")
    ap.add_argument("--no-correct-scan", action="store_true",
                    help="skip the 1/2-layer unrolled cost extrapolation")
    ap.add_argument("--tag", default="", help="artifact filename suffix")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args(argv)

    if args.list:
        for a in list_archs():
            print(a)
        return 0

    pairs = []
    if args.all:
        for arch in ASSIGNED:
            for s in SHAPES:
                if shape_applicable(arch, s):
                    pairs.append((arch, s))
    else:
        if not args.arch or not args.shape:
            ap.error("need --arch and --shape (or --all)")
        pairs = [(args.arch, args.shape)]

    failures = 0
    for arch, s in pairs:
        row = run_pair(arch, s, multi_pod=args.multi_pod,
                       n_micro=args.n_micro, layout=args.layout,
                       correct_scan=not args.no_correct_scan, tag=args.tag)
        failures += 0 if row["ok"] else 1
    print(f"[dryrun] {len(pairs) - failures}/{len(pairs)} pairs OK")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
