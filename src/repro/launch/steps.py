"""Jit-able production step functions: train / prefill / serve(decode).

These are the programs the multi-pod dry-run lowers and the roofline
analyses — one per assigned input-shape kind:

  train_step   : one SGD(+momentum) step on a global batch, with
                 microbatch gradient accumulation streamed directly into
                 the momentum buffer (no separate f32 accumulator — the
                 update  m ← β·m + Σᵢ gᵢ/n  starts the scan carry at β·m,
                 saving a full parameter-sized buffer; matters at 671B).
  prefill_step : full-sequence forward building the decode cache.
  serve_step   : ONE new token against a seq_len-sized KV/SSM cache.

SGD+momentum is the paper's optimizer family (CyclicFL trains with SGD);
AdamW is available in repro.optim for ablations but quadruples optimizer
memory at 671B scale.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.transformer import (
    TransformerConfig, init_decode_cache, decode_step, lm_loss, prefill,
)

Pytree = Any


@dataclasses.dataclass(frozen=True)
class TrainSpec:
    lr: float = 1e-3
    momentum: float = 0.9
    weight_decay: float = 0.0
    n_micro: int = 1              # microbatch accumulation factor


def _split_micro(batch: Pytree, n_micro: int) -> Pytree:
    """(B, ...) -> (n_micro, B/n_micro, ...) taking strided rows so each
    data shard contributes equally to every microbatch (no resharding)."""

    def split(x):
        B = x.shape[0]
        assert B % n_micro == 0, (B, n_micro)
        return x.reshape((B // n_micro, n_micro) + x.shape[1:]).swapaxes(0, 1)

    return jax.tree_util.tree_map(split, batch)


def make_train_step(cfg: TransformerConfig, spec: TrainSpec) -> Callable:
    """(params, mom, batch) -> (params, mom, metrics)."""

    def loss_fn(params, mb):
        loss, metrics = lm_loss(params, cfg, mb)
        return loss, metrics

    def train_step(params, mom, batch):
        if spec.n_micro == 1:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch)
            new_mom = jax.tree_util.tree_map(
                lambda m, g: spec.momentum * m + g.astype(m.dtype), mom, grads)
        else:
            micro = _split_micro(batch, spec.n_micro)

            def acc(carry, mb):
                (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb)
                carry = jax.tree_util.tree_map(
                    lambda c, g: c + g.astype(c.dtype) / spec.n_micro,
                    carry, grads)
                return carry, loss

            mom0 = jax.tree_util.tree_map(lambda m: spec.momentum * m, mom)
            new_mom, losses = jax.lax.scan(acc, mom0, micro)
            loss = jnp.mean(losses)
            metrics = {"loss": loss}
        if spec.weight_decay:
            new_mom = jax.tree_util.tree_map(
                lambda m, p: m + spec.weight_decay * p.astype(m.dtype),
                new_mom, params)
        params = jax.tree_util.tree_map(
            lambda p, m: (p - spec.lr * m).astype(p.dtype), params, new_mom)
        return params, new_mom, {"loss": metrics["loss"]}

    return train_step


def make_prefill_step(cfg: TransformerConfig, max_len: int) -> Callable:
    """(params, batch) -> (last-token logits, decode cache)."""

    def prefill_step(params, batch):
        logits, cache, _ = prefill(params, cfg, batch, max_len=max_len)
        return logits, cache

    return prefill_step


def make_serve_step(cfg: TransformerConfig) -> Callable:
    """(params, token, cache, cache_len) -> (logits, cache) — ONE token."""

    def serve_step(params, token, cache, cache_len):
        return decode_step(params, cfg, token, cache, cache_len)

    return serve_step


def init_momentum(params: Pytree, dtype=jnp.float32) -> Pytree:
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, dtype) if jnp.issubdtype(p.dtype, jnp.floating)
        else jnp.zeros_like(p), params)


def momentum_specs(params_spec: Pytree, dtype=jnp.float32) -> Pytree:
    return jax.tree_util.tree_map(
        lambda p: jax.ShapeDtypeStruct(
            p.shape, dtype if jnp.issubdtype(p.dtype, jnp.floating) else p.dtype),
        params_spec)
