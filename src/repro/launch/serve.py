"""Batched serving driver: continuous prefill + greedy decode.

The inference-side counterpart of launch/train.py — serves a (reduced or
full) assigned architecture with batched requests:

  1. ``prefill``  : full-prompt forward building the KV/SSM cache
                    (the ``prefill_32k`` shape's program);
  2. ``decode``   : one token per step against the cache
                    (the ``decode_32k`` / ``long_500k`` program),
                    jitted once and reused across steps and requests.

On a pod both programs lower with the same sharding rules the dry-run
exercises (cache sharded batch×model, params FSDP×TP).  On CPU this CLI
greedy-decodes from a reduced config so the serving path is runnable
end-to-end:

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b \
        --batch 4 --prompt-len 32 --new-tokens 16
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import (
    TransformerConfig, decode_step, init_decode_cache, init_lm, prefill,
)

Pytree = Any


@dataclasses.dataclass
class ServeStats:
    prefill_s: float
    decode_s: float
    tokens_out: int

    @property
    def tok_per_s(self) -> float:
        return self.tokens_out / self.decode_s if self.decode_s else 0.0


class Engine:
    """Minimal batched-serving engine over one model instance.

    Jit-compiles prefill once per (B, S_prompt) and decode once per B;
    decode is a single fused program reused every step.
    """

    def __init__(self, cfg: TransformerConfig, params: Optional[Pytree] = None,
                 seed: int = 0):
        self.cfg = cfg
        self.params = params if params is not None else init_lm(
            jax.random.PRNGKey(seed), cfg)
        self._decode_fn = jax.jit(
            lambda p, t, c, n: decode_step(p, cfg, t, c, n))
        self._prefill_fn: Dict[tuple, Callable] = {}

    def _get_prefill(self, max_len: int) -> Callable:
        fn = self._prefill_fn.get(max_len)
        if fn is None:
            fn = jax.jit(lambda p, b: prefill(p, self.cfg, b, max_len=max_len))
            self._prefill_fn[max_len] = fn
        return fn

    def generate(self, batch: Dict[str, jnp.ndarray], new_tokens: int,
                 greedy: bool = True, key: Optional[jax.Array] = None):
        """Greedy (or sampled) continuation.  Returns (tokens, stats)."""
        cfg = self.cfg
        if cfg.input_mode == "tokens":
            prompt_len = batch["tokens"].shape[1]
        elif cfg.input_mode == "vlm":
            prompt_len = cfg.n_prefix_tokens + batch["tokens"].shape[1]
        else:
            prompt_len = batch["frame_embeds"].shape[1]
        max_len = prompt_len + new_tokens

        t0 = time.time()
        logits, cache, plen = self._get_prefill(max_len)(self.params, batch)
        logits.block_until_ready()
        t_prefill = time.time() - t0

        outs = []
        t0 = time.time()

        def pick(lg):
            """logits -> next ids: (B, 1) or (B, 1, n_codebooks) for audio."""
            last = lg[:, -1]
            if greedy or key is None:
                ids = jnp.argmax(last, axis=-1)
            else:
                ids = jax.random.categorical(jax.random.fold_in(key, len(outs)),
                                             last)
            return ids[:, None]

        def feed(ids):
            """ids -> the decode-step input the model consumes."""
            if cfg.input_mode != "embeddings":
                return ids
            # audio decoder consumes frame embeddings; feed tokens back via
            # a one-hot stand-in for the (stubbed) codec embedding, averaged
            # over codebooks (MusicGen sums its codebook embeddings).
            oh = jax.nn.one_hot(ids % cfg.d_model, cfg.d_model, dtype=cfg.dtype)
            if cfg.n_codebooks > 1:
                oh = jnp.mean(oh, axis=2)
            return oh.reshape(ids.shape[0], 1, cfg.d_model)

        nxt = pick(logits)
        cache_len = jnp.int32(prompt_len)
        for i in range(new_tokens):
            outs.append(nxt)
            logits, cache = self._decode_fn(self.params, feed(nxt), cache,
                                            cache_len + i)
            nxt = pick(logits)
        jax.block_until_ready(nxt)
        t_decode = time.time() - t0
        tokens = jnp.concatenate(outs, axis=1)
        stats = ServeStats(prefill_s=t_prefill, decode_s=t_decode,
                           tokens_out=int(tokens.size))
        return tokens, stats


def main(argv=None) -> int:
    from repro.configs import get_reduced

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--sample", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_reduced(args.arch)
    eng = Engine(cfg, seed=args.seed)
    key = jax.random.PRNGKey(args.seed)
    B, S = args.batch, args.prompt_len
    if cfg.input_mode == "tokens":
        batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    elif cfg.input_mode == "vlm":
        batch = {
            "patch_embeds": jax.random.normal(
                key, (B, cfg.n_prefix_tokens, cfg.d_model), cfg.dtype),
            "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
        }
    else:
        batch = {"frame_embeds": jax.random.normal(key, (B, S, cfg.d_model),
                                                   cfg.dtype)}
    toks, stats = eng.generate(batch, args.new_tokens,
                               greedy=not args.sample,
                               key=key if args.sample else None)
    print(f"[serve] {args.arch}: batch={B} prompt={S} new={args.new_tokens}  "
          f"prefill {stats.prefill_s * 1e3:.0f}ms  "
          f"decode {stats.tok_per_s:.1f} tok/s")
    print(f"[serve] first sequence: {np.asarray(toks[0])[:16].tolist()}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
