"""Pod-scale federated training driver — CyclicFL as a first-class
distributed feature.

This is the production mapping of the paper's two phases onto a TPU mesh
(DESIGN.md §3).  Clients are *simulated mesh tenants*: every client's
local batch is sharded over the ``data`` (and ``pod``) axis and the model
over ``model`` (FSDP × TP via repro.sharding.rules), so ONE XLA program
runs a whole federated round:

  P1 (cyclic relay)   : ``lax.scan`` over the K selected clients carrying
                        the model — the strict sequential schedule of
                        Algorithm 1.  No aggregation — the model hops
                        client→client exactly like the paper's
                        server-relayed download/upload, except the "hop"
                        is free on-chip.
  P2 (federated round): the same scan, but each client starts from the
                        round's global params and emits a weighted delta;
                        aggregation is the running weighted delta sum —
                        the computation that IS the FedAvg all-reduce.
                        fedavg / fedprox / scaffold / moon, with
                        per-client state sharded over the mesh ``data``
                        axis (repro.fl.pod.ShardedClientStateStore).

Since PR 2 the driver is a thin schedule over the shared round engine:
``run_pod_training`` builds ``PodCyclicConfig``/``PodFLConfig`` phases
and hands them to ``core.pipeline.run_phase_schedule``, so the sharded
path gets on-device client sampling, in-program key derivation, chunked
``chunk_size``-rounds-per-dispatch scans with donated sharded carries,
lr schedules and switch policies — identical to the host simulator.
The pre-sampled per-round bodies (``make_pod_cyclic_round`` /
``make_pod_fl_round``) are kept for AOT lowering (dry-run HLO analysis)
and as the per-round-dispatch baseline in benchmarks/perf_pod_round.py.

CLI (CPU, reduced configs):
    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --rounds 4 --cyclic-rounds 2 --clients 8
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pipeline import Phase, run_phase_schedule
from repro.fl.compression import CompressionSpec
from repro.fl.pod import (
    POD_ALGORITHMS,
    PodCyclicConfig,
    PodFLConfig,
    PodFLSpec,
)
from repro.fl.privacy import DPSpec
from repro.fl.task import lm_task
from repro.models.transformer import TransformerConfig, init_lm, lm_loss
from repro.sharding import rules
from repro.sharding.rules import fl_batch_pspec, fl_batch_shardings  # noqa: F401  (re-export)
from repro.utils import tree_math as tm

Pytree = Any


def _local_sgd(cfg: TransformerConfig, spec: PodFLSpec):
    """t_i SGD steps on one client's pre-sampled batches.

    (params, batches, lr_scale, w_anchor) -> (params, mean_loss)
    batches leaves: (t_i, B, S); w_anchor is the fedprox anchor (the
    round's global params) or None.  Kept for the AOT-lowered round
    bodies; the engine path runs the same math through
    ``repro.fl.local.make_local_fn`` with on-device batch sampling.
    """

    def loss_fn(params, mb, anchor):
        loss, _ = lm_loss(params, cfg, mb)
        if spec.algorithm == "fedprox" and anchor is not None:
            loss = loss + 0.5 * spec.mu * tm.squared_norm(
                jax.tree_util.tree_map(
                    lambda p, a: (p - a).astype(jnp.float32), params, anchor))
        return loss

    def run(params, batches, lr_scale, anchor):
        mom0 = tm.zeros_like(params) if spec.momentum else ()

        def step(carry, mb):
            w, mom = carry
            loss, grads = jax.value_and_grad(loss_fn)(w, mb, anchor)
            # clip the RAW gradient, then decay — same order as
            # repro.fl.local (parity-tested in tests/test_pod_engine.py)
            if spec.grad_clip:
                grads = tm.global_clip(grads, spec.grad_clip)
            if spec.weight_decay:
                grads = tm.add_scaled(grads, w, spec.weight_decay)
            if spec.momentum:
                mom = tm.add_scaled(grads, mom, spec.momentum)
                eff = mom
            else:
                eff = grads
            w = jax.tree_util.tree_map(
                lambda p, g: (p - spec.lr * lr_scale * g).astype(p.dtype),
                w, eff)
            return (w, mom), loss

        (params, _), losses = jax.lax.scan(step, (params, mom0), batches)
        return params, jnp.mean(losses)

    return run


def make_pod_cyclic_round(cfg: TransformerConfig, spec: PodFLSpec) -> Callable:
    """P1: sequential relay over K clients (Algorithm 1, one round).

    (params, batches, lr_scale) -> (params, metrics)
    batches leaves: (K, t_i, B, S) — client-major.  The scan carry is the
    relayed model; there is deliberately NO aggregation.
    """
    local = _local_sgd(cfg, spec)

    def round_fn(params, batches, lr_scale):
        def relay(w, client_batches):
            w, loss = local(w, client_batches, lr_scale, None)
            return w, loss

        params, losses = jax.lax.scan(relay, params, batches)
        return params, {"local_loss": jnp.mean(losses)}

    return round_fn


def make_pod_fl_round(cfg: TransformerConfig, spec: PodFLSpec) -> Callable:
    """P2: one federated round = local runs + weighted-delta aggregation.

    (params, batches, weights, lr_scale) -> (params, metrics)
    batches leaves: (K, t_i, B, S); weights: (K,) client sample counts N_i.

    Clients run sequentially (scan) — at LLM scale a full per-client
    parameter copy per vmap lane is exactly what does NOT fit, so the
    production schedule trades wall-clock serialization for memory:
    peak = 2×params (+momentum), independent of K.  The weighted delta
    accumulator is the FedAvg aggregation; on the mesh its reduction is
    the all-reduce the paper's server performs.
    """
    local = _local_sgd(cfg, spec)

    def round_fn(params, batches, weights, lr_scale):
        wsum = jnp.sum(weights)

        def one_client(acc, inp):
            client_batches, w_i = inp
            anchor = params if spec.algorithm == "fedprox" else None
            w_end, loss = local(params, client_batches, lr_scale, anchor)
            acc = jax.tree_util.tree_map(
                lambda a, we, p: a + (w_i / wsum) * (we - p).astype(a.dtype),
                acc, w_end, params)
            return acc, loss

        delta0 = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        delta, losses = jax.lax.scan(one_client, delta0,
                                     (batches, weights.astype(jnp.float32)))
        new_params = jax.tree_util.tree_map(
            lambda p, d: (p + d.astype(jnp.float32)).astype(p.dtype),
            params, delta)
        return new_params, {"local_loss": jnp.mean(losses)}

    return round_fn


def lower_pod_round(cfg: TransformerConfig, mesh, *, kind: str = "fl",
                    spec: Optional[PodFLSpec] = None, K: int = 8,
                    batch: int = 32, seq: int = 512):
    """AOT-lower a pod federated/cyclic round on ``mesh`` (dry-run path)."""
    spec = spec or PodFLSpec()
    p_specs = jax.eval_shape(lambda k: init_lm(k, cfg), jax.random.PRNGKey(0))
    p_sh = rules.param_shardings(p_specs, mesh)
    b_specs = {
        "tokens": jax.ShapeDtypeStruct((K, spec.local_steps, batch, seq), jnp.int32),
        "labels": jax.ShapeDtypeStruct((K, spec.local_steps, batch, seq), jnp.int32),
    }
    b_sh = fl_batch_shardings(b_specs, mesh)
    w_specs = jax.ShapeDtypeStruct((K,), jnp.float32)
    lr_specs = jax.ShapeDtypeStruct((), jnp.float32)

    with mesh:
        if kind == "cyclic":
            step = make_pod_cyclic_round(cfg, spec)
            jitted = jax.jit(step, in_shardings=(p_sh, b_sh, None),
                             out_shardings=(p_sh, None))
            return jitted.lower(p_specs, b_specs, lr_specs)
        step = make_pod_fl_round(cfg, spec)
        jitted = jax.jit(step, in_shardings=(p_sh, b_sh, None, None),
                         out_shardings=(p_sh, None))
        return jitted.lower(p_specs, b_specs, w_specs, lr_specs)


# ---------------------------------------------------------------------------
# end-to-end driver: the engine's phase schedule on the pod backend
# ---------------------------------------------------------------------------

def sample_round_batches(data, ids: np.ndarray, steps: int, batch: int,
                         rng: np.random.Generator) -> Dict[str, jnp.ndarray]:
    """Pre-sample (K, steps, batch, S) token/label batches for ``ids``
    (the per-round-dispatch baseline; the engine samples on device)."""
    toks, labs = [], []
    for cid in ids:
        bidx = rng.integers(0, data.n_per_client, size=(steps, batch))
        toks.append(data.x[cid][bidx])
        labs.append(data.y[cid][bidx])
    return {"tokens": jnp.asarray(np.stack(toks)),
            "labels": jnp.asarray(np.stack(labs))}


@dataclasses.dataclass
class PodTrainResult:
    params: Pytree
    history: list


def run_pod_training(cfg: TransformerConfig, data, *,
                     cyclic_rounds: int = 2, fl_rounds: int = 4,
                     clients_per_round: int = 4,
                     spec: Optional[PodFLSpec] = None,
                     mesh=None, seed: int = 0,
                     eval_fn: Optional[Callable] = None,
                     eval_every: Optional[int] = None,
                     eval_batch: int = 64,
                     verbose: bool = False,
                     chunk_size: int = 4,
                     sampling: str = "device",
                     layout: str = "fsdp_tp",
                     aggregation: str = "sequential",
                     n_pods: Optional[int] = None,
                     store: str = "dense",
                     store_capacity: int = 1024,
                     overlap: str = "on") -> PodTrainResult:
    """CyclicFL end-to-end on the pod backend: a declarative P1→P2 phase
    schedule through the shared round engine — no hand-rolled loops.

    Evaluation streams IN PROGRAM (repro.fl.engine): rounds on the
    ``eval_every`` cadence score the held-out test set inside the
    compiled chunk, so evaluating keeps ONE mesh dispatch per
    ``chunk_size`` rounds — there is no per-round-dispatch eval mode
    anymore.  ``eval_fn`` optionally overrides the default test-accuracy
    metric and must be traceable with the engine's per-sample contract
    ``eval_fn(params, bx, by) -> (B,)``.  ``eval_every=None`` defaults
    to every round when a custom metric is given (the legacy cadence)
    and to no evaluation otherwise; evaluated rounds carry an ``eval``
    entry in their history row.
    """
    from repro.launch.mesh import make_host_mesh
    spec = spec or PodFLSpec()
    mesh = mesh or make_host_mesh()
    task = lm_task(cfg)

    if eval_every is None:
        eval_every = 1 if eval_fn is not None else 0

    common = dict(mesh=mesh, clients_per_round=clients_per_round, spec=spec,
                  layout=layout, chunk_size=chunk_size, sampling=sampling,
                  eval_every=eval_every, eval_batch=eval_batch)
    # P2-only knobs: aggregation topology, the client-state store and
    # the overlapped residency pipeline (P1 relays the model and keeps
    # no per-client state, so overlap has nothing to hide there)
    if overlap not in ("on", "off"):
        raise ValueError(f"--overlap must be on|off, got {overlap!r}")
    fl_extra = dict(aggregation=aggregation, n_pods=n_pods, store=store,
                    store_capacity=store_capacity,
                    overlap=(overlap == "on"))
    phases = []
    if cyclic_rounds > 0:
        # privacy, compression and the trainable-slice filter apply at
        # the P2 aggregate only — P1 relays the model client-to-client
        # with no aggregation (clients need exact params to train on,
        # and the relay hop carries the full model), so the relay phase
        # runs with those knobs stripped (RelayStrategy rejects them)
        p1_common = dict(common, spec=dataclasses.replace(
            spec, dp=None, secure_agg=False, compression=None,
            peft=None, trainable_filter=None))
        phases.append(Phase("P1", PodCyclicConfig(rounds=cyclic_rounds,
                                                  seed=seed, **p1_common),
                            eval_fn=eval_fn))
    if fl_rounds > 0:
        # decorrelate the P2 key stream from P1's: each phase restarts
        # from PRNGKey(its seed), and with equal K the relay and
        # aggregate rounds split keys identically — the same seed would
        # replay P1's exact client selections and batch draws in P2.
        # When P2 is the first phase its seed also drives model init,
        # so only offset when a P1 phase precedes it.
        from repro.fl.pod import HOST_RNG_OFFSET_P2
        p2_seed = seed + HOST_RNG_OFFSET_P2 if phases else seed
        phases.append(Phase("P2", PodFLConfig(rounds=fl_rounds, seed=p2_seed,
                                              **common, **fl_extra),
                            eval_fn=eval_fn))
    if not phases:
        return PodTrainResult(params=init_lm(jax.random.PRNGKey(seed), cfg),
                              history=[])

    sched = run_phase_schedule(task, data, phases, verbose=verbose)
    history = []
    for h in sched.history:
        row = {"phase": h["phase"], "round": h["round"],
               "loss": h["local_loss"]}
        if "acc" in h:
            row["eval"] = h["acc"]
        history.append(row)
    return PodTrainResult(params=sched.params, history=history)


def main(argv=None) -> int:
    from repro.configs import get_reduced
    from repro.data.synthetic import make_synthetic_tokenlm

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--rounds", type=int, default=4)
    ap.add_argument("--cyclic-rounds", type=int, default=2)
    ap.add_argument("--clients", "--n-clients", dest="clients", type=int,
                    default=16, help="population size N (synthetic shards)")
    ap.add_argument("--clients-per-round", type=int, default=4)
    ap.add_argument("--local-steps", type=int, default=8)
    ap.add_argument("--batch", type=int, default=8,
                    help="per-step local batch size B")
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--algorithm", default="fedavg",
                    choices=POD_ALGORITHMS)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--server-opt", default="none",
                    choices=("none", "momentum", "adam"),
                    help="server-side optimizer on the aggregated "
                         "pseudo-gradient (FedAvgM / FedAdam)")
    ap.add_argument("--server-lr", type=float, default=1.0,
                    help="server step size; 1.0 suits momentum (FedAvgM), "
                         "adam wants ~0.01-0.1 (its update is sign-scale)")
    ap.add_argument("--server-momentum", type=float, default=0.9)
    ap.add_argument("--update-impl", default="fused",
                    choices=("tree", "fused", "fused_interpret"),
                    help="step-tail/aggregation implementation: the fused "
                         "flat-first path (default — ShardedFlatView "
                         "buffers preserve the FSDP×TP layout and the "
                         "kernels run shard-locally; auto-interprets "
                         "off-TPU) or the per-leaf tree algebra (the "
                         "parity oracle)")
    ap.add_argument("--eval-every", type=int, default=0,
                    help="in-program test-accuracy cadence "
                         "(0 = no evaluation; never splits a chunk)")
    ap.add_argument("--chunk-size", type=int, default=4,
                    help="rounds fused into one XLA dispatch")
    ap.add_argument("--sampling", default="device",
                    choices=("device", "host"))
    ap.add_argument("--layout", default="fsdp_tp", choices=rules.LAYOUTS)
    ap.add_argument("--aggregation", default="sequential",
                    choices=("sequential", "hierarchical"),
                    help="P2 topology: one scan over all K clients, or "
                         "two-level — per-pod partial deltas + one "
                         "cross-pod combine (pods default to the mesh "
                         "data-axis size; see --n-pods)")
    ap.add_argument("--n-pods", type=int, default=None,
                    help="pod count for --aggregation hierarchical "
                         "(must divide clients-per-round)")
    ap.add_argument("--store", default="dense", choices=("dense", "sparse"),
                    help="per-client state store: dense (n_clients, ...) "
                         "stacks or the participation-indexed sparse "
                         "active-set table (O(capacity) memory)")
    ap.add_argument("--store-capacity", type=int, default=1024,
                    help="sparse store rows; must cover the distinct "
                         "participants of one dispatch "
                         "(chunk-size x clients-per-round)")
    ap.add_argument("--overlap", default="on", choices=("on", "off"),
                    help="pipeline sparse-store residency for dispatch "
                         "N+1 behind dispatch N's device compute "
                         "(bitwise-identical results; off = synchronous "
                         "prepare between dispatches)")
    ap.add_argument("--dp-clip", type=float, default=None,
                    help="DP-FedAvg per-client delta clip bound C "
                         "(None = no clipping)")
    ap.add_argument("--dp-sigma", type=float, default=0.0,
                    help="DP-FedAvg noise multiplier (per-client stddev "
                         "sigma*C, applied at aggregation; needs "
                         "--dp-clip)")
    ap.add_argument("--secure-agg", action="store_true",
                    help="simulate pairwise-masked secure aggregation "
                         "(masks cancel in the round sum)")
    ap.add_argument("--compress-bits", type=int, default=32,
                    choices=(8, 16, 32),
                    help="P2 upload quantization: blockwise symmetric "
                         "int8/int16 fake quantization of each client's "
                         "delta (32 = no quantization)")
    ap.add_argument("--compress-density", type=float, default=1.0,
                    help="P2 upload top-k sparsification: fraction of "
                         "delta elements kept per bucket, by magnitude "
                         "(1.0 = keep everything)")
    ap.add_argument("--error-feedback", action="store_true",
                    help="carry each client's compression residual and "
                         "add it to the next participating round's delta "
                         "(needs a lossy --compress-bits/-density combo)")
    ap.add_argument("--peft", default=None, metavar="lora:<r>",
                    help="parameter-efficient P2: build the model with "
                         "rank-r LoRA adapters and train ONLY them — "
                         "frozen leaves never enter the kernels, the "
                         "donated carry or the upload (P1 still relays "
                         "the full model)")
    ap.add_argument("--trainable-filter", default=None,
                    choices=sorted(rules.TRAINABLE_FILTERS),
                    help="named trainable-leaf filter (overrides the one "
                         "--peft implies); needs --update-impl fused")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    from repro.configs import with_peft
    cfg = with_peft(get_reduced(args.arch), args.peft)
    if cfg.input_mode != "tokens":
        print(f"[train] {args.arch}: pod driver trains token-mode archs; "
              f"{cfg.input_mode}-mode archs train via the same round fns "
              "with embedding batches (see examples/)", file=sys.stderr)
        return 2
    data = make_synthetic_tokenlm(
        n_clients=args.clients, seq_len=args.seq, n_seq_per_client=64,
        vocab=cfg.vocab_size, beta=0.5, seed=args.seed)
    dp = DPSpec(args.dp_clip, args.dp_sigma) \
        if args.dp_clip is not None else None
    comp = CompressionSpec(bits=args.compress_bits,
                           density=args.compress_density,
                           error_feedback=args.error_feedback)
    spec = PodFLSpec(local_steps=args.local_steps, batch_size=args.batch,
                     lr=args.lr, algorithm=args.algorithm,
                     server_opt=args.server_opt, server_lr=args.server_lr,
                     server_momentum=args.server_momentum,
                     update_impl=args.update_impl, dp=dp,
                     secure_agg=args.secure_agg,
                     compression=None if comp.identity else comp,
                     peft=args.peft, trainable_filter=args.trainable_filter)
    t0 = time.time()
    res = run_pod_training(
        cfg, data, cyclic_rounds=args.cyclic_rounds, fl_rounds=args.rounds,
        clients_per_round=args.clients_per_round, spec=spec,
        seed=args.seed, verbose=True, chunk_size=args.chunk_size,
        eval_every=args.eval_every,
        sampling=args.sampling, layout=args.layout,
        aggregation=args.aggregation, n_pods=args.n_pods,
        store=args.store, store_capacity=args.store_capacity,
        overlap=args.overlap)
    first = res.history[0]["loss"]
    last = res.history[-1]["loss"]
    print(f"[train] {args.arch}: loss {first:.4f} -> {last:.4f} "
          f"({time.time() - t0:.1f}s)")
    return 0 if last < first else 1


if __name__ == "__main__":
    sys.exit(main())
