"""Production meshes.

Target hardware: TPU v5e-class pods — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI per chip.  Single pod = 16×16 = 256 chips
(data × model); multi-pod = 2×16×16 = 512 chips with a leading ``pod``
axis (DCN-connected in real deployments; the dry-run treats it as a
mesh axis so the pod-level collective schedule is visible in the HLO).

``make_production_mesh`` is a function (not a module constant) so that
importing this module never touches jax device state — the dry-run must
set XLA_FLAGS before the first jax call.
"""
from __future__ import annotations

import dataclasses

import jax

# hardware constants used by the roofline (per chip)
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # B/s
ICI_BW = 50e9                 # B/s per link


@dataclasses.dataclass(frozen=True)
class MeshSpec:
    name: str
    shape: tuple
    axes: tuple

    @property
    def n_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


SINGLE_POD = MeshSpec("pod", (16, 16), ("data", "model"))
MULTI_POD = MeshSpec("multipod", (2, 16, 16), ("pod", "data", "model"))


def _make_mesh(shape, axes):
    """jax.make_mesh across jax versions: ``axis_types`` (and the
    AxisType enum) only exist on newer jax; every mesh here wants the
    Auto type, which IS the old default, so fall back silently."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        try:
            return jax.make_mesh(shape, axes,
                                 axis_types=(axis_type.Auto,) * len(axes))
        except TypeError:
            pass
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    spec = MULTI_POD if multi_pod else SINGLE_POD
    return _make_mesh(spec.shape, spec.axes)


def make_host_mesh():
    """Degenerate 1-device mesh for CPU tests (same axis names)."""
    return _make_mesh((1, 1), ("data", "model"))
