"""Roofline-term extraction from a compiled (AOT) XLA artifact.

Three terms per (arch × shape × mesh), per DESIGN.md §6:

    compute    = HLO_FLOPs_total        / (chips · PEAK_FLOPS_BF16)
    memory     = HLO_bytes_total        / (chips · HBM_BW)
    collective = collective_bytes_total / (chips · ICI_BW)

IMPORTANT: for an SPMD-partitioned module, ``compiled.cost_analysis()``
and the HLO text describe the PER-DEVICE program, so the measured FLOPs
/ bytes / collective-result-bytes are already divided by ``chips`` —
each term below therefore divides by the single-chip rate only.

``cost_analysis`` provides FLOPs and bytes-accessed.  Collective bytes
are NOT in cost_analysis — we parse the post-SPMD HLO text and sum the
result-shape bytes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute instruction; that sum = bytes one chip
injects into the ICI per step.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

# one typed buffer inside an HLO shape, e.g. ``bf16[64,128,8,128]{3,2,1,0}``
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([\d,]*)\]")

COLLECTIVE_OPS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# `  %name = <shape-or-tuple> op-name(` — post-optimization HLO instruction
_INSTR_RE = re.compile(
    r"=\s+((?:\([^)]*\))|(?:[a-z0-9]+\[[\d,]*\](?:\{[^}]*\})?))\s+"
    r"(" + "|".join(COLLECTIVE_OPS) + r")(?:-(?:start|done))?\(",
)


def _shape_bytes(shape_text: str) -> int:
    """Sum bytes over every typed buffer in ``shape_text`` (handles tuples)."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes_by_op(hlo_text: str) -> Dict[str, int]:
    """Per-collective-op result bytes from post-SPMD HLO text.

    ``-start`` ops are counted, matching ``-done`` duplicates are not
    (async pairs name the same transfer twice).
    """
    out: Dict[str, int] = {op: 0 for op in COLLECTIVE_OPS}
    counts: Dict[str, int] = {op: 0 for op in COLLECTIVE_OPS}
    for m in _INSTR_RE.finditer(hlo_text):
        shape_text, op = m.group(1), m.group(2)
        full = m.group(0)
        if f"{op}-done(" in full:
            continue
        out[op] += _shape_bytes(shape_text)
        counts[op] += 1
    out["_counts"] = counts  # type: ignore[assignment]
    return out


def total_collective_bytes(hlo_text: str) -> int:
    d = collective_bytes_by_op(hlo_text)
    return sum(v for k, v in d.items() if not k.startswith("_"))


def _cost_value(cost, key: str) -> float:
    """cost_analysis() is a dict (new jax) or [dict] (older)."""
    if cost is None:
        return 0.0
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return float(cost.get(key, 0.0))


@dataclasses.dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    n_chips: int
    hlo_flops: float            # PER CHIP (post-SPMD module)
    hlo_bytes: float            # PER CHIP bytes accessed
    collective_bytes: int       # PER CHIP ICI bytes
    collective_detail: Dict[str, int]
    model_flops: float = 0.0    # 6·N(_active)·D — GLOBAL
    bytes_per_device: Optional[Dict[str, float]] = None

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        # collective_bytes is already per-chip
        return self.collective_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        total = self.hlo_flops * self.n_chips
        return self.model_flops / total if total else 0.0

    def to_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "n_chips": self.n_chips,
            "hlo_flops_per_chip": self.hlo_flops,
            "hlo_bytes_per_chip": self.hlo_bytes,
            "collective_bytes_per_chip": self.collective_bytes,
            "collective_detail": {k: v for k, v in self.collective_detail.items()
                                  if not k.startswith("_")},
            "collective_counts": self.collective_detail.get("_counts", {}),
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
            "bytes_per_device": self.bytes_per_device,
        }


def analyze_compiled(compiled, *, arch: str, shape: str, mesh_name: str,
                     n_chips: int, model_flops: float = 0.0) -> RooflineTerms:
    cost = compiled.cost_analysis()
    flops = _cost_value(cost, "flops")
    byts = _cost_value(cost, "bytes accessed")
    hlo = compiled.as_text()
    det = collective_bytes_by_op(hlo)
    coll = sum(v for k, v in det.items() if not k.startswith("_"))

    mem = None
    try:
        ma = compiled.memory_analysis()
        mem = {
            "argument_bytes": float(getattr(ma, "argument_size_in_bytes", 0)),
            "output_bytes": float(getattr(ma, "output_size_in_bytes", 0)),
            "temp_bytes": float(getattr(ma, "temp_size_in_bytes", 0)),
            "peak_bytes": float(
                getattr(ma, "peak_memory_in_bytes",
                        getattr(ma, "temp_size_in_bytes", 0))),
        }
    except Exception:  # pragma: no cover - backend-specific
        pass

    return RooflineTerms(
        arch=arch, shape=shape, mesh=mesh_name, n_chips=n_chips,
        hlo_flops=flops, hlo_bytes=byts, collective_bytes=coll,
        collective_detail=det, model_flops=model_flops,
        bytes_per_device=mem)
