"""Non-IID federated partitioning.

The paper follows Li et al. (ICDE'22): per-class Dirichlet(beta) splits
across clients.  Smaller beta = more heterogeneous.  beta in {0.1, 0.5, 1.0}
are the paper's three non-IID scenarios.
"""
from __future__ import annotations

from typing import Dict, List, Sequence

import numpy as np


def dirichlet_partition(
    labels: np.ndarray,
    n_clients: int,
    beta: float,
    rng: np.random.Generator,
    min_per_client: int = 2,
    max_retries: int = 50,
) -> List[np.ndarray]:
    """Split sample indices over ``n_clients`` with per-class Dir(beta).

    Returns a list of index arrays, one per client.  Retries until every
    client holds at least ``min_per_client`` samples (standard practice —
    degenerate empty clients break local training).
    """
    if beta <= 0:
        raise ValueError(f"beta must be positive, got {beta}")
    labels = np.asarray(labels)
    n = len(labels)
    classes = np.unique(labels)
    for _ in range(max_retries):
        client_indices: List[List[int]] = [[] for _ in range(n_clients)]
        for c in classes:
            idx_c = np.flatnonzero(labels == c)
            rng.shuffle(idx_c)
            props = rng.dirichlet(np.full(n_clients, beta))
            # cumulative split points over this class's samples
            cuts = (np.cumsum(props) * len(idx_c)).astype(int)[:-1]
            for cid, part in enumerate(np.split(idx_c, cuts)):
                client_indices[cid].extend(part.tolist())
        sizes = np.array([len(ci) for ci in client_indices])
        if sizes.min() >= min_per_client:
            return [np.array(sorted(ci), dtype=np.int64) for ci in client_indices]
    # fall back: top up tiny clients from the global pool
    pool = np.arange(n)
    out = []
    for ci in client_indices:
        ci = np.asarray(ci, dtype=np.int64)
        if len(ci) < min_per_client:
            extra = rng.choice(pool, size=min_per_client - len(ci), replace=False)
            ci = np.concatenate([ci, extra])
        out.append(np.sort(ci))
    return out


def partition_stats(labels: np.ndarray, parts: Sequence[np.ndarray]) -> Dict[str, float]:
    """Heterogeneity diagnostics for a partition: per-client size spread and
    mean label-distribution distance from the global distribution."""
    labels = np.asarray(labels)
    classes = np.unique(labels)
    global_dist = np.array([(labels == c).mean() for c in classes])
    tvs = []
    for idx in parts:
        if len(idx) == 0:
            tvs.append(1.0)
            continue
        local = labels[idx]
        local_dist = np.array([(local == c).mean() for c in classes])
        tvs.append(0.5 * np.abs(local_dist - global_dist).sum())
    sizes = np.array([len(p) for p in parts], dtype=np.float64)
    return {
        "n_clients": len(parts),
        "mean_size": float(sizes.mean()),
        "min_size": float(sizes.min()),
        "max_size": float(sizes.max()),
        "mean_tv_from_global": float(np.mean(tvs)),
        "coverage": float(len(np.unique(np.concatenate(parts))) / max(len(labels), 1)),
    }
