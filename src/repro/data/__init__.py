from repro.data.partition import dirichlet_partition, partition_stats
from repro.data.federated import FederatedDataset, ClientBatchIterator
from repro.data.synthetic import (
    make_synthetic_vision,
    make_synthetic_charlm,
    make_synthetic_tokenlm,
    DATASETS,
)
