"""Federated dataset container + client batch iteration.

Simulation keeps every client's data as fixed-size stacked arrays
``(n_clients, n_per_client, ...)`` so that client-parallel local training
is a single ``vmap``/``shard_map`` over axis 0 — this is exactly the
layout that maps FL clients onto the mesh ``data`` axis on a pod.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.partition import dirichlet_partition


@dataclasses.dataclass
class FederatedDataset:
    """Stacked per-client data.

    x: (n_clients, n_per_client, *feature_shape)
    y: (n_clients, n_per_client) int labels (or next-token targets)
    n_real: (n_clients,) number of genuine (non-resampled) samples per
        client — used as FedAvg aggregation weights N_i.
    test_x / test_y: held-out global test set.
    """

    x: np.ndarray
    y: np.ndarray
    n_real: np.ndarray
    test_x: np.ndarray
    test_y: np.ndarray
    n_classes: int
    name: str = "federated"
    _device_cache: Dict = dataclasses.field(
        default_factory=dict, init=False, repr=False, compare=False)

    @property
    def n_clients(self) -> int:
        return self.x.shape[0]

    @property
    def n_per_client(self) -> int:
        return self.x.shape[1]

    def client_weights(self) -> np.ndarray:
        return self.n_real.astype(np.float64) / self.n_real.sum()

    @classmethod
    def from_arrays(
        cls,
        x: np.ndarray,
        y: np.ndarray,
        test_x: np.ndarray,
        test_y: np.ndarray,
        n_clients: int,
        beta: Optional[float],
        seed: int,
        n_classes: Optional[int] = None,
        n_per_client: Optional[int] = None,
        name: str = "federated",
    ) -> "FederatedDataset":
        """Partition a centralized dataset into clients.

        beta=None means IID (uniform random split); otherwise per-class
        Dirichlet(beta).  Each client is padded to ``n_per_client`` by
        resampling its own data (with replacement) so the stacked layout
        is rectangular; ``n_real`` records true sizes for weighting.
        """
        rng = np.random.default_rng(seed)
        n = len(y)
        if beta is None:
            perm = rng.permutation(n)
            parts = np.array_split(perm, n_clients)
        else:
            parts = dirichlet_partition(y, n_clients, beta, rng)
        if n_per_client is None:
            n_per_client = max(int(np.ceil(n / n_clients)), 2)
        xs, ys, n_real = [], [], []
        for idx in parts:
            n_real.append(len(idx))
            if len(idx) >= n_per_client:
                take = rng.choice(idx, size=n_per_client, replace=False)
            else:
                pad = rng.choice(idx, size=n_per_client - len(idx), replace=True)
                take = np.concatenate([idx, pad])
            rng.shuffle(take)
            xs.append(x[take])
            ys.append(y[take])
        return cls(
            x=np.stack(xs),
            y=np.stack(ys),
            n_real=np.asarray(n_real, dtype=np.int64),
            test_x=test_x,
            test_y=test_y,
            n_classes=n_classes or int(y.max()) + 1,
            name=name,
        )

    def client_batches(self, client: int, batch_size: int, key: jax.Array,
                       n_batches: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Sample ``n_batches`` batches for one client; returns stacked
        (n_batches, batch, ...) arrays ready for ``lax.scan``."""
        idx = jax.random.randint(key, (n_batches, batch_size), 0, self.n_per_client)
        x_all, y_all, _ = self.device_arrays()
        return x_all[client][idx], y_all[client][idx]

    def device_arrays(self, shardings=None
                      ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
        """Stacked client arrays on device, uploaded once and cached —
        every round/batch access indexes the resident copies instead of
        re-transferring host memory.

        ``shardings`` is an optional hashable ``(x_sh, y_sh, n_real_sh)``
        placement triple (e.g. NamedShardings from a pod backend); each
        distinct placement is uploaded once and cached independently, so
        host and mesh engines can stream rounds off the same dataset.
        """
        if shardings not in self._device_cache:
            if shardings is None:
                arrs = (jnp.asarray(self.x), jnp.asarray(self.y),
                        jnp.asarray(self.n_real))
            else:
                sx, sy, sn = shardings
                arrs = (jax.device_put(self.x, sx),
                        jax.device_put(self.y, sy),
                        jax.device_put(self.n_real, sn))
            self._device_cache[shardings] = arrs
        return self._device_cache[shardings]


class ClientBatchIterator:
    """Host-side epoch iterator over one client's shard (used by examples
    that mimic the paper's 5-local-epoch protocol exactly)."""

    def __init__(self, x: np.ndarray, y: np.ndarray, batch_size: int, seed: int):
        self.x, self.y = x, y
        self.batch_size = batch_size
        self.rng = np.random.default_rng(seed)

    def epoch(self) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        n = len(self.y)
        perm = self.rng.permutation(n)
        for start in range(0, n - self.batch_size + 1, self.batch_size):
            take = perm[start:start + self.batch_size]
            yield self.x[take], self.y[take]
