"""Procedural stand-ins for the paper's benchmarks (offline container).

Three families, mirroring the paper's evaluation structure:

- vision  : class-conditional images.  Each class has a fixed random
            template (low-frequency pattern); a sample is the template
            under a random shift + Gaussian noise + random contrast.
            Learnable by LeNet/ResNet-class models, non-trivially so.
- charlm  : character streams from per-client-style Markov chains
            (Shakespeare stand-in).  Client style = mixture of a global
            transition matrix and a client-specific one => natural non-IID.
- tokenlm : token streams from a sparse random bigram teacher over a
            configurable vocab (used to exercise the assigned LLM-class
            architectures with CyclicFL as federated next-token training).

All generators are deterministic in ``seed``.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from repro.data.federated import FederatedDataset
from repro.utils.registry import Registry

DATASETS: Registry = Registry("dataset")


def _class_templates(rng: np.random.Generator, n_classes: int, h: int, w: int, c: int) -> np.ndarray:
    """Low-frequency class templates: random coefficients over a small 2D
    Fourier basis so that classes are distinguishable but overlapping."""
    fy, fx = 4, 4
    coef = rng.normal(size=(n_classes, c, fy, fx))
    ys = np.linspace(0, np.pi, h)[:, None, None, None]
    xs = np.linspace(0, np.pi, w)[None, :, None, None]
    basis = np.cos(ys * np.arange(fy)[None, None, :, None]) * np.cos(
        xs * np.arange(fx)[None, None, None, :])  # (h, w, fy, fx)
    tmpl = np.einsum("ncyx,hwyx->nhwc", coef, basis)
    tmpl /= np.abs(tmpl).max(axis=(1, 2, 3), keepdims=True) + 1e-8
    return tmpl.astype(np.float32)


def make_synthetic_vision(
    n_train: int = 20000,
    n_test: int = 2000,
    n_classes: int = 10,
    image_hw: Tuple[int, int] = (32, 32),
    channels: int = 3,
    noise: float = 0.35,
    max_shift: int = 4,
    seed: int = 0,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Returns (train_x, train_y, test_x, test_y); x in NHWC float32."""
    rng = np.random.default_rng(seed)
    h, w = image_hw
    tmpl = _class_templates(rng, n_classes, h, w, channels)

    def gen(n, r):
        y = r.integers(0, n_classes, size=n)
        x = tmpl[y].copy()
        # random circular shift per sample (translation invariance pressure)
        sy = r.integers(-max_shift, max_shift + 1, size=n)
        sx = r.integers(-max_shift, max_shift + 1, size=n)
        for i in range(n):
            x[i] = np.roll(np.roll(x[i], sy[i], axis=0), sx[i], axis=1)
        contrast = r.uniform(0.7, 1.3, size=(n, 1, 1, 1)).astype(np.float32)
        x = x * contrast + r.normal(scale=noise, size=x.shape).astype(np.float32)
        return x.astype(np.float32), y.astype(np.int32)

    train_x, train_y = gen(n_train, rng)
    test_x, test_y = gen(n_test, np.random.default_rng(seed + 1))
    return train_x, train_y, test_x, test_y


def make_synthetic_charlm(
    n_clients: int = 64,
    seq_len: int = 80,
    n_seq_per_client: int = 64,
    vocab: int = 64,
    style_mix: float = 0.35,
    n_test: int = 512,
    seed: int = 0,
) -> FederatedDataset:
    """Shakespeare stand-in: next-char prediction.  x[t] predicts x[t+1];
    we store sequences, training consumes (seq[:-1] -> seq[1:]).

    Naturally non-IID: each client's Markov chain is
    (1-style_mix)*global + style_mix*client_specific.
    """
    rng = np.random.default_rng(seed)

    def row_norm(m):
        return m / m.sum(axis=1, keepdims=True)

    # sparse-ish global chain: every char strongly prefers a few successors
    global_T = row_norm(rng.dirichlet(np.full(vocab, 0.1), size=vocab))

    def sample_stream(T, n, L, r):
        out = np.empty((n, L), dtype=np.int32)
        state = r.integers(0, vocab, size=n)
        cdf = np.cumsum(T, axis=1)
        for t in range(L):
            out[:, t] = state
            u = r.random(n)
            state = (u[:, None] < cdf[state]).argmax(axis=1)
        return out

    xs = []
    for cid in range(n_clients):
        r = np.random.default_rng(seed + 1000 + cid)
        local_T = row_norm(r.dirichlet(np.full(vocab, 0.1), size=vocab))
        T = row_norm((1 - style_mix) * global_T + style_mix * local_T)
        xs.append(sample_stream(T, n_seq_per_client, seq_len + 1, r))
    x = np.stack(xs)  # (clients, n_seq, L+1)
    test = sample_stream(global_T, n_test, seq_len + 1, np.random.default_rng(seed + 7))
    return FederatedDataset(
        x=x[:, :, :-1],
        y=x[:, :, 1:],
        n_real=np.full(n_clients, n_seq_per_client, dtype=np.int64),
        test_x=test[:, :-1],
        test_y=test[:, 1:],
        n_classes=vocab,
        name="synthetic-charlm",
    )


def make_synthetic_tokenlm(
    n_clients: int,
    seq_len: int,
    n_seq_per_client: int,
    vocab: int,
    n_topics: int = 8,
    beta: float = 0.5,
    n_test: int = 64,
    seed: int = 0,
) -> FederatedDataset:
    """Token-LM federated data for the assigned LLM-class architectures.

    A set of ``n_topics`` bigram teachers; each client draws a topic
    mixture from Dir(beta) (non-IID across clients) and samples token
    streams from its mixture — CyclicFL's P1/P2 both consume this.
    """
    rng = np.random.default_rng(seed)
    # topic chains over a *bucketed* vocab to keep memory bounded for huge vocabs
    bucket = min(vocab, 4096)

    def row_norm(m):
        return m / m.sum(axis=1, keepdims=True)

    chains = np.stack([
        row_norm(rng.dirichlet(np.full(bucket, 0.05), size=bucket))
        for _ in range(n_topics)
    ])
    cdfs = np.cumsum(chains, axis=2)

    def sample(topic_probs, n, L, r):
        out = np.empty((n, L), dtype=np.int32)
        topics = r.choice(n_topics, size=n, p=topic_probs)
        state = r.integers(0, bucket, size=n)
        for t in range(L):
            out[:, t] = state
            u = r.random(n)
            rowcdf = cdfs[topics, state]  # (n, bucket)
            state = (u[:, None] < rowcdf).argmax(axis=1)
        if vocab > bucket:
            # spread bucketed ids over the true vocab deterministically
            out = out * (vocab // bucket) + (out % (vocab // bucket))
        return out

    xs = []
    for cid in range(n_clients):
        r = np.random.default_rng(seed + 500 + cid)
        mix = r.dirichlet(np.full(n_topics, beta))
        xs.append(sample(mix, n_seq_per_client, seq_len + 1, r))
    x = np.stack(xs)
    test = sample(np.full(n_topics, 1.0 / n_topics), n_test, seq_len + 1,
                  np.random.default_rng(seed + 9))
    return FederatedDataset(
        x=x[:, :, :-1],
        y=x[:, :, 1:],
        n_real=np.full(n_clients, n_seq_per_client, dtype=np.int64),
        test_x=test[:, :-1],
        test_y=test[:, 1:],
        n_classes=vocab,
        name="synthetic-tokenlm",
    )


@DATASETS.register("cifar10-like")
def _cifar10_like(n_clients: int = 100, beta: Optional[float] = 0.5, seed: int = 0,
                  n_train: int = 20000, n_test: int = 2000,
                  noise: float = 0.35) -> FederatedDataset:
    tx, ty, ex, ey = make_synthetic_vision(n_train=n_train, n_test=n_test,
                                           n_classes=10, image_hw=(32, 32),
                                           channels=3, noise=noise, seed=seed)
    return FederatedDataset.from_arrays(tx, ty, ex, ey, n_clients, beta, seed,
                                        n_classes=10, name="cifar10-like")


@DATASETS.register("cifar100-like")
def _cifar100_like(n_clients: int = 100, beta: Optional[float] = 0.5, seed: int = 0,
                   n_train: int = 20000, n_test: int = 2000,
                   coarse: bool = False, noise: float = 0.35) -> FederatedDataset:
    n_classes = 20 if coarse else 100
    tx, ty, ex, ey = make_synthetic_vision(n_train=n_train, n_test=n_test,
                                           n_classes=n_classes, image_hw=(32, 32),
                                           channels=3, noise=noise, seed=seed)
    return FederatedDataset.from_arrays(tx, ty, ex, ey, n_clients, beta, seed,
                                        n_classes=n_classes, name="cifar100-like")


# the benchmark workhorse: 20-class coarse labels + heavy noise so that
# quick-preset runs have headroom (no accuracy ceiling at tiny scales)
@DATASETS.register("cifar100c-hard")
def _cifar100c_hard(n_clients: int = 100, beta: Optional[float] = 0.5,
                    seed: int = 0, n_train: int = 20000,
                    n_test: int = 2000) -> FederatedDataset:
    return _cifar100_like(n_clients=n_clients, beta=beta, seed=seed,
                          n_train=n_train, n_test=n_test, coarse=True,
                          noise=0.9)


@DATASETS.register("fashion-like")
def _fashion_like(n_clients: int = 100, beta: Optional[float] = 0.5, seed: int = 0,
                  n_train: int = 20000, n_test: int = 2000,
                  noise: float = 0.35) -> FederatedDataset:
    tx, ty, ex, ey = make_synthetic_vision(n_train=n_train, n_test=n_test,
                                           n_classes=10, image_hw=(28, 28),
                                           channels=1, noise=noise, seed=seed)
    return FederatedDataset.from_arrays(tx, ty, ex, ey, n_clients, beta, seed,
                                        n_classes=10, name="fashion-like")


@DATASETS.register("femnist-like")
def _femnist_like(n_clients: int = 190, beta: Optional[float] = 0.3, seed: int = 0,
                  n_train: int = 19000, n_test: int = 2000,
                  noise: float = 0.35) -> FederatedDataset:
    tx, ty, ex, ey = make_synthetic_vision(n_train=n_train, n_test=n_test,
                                           n_classes=62, image_hw=(28, 28),
                                           channels=1, noise=noise, seed=seed)
    return FederatedDataset.from_arrays(tx, ty, ex, ey, n_clients, beta, seed,
                                        n_classes=62, name="femnist-like")


@DATASETS.register("shakespeare-like")
def _shakespeare_like(n_clients: int = 66, seed: int = 0, **kw) -> FederatedDataset:
    return make_synthetic_charlm(n_clients=n_clients, seed=seed, **kw)


# token-LM stream for the pod backend / LLM-class archs: registered so
# benchmarks and CLIs can stream per-round batches by dataset name
@DATASETS.register("tokenlm-bigram")
def _tokenlm_bigram(n_clients: int = 16, seed: int = 0, seq_len: int = 64,
                    n_seq_per_client: int = 64, vocab: int = 256,
                    beta: float = 0.5, n_test: int = 64) -> FederatedDataset:
    return make_synthetic_tokenlm(
        n_clients=n_clients, seq_len=seq_len,
        n_seq_per_client=n_seq_per_client, vocab=vocab, beta=beta,
        n_test=n_test, seed=seed)
