from repro.utils import tree_math
from repro.utils.registry import Registry
