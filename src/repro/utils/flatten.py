"""FlatView — pack a pytree into contiguous per-dtype 1-D buffers.

The FL update hot loop (clip / correct / decay / momentum / axpy per
local SGD step, weighted delta accumulation + server moments per round)
is pure elementwise algebra over the parameter pytree.  Leaf-wise
``tree_map`` turns each of those into O(n_leaves) tiny ops; packing the
tree into one contiguous buffer per dtype turns them into O(1) blocked
kernels (repro.kernels.fused_update) regardless of model depth.

The contract:

  view = FlatView.of(tree)          # shapes/dtypes only — works on tracers
  bufs = view.flatten(tree)         # {dtype_name: (total,) 1-D buffer}
  tree == view.unflatten(bufs)      # exact round-trip, any nesting

Leaves are grouped by canonical dtype name ("float32", "bfloat16", ...)
in first-seen traversal order; each leaf owns a static ``[offset,
offset+size)`` slice of its dtype's buffer (``slots``), so flatten is
reshape+concatenate and unflatten is static-slice+reshape — pure data
movement XLA folds into neighbouring ops.  Scalar leaves occupy one
element; empty (sub)trees contribute no slots and an empty buffer dict.

``flatten_stacked`` / ``unflatten_stacked`` handle trees whose leaves
carry a shared leading axis (the engine's vmapped ``(K, ...)`` client
stacks): buffers come out ``(K, total)`` with the same per-leaf offsets.

FlatView is a frozen, hashable value (treedef + slot tuple), so it can
key caches and ride static arguments.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


@dataclasses.dataclass(frozen=True)
class LeafSlot:
    """One leaf's static slice of its dtype buffer."""
    buffer: str                 # canonical dtype name, e.g. "float32"
    offset: int                 # element offset into the buffer
    size: int                   # number of elements (1 for scalar leaves)
    shape: Tuple[int, ...]      # original leaf shape


@dataclasses.dataclass(frozen=True)
class FlatView:
    """Static packing plan for one pytree structure (see module doc)."""
    treedef: Any
    slots: Tuple[LeafSlot, ...]

    @classmethod
    def of(cls, tree: Pytree) -> "FlatView":
        """Build a view from shapes/dtypes only — leaves may be tracers,
        ShapeDtypeStructs or concrete arrays."""
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        sizes: Dict[str, int] = {}
        slots = []
        for leaf in leaves:
            name = jnp.dtype(leaf.dtype).name
            size = int(math.prod(leaf.shape))
            off = sizes.get(name, 0)
            slots.append(LeafSlot(buffer=name, offset=off, size=size,
                                  shape=tuple(leaf.shape)))
            sizes[name] = off + size
        return cls(treedef=treedef, slots=tuple(slots))

    # -- introspection ------------------------------------------------------

    @property
    def buffer_sizes(self) -> Dict[str, int]:
        """Total elements per dtype buffer, in first-seen order."""
        sizes: Dict[str, int] = {}
        for s in self.slots:
            sizes[s.buffer] = s.offset + s.size
        return sizes

    @property
    def total_size(self) -> int:
        return sum(self.buffer_sizes.values())

    def _check(self, tree: Pytree) -> list:
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        if treedef != self.treedef:
            raise ValueError(f"tree structure mismatch: {treedef} != "
                             f"{self.treedef}")
        return leaves

    # -- pack / unpack ------------------------------------------------------

    def flatten(self, tree: Pytree) -> Dict[str, jnp.ndarray]:
        """Pack ``tree`` into ``{dtype_name: (total,) buffer}``."""
        leaves = self._check(tree)
        parts: Dict[str, list] = {}
        for slot, leaf in zip(self.slots, leaves):
            parts.setdefault(slot.buffer, []).append(
                jnp.asarray(leaf).reshape(-1))
        return {name: jnp.concatenate(chunks)
                for name, chunks in parts.items()}

    def unflatten(self, bufs: Dict[str, jnp.ndarray]) -> Pytree:
        """Inverse of :meth:`flatten` (accepts buffers of any dtype —
        leaves are cast back to the slot's recorded dtype by reshape,
        not re-cast; pass matching dtypes for an exact round-trip)."""
        leaves = [bufs[s.buffer][s.offset:s.offset + s.size].reshape(s.shape)
                  for s in self.slots]
        return jax.tree_util.tree_unflatten(self.treedef, leaves)

    # -- stacked variants (leading shared axis, e.g. (K, ...) clients) ------

    def flatten_stacked(self, tree: Pytree) -> Dict[str, jnp.ndarray]:
        """Pack a tree whose leaves carry one shared leading axis K into
        ``{dtype_name: (K, total) buffers}``."""
        leaves = self._check(tree)
        parts: Dict[str, list] = {}
        for slot, leaf in zip(self.slots, leaves):
            leaf = jnp.asarray(leaf)
            parts.setdefault(slot.buffer, []).append(
                leaf.reshape(leaf.shape[0], -1))
        return {name: jnp.concatenate(chunks, axis=1)
                for name, chunks in parts.items()}

    def unflatten_stacked(self, bufs: Dict[str, jnp.ndarray]) -> Pytree:
        leaves = []
        for s in self.slots:
            buf = bufs[s.buffer]
            leaves.append(buf[:, s.offset:s.offset + s.size].reshape(
                (buf.shape[0],) + s.shape))
        return jax.tree_util.tree_unflatten(self.treedef, leaves)

    # -- constructors over the same plan ------------------------------------

    def zeros(self, dtype=None) -> Dict[str, jnp.ndarray]:
        """Zero buffers with this view's sizes; ``dtype`` overrides the
        per-buffer dtype (e.g. an f32 delta accumulator over bf16
        params)."""
        return {name: jnp.zeros((size,), dtype or name)
                for name, size in self.buffer_sizes.items()}
