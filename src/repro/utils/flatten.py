"""FlatView — pack a pytree into contiguous per-dtype 1-D buffers.

The FL update hot loop (clip / correct / decay / momentum / axpy per
local SGD step, weighted delta accumulation + server moments per round)
is pure elementwise algebra over the parameter pytree.  Leaf-wise
``tree_map`` turns each of those into O(n_leaves) tiny ops; packing the
tree into one contiguous buffer per dtype turns them into O(1) blocked
kernels (repro.kernels.fused_update) regardless of model depth.

The contract:

  view = FlatView.of(tree)          # shapes/dtypes only — works on tracers
  bufs = view.flatten(tree)         # {dtype_name: (total,) 1-D buffer}
  tree == view.unflatten(bufs)      # exact round-trip, any nesting

Leaves are grouped by canonical dtype name ("float32", "bfloat16", ...)
in first-seen traversal order; each leaf owns a static ``[offset,
offset+size)`` slice of its dtype's buffer (``slots``), so flatten is
reshape+concatenate and unflatten is static-slice+reshape — pure data
movement XLA folds into neighbouring ops.  Scalar leaves occupy one
element; empty (sub)trees contribute no slots and an empty buffer dict.

``flatten_stacked`` / ``unflatten_stacked`` handle trees whose leaves
carry a shared leading axis (the engine's vmapped ``(K, ...)`` client
stacks): buffers come out ``(K, total)`` with the same per-leaf offsets.

FlatView is a frozen, hashable value (treedef + slot tuple), so it can
key caches and ride static arguments.

Trainable-slice partitioning (federated PEFT): ``of(tree, filter=...)``
takes a per-leaf boolean mask (True = trainable, tree_flatten order —
repro.sharding.rules.trainable_mask builds one from a path pattern) and
routes frozen leaves into separate ``"frozen:"``-prefixed buckets with
their own static offsets.  Every emitting method — ``flatten``,
``zeros``, ``normal``, the stacked variants, ``buffer_sizes`` — then
speaks TRAINABLE buckets only, so gradients, momentum, deltas, server
moments and upload accounting all shrink to the optimized slice without
any caller-side masking; the frozen constants pack once via
``flatten_frozen`` and merge back at the ``unflatten(bufs, frozen=...)``
boundary (absent frozen buckets zero-fill, for moment trees).  With
``filter=None`` there are no frozen buckets and every path is the exact
unfiltered program.

:class:`ShardedFlatView` is the mesh-aware sibling: leaves are bucketed
per *(dtype, mesh-axis group)* — the group being the set of mesh axes
their PartitionSpec shards them over — and each bucket packs into a
``(n_shards, per_shard)`` buffer whose leading axis is sharded over
exactly those axes.  Per-shard offsets are static, so every device holds
one contiguous local buffer per bucket and the fused update kernels run
shard-locally (see repro.fl.pod.ShardedFlatOps) without giving up the
FSDP×TP layout.  The view itself is pure data movement
(reshape/transpose), value-like and hashable; placement is the caller's
job (repro.sharding.rules builds the views and NamedShardings).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

Pytree = Any

# frozen leaves pack into buckets under this name prefix; the plain
# bucket name (dtype / dtype@axes) follows the prefix unchanged
FROZEN_PREFIX = "frozen:"


def is_frozen_bucket(name: str) -> bool:
    return name.startswith(FROZEN_PREFIX)


def _check_filter(filter, n_leaves: int):
    """Normalize a per-leaf trainable mask (None = all trainable)."""
    if filter is None:
        return None
    mask = tuple(bool(b) for b in filter)
    if len(mask) != n_leaves:
        raise ValueError(f"trainable filter has {len(mask)} entries for a "
                         f"{n_leaves}-leaf tree")
    return mask


@dataclasses.dataclass(frozen=True)
class LeafSlot:
    """One leaf's static slice of its dtype buffer."""
    buffer: str                 # canonical dtype name, e.g. "float32"
    offset: int                 # element offset into the buffer
    size: int                   # number of elements (1 for scalar leaves)
    shape: Tuple[int, ...]      # original leaf shape


@dataclasses.dataclass(frozen=True)
class FlatView:
    """Static packing plan for one pytree structure (see module doc)."""
    treedef: Any
    slots: Tuple[LeafSlot, ...]

    @classmethod
    def of(cls, tree: Pytree, filter=None) -> "FlatView":
        """Build a view from shapes/dtypes only — leaves may be tracers,
        ShapeDtypeStructs or concrete arrays.  ``filter`` is an optional
        per-leaf trainable mask (tree_flatten order): False routes the
        leaf into a ``frozen:``-prefixed bucket (see module doc)."""
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        mask = _check_filter(filter, len(leaves))
        sizes: Dict[str, int] = {}
        slots = []
        for i, leaf in enumerate(leaves):
            name = jnp.dtype(leaf.dtype).name
            if mask is not None and not mask[i]:
                name = FROZEN_PREFIX + name
            size = int(math.prod(leaf.shape))
            off = sizes.get(name, 0)
            slots.append(LeafSlot(buffer=name, offset=off, size=size,
                                  shape=tuple(leaf.shape)))
            sizes[name] = off + size
        return cls(treedef=treedef, slots=tuple(slots))

    # -- introspection ------------------------------------------------------

    @property
    def buffer_sizes(self) -> Dict[str, int]:
        """Total elements per TRAINABLE dtype buffer, first-seen order
        (everything the round program optimizes and communicates)."""
        sizes: Dict[str, int] = {}
        for s in self.slots:
            if not is_frozen_bucket(s.buffer):
                sizes[s.buffer] = s.offset + s.size
        return sizes

    @property
    def frozen_sizes(self) -> Dict[str, int]:
        """Total elements per frozen bucket ({} without a filter)."""
        sizes: Dict[str, int] = {}
        for s in self.slots:
            if is_frozen_bucket(s.buffer):
                sizes[s.buffer] = s.offset + s.size
        return sizes

    @property
    def total_size(self) -> int:
        return sum(self.buffer_sizes.values())

    def _check(self, tree: Pytree) -> list:
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        if treedef != self.treedef:
            raise ValueError(f"tree structure mismatch: {treedef} != "
                             f"{self.treedef}")
        return leaves

    # -- pack / unpack ------------------------------------------------------

    def flatten(self, tree: Pytree) -> Dict[str, jnp.ndarray]:
        """Pack ``tree``'s trainable leaves into ``{dtype_name: (total,)
        buffer}`` (all leaves without a filter)."""
        leaves = self._check(tree)
        parts: Dict[str, list] = {}
        for slot, leaf in zip(self.slots, leaves):
            if is_frozen_bucket(slot.buffer):
                continue
            parts.setdefault(slot.buffer, []).append(
                jnp.asarray(leaf).reshape(-1))
        return {name: jnp.concatenate(chunks)
                for name, chunks in parts.items()}

    def flatten_frozen(self, tree: Pytree) -> Dict[str, jnp.ndarray]:
        """Pack the FROZEN leaves into their ``frozen:`` buckets — the
        once-per-phase read-only constant dict ({} without a filter)."""
        leaves = self._check(tree)
        parts: Dict[str, list] = {}
        for slot, leaf in zip(self.slots, leaves):
            if not is_frozen_bucket(slot.buffer):
                continue
            parts.setdefault(slot.buffer, []).append(
                jnp.asarray(leaf).reshape(-1))
        return {name: jnp.concatenate(chunks)
                for name, chunks in parts.items()}

    def frozen_zeros(self) -> Dict[str, jnp.ndarray]:
        """Zero frozen buckets at their recorded dtypes — the fill-in
        for unflattening a trainable-only wrapper pytree (server
        moments) whose frozen slots have no values."""
        return {name: jnp.zeros((size,),
                                name[len(FROZEN_PREFIX):])
                for name, size in self.frozen_sizes.items()}

    def unflatten(self, bufs: Dict[str, jnp.ndarray],
                  frozen: Dict[str, jnp.ndarray] = None) -> Pytree:
        """Inverse of :meth:`flatten` (accepts buffers of any dtype —
        leaves are cast back to the slot's recorded dtype by reshape,
        not re-cast; pass matching dtypes for an exact round-trip).
        With a filter, ``frozen`` supplies the ``frozen:`` buckets
        (:meth:`flatten_frozen`); absent frozen buckets zero-fill."""
        if self.frozen_sizes:
            merged = dict(bufs)
            merged.update(frozen if frozen else self.frozen_zeros())
            bufs = merged
        leaves = [bufs[s.buffer][s.offset:s.offset + s.size].reshape(s.shape)
                  for s in self.slots]
        return jax.tree_util.tree_unflatten(self.treedef, leaves)

    # -- stacked variants (leading shared axis, e.g. (K, ...) clients) ------

    def flatten_stacked(self, tree: Pytree) -> Dict[str, jnp.ndarray]:
        """Pack a tree whose (trainable) leaves carry one shared leading
        axis K into ``{dtype_name: (K, total) buffers}``."""
        leaves = self._check(tree)
        parts: Dict[str, list] = {}
        for slot, leaf in zip(self.slots, leaves):
            if is_frozen_bucket(slot.buffer):
                continue
            leaf = jnp.asarray(leaf)
            parts.setdefault(slot.buffer, []).append(
                leaf.reshape(leaf.shape[0], -1))
        return {name: jnp.concatenate(chunks, axis=1)
                for name, chunks in parts.items()}

    def unflatten_stacked(self, bufs: Dict[str, jnp.ndarray],
                          frozen: Dict[str, jnp.ndarray] = None) -> Pytree:
        """Inverse of :meth:`flatten_stacked`.  With a filter, frozen
        slots broadcast the shared constant bucket (``frozen``, 1-D per
        :meth:`flatten_frozen`; zero-filled when absent) over the K
        axis — every row shares the same frozen base."""
        fz = None
        if self.frozen_sizes:
            fz = dict(frozen) if frozen else self.frozen_zeros()
        K = next(iter(bufs.values())).shape[0]
        leaves = []
        for s in self.slots:
            if is_frozen_bucket(s.buffer):
                row = fz[s.buffer][s.offset:s.offset + s.size].reshape(s.shape)
                leaves.append(jnp.broadcast_to(row[None], (K,) + s.shape))
                continue
            buf = bufs[s.buffer]
            leaves.append(buf[:, s.offset:s.offset + s.size].reshape(
                (buf.shape[0],) + s.shape))
        return jax.tree_util.tree_unflatten(self.treedef, leaves)

    # -- constructors over the same plan ------------------------------------

    def zeros(self, dtype=None) -> Dict[str, jnp.ndarray]:
        """Zero buffers with this view's sizes; ``dtype`` overrides the
        per-buffer dtype (e.g. an f32 delta accumulator over bf16
        params)."""
        return {name: jnp.zeros((size,), dtype or name)
                for name, size in self.buffer_sizes.items()}

    def normal(self, key) -> Dict[str, jnp.ndarray]:
        """Standard-normal f32 buffers over this plan, drawn PER LEAF:
        leaf ``i`` (tree_flatten order) draws with
        ``fold_in(key, i)`` at the leaf's original shape, then packs
        like :meth:`flatten`.  Keying and shaping the draws by leaf —
        not by buffer — makes the bits independent of the packing, so a
        tree-side twin (repro.fl.privacy.tree_normal) and the
        ShardedFlatView flavor produce the SAME values per parameter.
        Non-inexact (integer) slots draw zeros.  Frozen slots are never
        noised, masked or uploaded — they emit nothing (the per-leaf
        fold_in index stays the GLOBAL slot index, so a filtered view
        draws the same bits per trainable parameter as the full view)."""
        parts: Dict[str, list] = {}
        for i, slot in enumerate(self.slots):
            if is_frozen_bucket(slot.buffer):
                continue
            if jnp.issubdtype(jnp.dtype(slot.buffer), jnp.inexact):
                draw = jax.random.normal(jax.random.fold_in(key, i),
                                         slot.shape, jnp.float32)
            else:
                draw = jnp.zeros(slot.shape, jnp.float32)
            parts.setdefault(slot.buffer, []).append(draw.reshape(-1))
        return {name: jnp.concatenate(chunks)
                for name, chunks in parts.items()}


# ---------------------------------------------------------------------------
# sharded flat view — per-(dtype × mesh-axis-group) buffers
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShardedLeafSlot:
    """One leaf's static slice of its bucket, PER SHARD."""
    buffer: str                   # bucket name, e.g. "float32@data+model"
    offset: int                   # element offset within each shard row
    size: int                     # elements per shard for this leaf
    shape: Tuple[int, ...]        # global (unsharded) leaf shape
    # mesh axes tiling each dim, in the dim's tiling order (() = unsharded)
    dim_axes: Tuple[Tuple[str, ...], ...]


@dataclasses.dataclass(frozen=True)
class ShardGroup:
    """One bucket: all leaves of one dtype sharded over one axis set."""
    name: str
    dtype: str
    axes: Tuple[str, ...]         # canonical (mesh) order; () = replicated
    n_shards: int
    size: int                     # elements per shard (bucket total)


def _spec_entries(pspec, rank: int) -> Tuple[Tuple[str, ...], ...]:
    """Normalize a PartitionSpec-like into per-dim axis-name tuples,
    right-padded with () to the leaf rank."""
    entries = tuple(pspec) if pspec is not None else ()
    out = []
    for e in entries:
        if e is None:
            out.append(())
        elif isinstance(e, str):
            out.append((e,))
        else:
            out.append(tuple(e))
    out += [()] * (rank - len(out))
    return tuple(out[:rank])


@dataclasses.dataclass(frozen=True)
class ShardedFlatView:
    """Static packing plan bucketing leaves per (dtype, mesh-axis group).

    Each bucket's buffer is ``(n_shards, per_shard)``: axis 0 enumerates
    the shards of the group's mesh axes in canonical (mesh-order)
    row-major order, and every leaf owns the static per-shard slice
    ``[offset, offset + size)`` of axis 1 — so sharding axis 0 over the
    group's axes puts each leaf's local tile in one contiguous run of
    the device-local buffer.  flatten/unflatten are pure
    reshape/transpose data movement and work on tracers.
    """
    treedef: Any
    slots: Tuple[ShardedLeafSlot, ...]
    groups: Tuple[ShardGroup, ...]
    axis_sizes: Tuple[Tuple[str, int], ...]   # canonical order, all axes

    @classmethod
    def of(cls, tree: Pytree, pspecs: Pytree,
           axis_sizes: Dict[str, int], filter=None) -> "ShardedFlatView":
        """Build a view from leaf shapes/dtypes plus a matching
        PartitionSpec tree (e.g. repro.sharding.rules.param_pspecs).

        ``axis_sizes`` maps mesh axis name -> size, in canonical mesh
        order; size-1 axes never shard anything and are dropped, so the
        same rules produce bit-identical single-device views.
        ``filter`` is the per-leaf trainable mask (see
        :class:`FlatView`): frozen leaves bucket into
        ``frozen:``-prefixed groups that keep their (dtype × mesh-axis
        group) decomposition — the frozen base stays FSDP-sharded — but
        never appear in the trainable emitters."""
        from jax.sharding import PartitionSpec
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        mask = _check_filter(filter, len(leaves))
        spec_leaves, _ = jax.tree_util.tree_flatten(
            pspecs, is_leaf=lambda x: x is None or
            isinstance(x, PartitionSpec))
        if len(spec_leaves) != len(leaves):
            raise ValueError(
                f"pspec tree has {len(spec_leaves)} leaves for a "
                f"{len(leaves)}-leaf param tree")
        order = tuple(axis_sizes)
        cursor: Dict[str, int] = {}
        meta: Dict[str, Tuple[str, Tuple[str, ...], int]] = {}
        slots = []
        for i, (leaf, pspec) in enumerate(zip(leaves, spec_leaves)):
            shape = tuple(leaf.shape)
            dtype = jnp.dtype(leaf.dtype).name
            dim_axes = tuple(
                tuple(a for a in entry if axis_sizes.get(a, 1) > 1)
                for entry in _spec_entries(pspec, len(shape)))
            used = [a for entry in dim_axes for a in entry]
            if len(set(used)) != len(used):
                raise ValueError(f"mesh axis repeated in spec {pspec}")
            for dim, entry in zip(shape, dim_axes):
                n = math.prod(axis_sizes[a] for a in entry)
                if n > 1 and dim % n != 0:
                    raise ValueError(
                        f"dim {dim} not divisible by axes {entry} ({n})")
            axes = tuple(a for a in order if a in used)
            n_shards = math.prod(axis_sizes[a] for a in axes)
            name = dtype + ("@" + "+".join(axes) if axes else "")
            if mask is not None and not mask[i]:
                name = FROZEN_PREFIX + name
            size = int(math.prod(shape)) // max(n_shards, 1)
            off = cursor.get(name, 0)
            slots.append(ShardedLeafSlot(buffer=name, offset=off, size=size,
                                         shape=shape, dim_axes=dim_axes))
            cursor[name] = off + size
            meta[name] = (dtype, axes, n_shards)
        groups = tuple(ShardGroup(name=name, dtype=m[0], axes=m[1],
                                  n_shards=m[2], size=cursor[name])
                       for name, m in meta.items())
        return cls(treedef=treedef, slots=tuple(slots), groups=groups,
                   axis_sizes=tuple((a, int(axis_sizes[a])) for a in order))

    # -- introspection ------------------------------------------------------

    @property
    def group_map(self) -> Dict[str, ShardGroup]:
        return {g.name: g for g in self.groups}

    @property
    def trainable_groups(self) -> Tuple[ShardGroup, ...]:
        return tuple(g for g in self.groups if not is_frozen_bucket(g.name))

    @property
    def frozen_groups(self) -> Tuple[ShardGroup, ...]:
        return tuple(g for g in self.groups if is_frozen_bucket(g.name))

    @property
    def buffer_shapes(self) -> Dict[str, Tuple[int, int]]:
        return {g.name: (g.n_shards, g.size) for g in self.trainable_groups}

    @property
    def total_size(self) -> int:
        return sum(g.n_shards * g.size for g in self.trainable_groups)

    def _axis_size(self, name: str) -> int:
        return dict(self.axis_sizes)[name]

    # -- per-leaf shard transform ------------------------------------------

    def _perm_info(self, slot: ShardedLeafSlot):
        """(expanded shape, factor->front permutation, n_shards) for one
        leaf: every sharded dim splits into its axis factors, and the
        factors move to the front in canonical (mesh) order."""
        order = [a for a, _ in self.axis_sizes]
        expanded, factor_pos = [], {}
        for dim, entry in zip(slot.shape, slot.dim_axes):
            for a in entry:
                factor_pos[a] = len(expanded)
                expanded.append(self._axis_size(a))
                dim //= self._axis_size(a)
            expanded.append(dim)
        block_pos = [i for i in range(len(expanded))
                     if i not in factor_pos.values()]
        perm = [factor_pos[a] for a in order if a in factor_pos] + block_pos
        n_shards = math.prod(self._axis_size(a) for a in factor_pos)
        return expanded, perm, n_shards

    def _leaf_to_shards(self, leaf: jnp.ndarray,
                        slot: ShardedLeafSlot) -> jnp.ndarray:
        """(global leaf) -> (n_shards, per_shard) rows, shard-major in
        canonical axis order."""
        expanded, perm, n_shards = self._perm_info(slot)
        out = jnp.asarray(leaf).reshape(expanded).transpose(perm)
        return out.reshape(n_shards, slot.size)

    def _shards_to_leaf(self, rows: jnp.ndarray,
                        slot: ShardedLeafSlot) -> jnp.ndarray:
        expanded, perm, _ = self._perm_info(slot)
        inv = [perm.index(i) for i in range(len(perm))]
        mid = rows.reshape([expanded[i] for i in perm])
        return mid.transpose(inv).reshape(slot.shape)

    # -- pack / unpack ------------------------------------------------------

    def _check(self, tree: Pytree) -> list:
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        if treedef != self.treedef:
            raise ValueError(f"tree structure mismatch: {treedef} != "
                             f"{self.treedef}")
        return leaves

    def flatten(self, tree: Pytree) -> Dict[str, jnp.ndarray]:
        """Pack ``tree``'s trainable leaves into ``{bucket: (n_shards,
        per_shard)}`` (all leaves without a filter)."""
        leaves = self._check(tree)
        parts: Dict[str, list] = {}
        for slot, leaf in zip(self.slots, leaves):
            if is_frozen_bucket(slot.buffer):
                continue
            parts.setdefault(slot.buffer, []).append(
                self._leaf_to_shards(leaf, slot))
        return {name: jnp.concatenate(rows, axis=1)
                for name, rows in parts.items()}

    def flatten_frozen(self, tree: Pytree) -> Dict[str, jnp.ndarray]:
        """Pack the FROZEN leaves into their ``frozen:`` buckets, same
        per-group shard decomposition ({} without a filter)."""
        leaves = self._check(tree)
        parts: Dict[str, list] = {}
        for slot, leaf in zip(self.slots, leaves):
            if not is_frozen_bucket(slot.buffer):
                continue
            parts.setdefault(slot.buffer, []).append(
                self._leaf_to_shards(leaf, slot))
        return {name: jnp.concatenate(rows, axis=1)
                for name, rows in parts.items()}

    def frozen_zeros(self) -> Dict[str, jnp.ndarray]:
        """Zero frozen buckets at their recorded dtypes/shapes."""
        return {g.name: jnp.zeros((g.n_shards, g.size), g.dtype)
                for g in self.frozen_groups}

    def unflatten(self, bufs: Dict[str, jnp.ndarray],
                  frozen: Dict[str, jnp.ndarray] = None) -> Pytree:
        if self.frozen_groups:
            merged = dict(bufs)
            merged.update(frozen if frozen else self.frozen_zeros())
            bufs = merged
        leaves = [self._shards_to_leaf(
            bufs[s.buffer][:, s.offset:s.offset + s.size], s)
            for s in self.slots]
        return jax.tree_util.tree_unflatten(self.treedef, leaves)

    def zeros(self, dtype=None) -> Dict[str, jnp.ndarray]:
        """Zero buffers with this view's trainable shapes; ``dtype``
        overrides the per-bucket dtype (e.g. the pod's f32 delta
        accumulator)."""
        return {g.name: jnp.zeros((g.n_shards, g.size), dtype or g.dtype)
                for g in self.trainable_groups}

    def normal(self, key) -> Dict[str, jnp.ndarray]:
        """Standard-normal f32 buckets, drawn per leaf with
        ``fold_in(key, i)`` at the GLOBAL leaf shape and then
        shard-split — bit-identical per parameter to
        ``FlatView.normal`` / the tree twin for the same key, whatever
        the mesh layout (the draw precedes the pure-data-movement shard
        transform).  Non-inexact slots draw zeros; frozen slots emit
        nothing (fold_in keeps the global slot index, like
        ``FlatView.normal``)."""
        gm = self.group_map
        parts: Dict[str, list] = {}
        for i, slot in enumerate(self.slots):
            if is_frozen_bucket(slot.buffer):
                continue
            if jnp.issubdtype(jnp.dtype(gm[slot.buffer].dtype), jnp.inexact):
                draw = jax.random.normal(jax.random.fold_in(key, i),
                                         slot.shape, jnp.float32)
            else:
                draw = jnp.zeros(slot.shape, jnp.float32)
            parts.setdefault(slot.buffer, []).append(
                self._leaf_to_shards(draw, slot))
        return {name: jnp.concatenate(rows, axis=1)
                for name, rows in parts.items()}
