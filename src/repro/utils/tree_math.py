"""Pytree arithmetic used throughout the FL stack.

Federated algorithms are pytree algebra: weighted averages of client
models (FedAvg), model deltas (server momentum / SCAFFOLD control
variates), prox terms (FedProx), and parameter-space distances (Moon's
representation anchors, sharpness probes).  Everything here is pure and
jit-friendly.
"""
from __future__ import annotations

import math
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp

Pytree = Any


def tree_map(fn: Callable, *trees: Pytree) -> Pytree:
    return jax.tree_util.tree_map(fn, *trees)


def add(a: Pytree, b: Pytree) -> Pytree:
    return tree_map(jnp.add, a, b)


def sub(a: Pytree, b: Pytree) -> Pytree:
    return tree_map(jnp.subtract, a, b)


def scale(a: Pytree, s) -> Pytree:
    return tree_map(lambda x: x * s, a)


def add_scaled(a: Pytree, b: Pytree, s) -> Pytree:
    """a + s * b, fused per-leaf."""
    return tree_map(lambda x, y: x + s * y, a, b)


def zeros_like(a: Pytree) -> Pytree:
    return tree_map(jnp.zeros_like, a)


def ones_like(a: Pytree) -> Pytree:
    return tree_map(jnp.ones_like, a)


def weighted_mean(trees: Sequence[Pytree], weights: Sequence[float] | jnp.ndarray) -> Pytree:
    """FedAvg aggregation: sum_i w_i * tree_i / sum_i w_i."""
    w = jnp.asarray(weights, dtype=jnp.float32)
    total = jnp.sum(w)
    norm = w / total

    def combine(*leaves):
        acc = leaves[0] * norm[0]
        for i in range(1, len(leaves)):
            acc = acc + leaves[i] * norm[i]
        return acc

    return tree_map(combine, *trees)


def stacked_weighted_mean(stacked: Pytree, weights: jnp.ndarray) -> Pytree:
    """Aggregation over a leading client axis (vmapped client training).

    ``stacked`` leaves have shape (n_clients, ...); returns the weighted
    mean over axis 0.  This is the jit-friendly form used inside the
    simulation loop and maps directly onto a psum on hardware.
    """
    w = weights / jnp.sum(weights)

    def combine(leaf):
        wb = w.reshape((-1,) + (1,) * (leaf.ndim - 1)).astype(leaf.dtype)
        return jnp.sum(leaf * wb, axis=0)

    return tree_map(combine, stacked)


def dot(a: Pytree, b: Pytree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree_map(lambda x, y: jnp.vdot(x, y), a, b))
    return sum(leaves)


def squared_norm(a: Pytree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree_map(lambda x: jnp.vdot(x, x), a))
    return sum(leaves)


def norm(a: Pytree) -> jnp.ndarray:
    return jnp.sqrt(squared_norm(a))


def distance(a: Pytree, b: Pytree) -> jnp.ndarray:
    return norm(sub(a, b))


def cosine_similarity(a: Pytree, b: Pytree, eps: float = 1e-12) -> jnp.ndarray:
    return dot(a, b) / (norm(a) * norm(b) + eps)


def count_params(a: Pytree) -> int:
    return sum(int(math.prod(x.shape)) for x in jax.tree_util.tree_leaves(a))


def size_bytes(a: Pytree) -> int:
    return sum(int(math.prod(x.shape)) * x.dtype.itemsize for x in jax.tree_util.tree_leaves(a))


def cast(a: Pytree, dtype) -> Pytree:
    return tree_map(lambda x: x.astype(dtype), a)


def random_like(key: jax.Array, a: Pytree, scale_: float = 1.0) -> Pytree:
    """Gaussian tree with the same structure — used by sharpness probes."""
    leaves, treedef = jax.tree_util.tree_flatten(a)
    keys = jax.random.split(key, len(leaves))
    noise = [jax.random.normal(k, l.shape, l.dtype if jnp.issubdtype(l.dtype, jnp.floating) else jnp.float32) * scale_
             for k, l in zip(keys, leaves)]
    return jax.tree_util.tree_unflatten(treedef, noise)


def filter_normalize(direction: Pytree, reference: Pytree, eps: float = 1e-10) -> Pytree:
    """Filter-wise normalization from Li et al. (NeurIPS'18) loss-landscape
    visualization: scale each direction leaf to the norm of the reference
    leaf.  Used by the Fig-7 flatness probe."""

    def _norm_leaf(d, r):
        dn = jnp.linalg.norm(d.reshape(-1))
        rn = jnp.linalg.norm(r.reshape(-1))
        return d * (rn / (dn + eps))

    return tree_map(_norm_leaf, direction, reference)


def global_clip(a: Pytree, max_norm: float) -> Pytree:
    n = norm(a)
    factor = jnp.minimum(1.0, max_norm / (n + 1e-12))
    return scale(a, factor)
