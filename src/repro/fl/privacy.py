"""Security-critical FL primitives — DP-FedAvg clipping/noise and a
secure-aggregation simulation, shared by the host and pod backends.

The paper pitches CyclicFL as composable with "any security-critical FL
methods"; this module is that composition point.  Two mechanisms, both
applied to the ROUND's parameter aggregate (auxiliary algorithm state —
scaffold control variates, moon anchors — is deliberately not privatized;
only model deltas leave a client):

DP-FedAvg (:class:`DPSpec`)
    Each client's round delta ``δᵢ = wᵢ − w`` is clipped to the
    sensitivity bound ``C`` — ``scaleᵢ = min(1, C/(‖δᵢ‖+ε))`` — and the
    server adds Gaussian noise calibrated to ``σ·C``.  The aggregate is

        w⁺ = cast(w₃₂ + Σᵢ w̄ᵢ·scaleᵢ·δᵢ + Σᵢ w̄ᵢ·σC·zᵢ)

    With uniform weights the aggregated noise variance is ``σ²C²/K``
    per parameter (property-tested in tests/test_privacy.py).  On the
    fused path the clip scale FOLDS INTO the aggregation coefficient and
    the noise rides the ``extra`` operand of
    ``repro.kernels.fused_update.weighted_delta`` — privacy costs zero
    additional buffer traversals; ``dp_clip_noise`` is the standalone
    one-pass kernel form of the same upload for callers that materialize
    per-client uploads.

Secure-aggregation simulation (``secure_agg=True``)
    Pairwise masks from shared per-pair keys: clients ``i < j`` both
    derive ``z = normal(pair key)`` and add ``+z`` (lower id) / ``−z``
    (higher id) to their weighted uploads, so ``m_ij = −m_ji`` holds
    BITWISE and the mask total telescopes to zero over full
    participation — the server learns only the sum.  Masks are added
    AFTER client weighting (each client knows its own weight), so
    cancellation is exact under non-uniform weights too.

Key derivation (in-program, threefry): from the round key ``rk`` that
the engine already threads into every round body,

    noise key  (round, client i) : fold_in(fold_in(rk, DP_NOISE_TAG), i)
    mask key   (round, pair i<j) : fold_in(fold_in(fold_in(rk, MASK_TAG),
                                   lo), hi),  lo/hi = sorted(i, j)

and every per-model draw expands a client/pair key PER LEAF —
``fold_in(k, leaf_index)`` at the leaf's global shape — so the tree
oracle, the host FlatView buffers and the pod's mesh-sharded
ShardedFlatView buckets all draw IDENTICAL bits (the shard transform is
pure data movement after the draw).  Host and pod round bodies receive
the same ``rk`` under the parity sampling scheme, hence "host and pod
draw identical bits".
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

Pytree = Any

# fold_in tags separating the privacy key streams from the engine's
# client-key splits (and from each other)
DP_NOISE_TAG = 0x6470_0001      # "dp" noise stream
MASK_TAG = 0x6d61_0002          # "ma"sk pairwise stream

# matches the fused/tree step-tail clip epsilon (repro.fl.local)
CLIP_EPS = 1e-12


@dataclasses.dataclass(frozen=True)
class DPSpec:
    """Static DP-FedAvg parameters: clip bound ``C`` and noise
    multiplier ``σ`` (noise stddev ``σ·C`` per client pre-weighting).

    Frozen + hashable so it can ride ``LocalSpec`` through the engine's
    lru-cached strategy/chunk builders.  ``clip=inf`` with ``sigma=0``
    is the identity mechanism — the fused path then statically reduces
    to the exact baseline program (bitwise, tests/test_privacy.py).
    """
    clip: float
    sigma: float = 0.0

    def __post_init__(self):
        if not self.clip > 0.0:
            raise ValueError(f"DP clip bound must be positive, got "
                             f"{self.clip}")
        if self.sigma < 0.0:
            raise ValueError(f"DP noise multiplier must be >= 0, got "
                             f"{self.sigma}")
        if self.sigma > 0.0 and not math.isfinite(self.clip):
            raise ValueError("DP noise needs a finite clip bound "
                             "(the noise stddev is sigma*clip)")

    @property
    def clips(self) -> bool:
        """Whether clipping is a real (finite-bound) operation — the
        static switch that keeps the identity spec bitwise-exact."""
        return math.isfinite(self.clip)

    @property
    def noised(self) -> bool:
        return self.sigma > 0.0


def privacy_on(dp: Optional[DPSpec], secure_agg: bool) -> bool:
    """Whether the round aggregate needs the privacy-aware path at all."""
    return dp is not None or secure_agg


# ---------------------------------------------------------------------------
# key derivation
# ---------------------------------------------------------------------------

def noise_base_key(round_key: jax.Array) -> jax.Array:
    """Round-level base of the per-client DP noise stream."""
    return jax.random.fold_in(round_key, DP_NOISE_TAG)

def mask_base_key(round_key: jax.Array) -> jax.Array:
    """Round-level base of the pairwise mask stream."""
    return jax.random.fold_in(round_key, MASK_TAG)


def client_noise_key(noise_base: jax.Array, cid) -> jax.Array:
    return jax.random.fold_in(noise_base, cid)


def pair_mask_key(mask_base: jax.Array, a, b) -> jax.Array:
    """The SHARED key of pair (a, b) — order-independent (sorted ids),
    so both endpoints derive identical mask bits."""
    lo = jnp.minimum(a, b)
    hi = jnp.maximum(a, b)
    return jax.random.fold_in(jax.random.fold_in(mask_base, lo), hi)


def pair_sign(cid, other) -> jnp.ndarray:
    """+1 for the lower id, −1 for the higher, 0 for self — the sign
    convention that makes ``m_ij = −m_ji`` hold bitwise."""
    return jnp.sign(other - cid).astype(jnp.float32)


# ---------------------------------------------------------------------------
# per-leaf draws — the tree twin of FlatView.normal / ShardedFlatView.normal
# ---------------------------------------------------------------------------

def tree_normal(key: jax.Array, tree: Pytree) -> Pytree:
    """Standard-normal f32 tree over ``tree``'s shapes, leaf ``i``
    (tree_flatten order) drawn with ``fold_in(key, i)`` at the leaf's
    shape — bit-identical per parameter to the flat views' ``normal``
    for the same key.  Non-inexact leaves draw zeros."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    outs = []
    for i, leaf in enumerate(leaves):
        if jnp.issubdtype(jnp.dtype(leaf.dtype), jnp.inexact):
            outs.append(jax.random.normal(jax.random.fold_in(key, i),
                                          jnp.shape(leaf), jnp.float32))
        else:
            outs.append(jnp.zeros(jnp.shape(leaf), jnp.float32))
    return jax.tree_util.tree_unflatten(treedef, outs)


# ---------------------------------------------------------------------------
# clip scales
# ---------------------------------------------------------------------------

def clip_scale(dp: DPSpec, sq: jnp.ndarray) -> jnp.ndarray:
    """``min(1, C/(‖δ‖+ε))`` from a squared delta norm (any leading
    batch shape)."""
    return jnp.minimum(1.0, dp.clip / (jnp.sqrt(sq) + CLIP_EPS)) \
        .astype(jnp.float32)


def flat_delta_sqnorm(w_bufs: Dict[str, jnp.ndarray],
                      p_bufs: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    """‖w − p‖² over every bucket for ONE client (host 1-D buffers or
    pod ``(n_shards, per_shard)`` buckets — pad lanes are zero in both
    operands, so they contribute nothing)."""
    return sum(jnp.sum((w.astype(jnp.float32) -
                        p_bufs[name].astype(jnp.float32)) ** 2)
               for name, w in w_bufs.items())


def tree_delta_sqnorm(w_end: Pytree, params: Pytree) -> jnp.ndarray:
    """‖w − p‖² over every leaf for ONE client (tree impl)."""
    return sum(jnp.sum((w.astype(jnp.float32) -
                        p.astype(jnp.float32)) ** 2)
               for w, p in zip(jax.tree_util.tree_leaves(w_end),
                               jax.tree_util.tree_leaves(params)))


def stacked_clip_scales(dp: Optional[DPSpec], params_leaves,
                        stacked_leaves) -> Optional[jnp.ndarray]:
    """Per-client ``(K,)`` clip scales from stacked (K, ...) locals
    (leaf lists — shared by the tree and flat host aggregates).
    ``None`` when clipping is statically off (no spec / infinite C)."""
    if dp is None or not dp.clips:
        return None
    sq = sum(jnp.sum((wl.astype(jnp.float32) -
                      p.astype(jnp.float32)[None]) ** 2,
                     axis=tuple(range(1, wl.ndim)))
             for p, wl in zip(params_leaves, stacked_leaves))
    return clip_scale(dp, sq)


# ---------------------------------------------------------------------------
# the round's additive extra: Σᵢ (w̄ᵢ·σC·zᵢ + mᵢ)
# ---------------------------------------------------------------------------

def client_mask(mask_base: jax.Array, cid, ids: jnp.ndarray,
                normal_fn: Callable, zeros_fn: Callable) -> Pytree:
    """Client ``cid``'s secure-agg mask against participant set ``ids``:
    ``mᵢ = Σⱼ sign(idsⱼ − cid)·normal(pair key)``.  Antisymmetric by
    construction (shared pair keys + the sign convention), so the masks
    of a full participant set sum to zero up to float reassociation."""
    def one_pair(m, j):
        other = ids[j]
        z = normal_fn(pair_mask_key(mask_base, cid, other))
        s = pair_sign(cid, other)
        return jax.tree_util.tree_map(lambda a, b: a + s * b, m, z), None

    m, _ = jax.lax.scan(one_pair, zeros_fn(), jnp.arange(ids.shape[0]))
    return m


def round_extra(dp: Optional[DPSpec], secure_agg: bool,
                round_key: jax.Array, ids: jnp.ndarray,
                wbar: jnp.ndarray, *, zeros_fn: Callable,
                normal_fn: Callable) -> Optional[Pytree]:
    """The additive privacy term of one round's aggregate:
    ``Σᵢ (w̄ᵢ·σC·zᵢ + mᵢ)`` — per-client calibrated Gaussian noise plus
    the pairwise secure-agg masks — in whatever f32 representation
    ``zeros_fn``/``normal_fn`` speak (buffer dicts or trees).

    Returns None when both mechanisms are statically off, so the
    DP-off/identity program is untouched.  The masks are built per
    client (each pair drawn once from EACH endpoint, opposite signs) —
    the honest O(K²) simulation whose cancellation the tests assert,
    not an algebraic shortcut to zero."""
    noised = dp is not None and dp.noised
    if not noised and not secure_agg:
        return None
    nk = noise_base_key(round_key)
    mk = mask_base_key(round_key)

    def one_client(acc, i):
        cid = ids[i]
        if noised:
            z = normal_fn(client_noise_key(nk, cid))
            c = wbar[i] * (dp.sigma * dp.clip)
            acc = jax.tree_util.tree_map(lambda a, b: a + c * b, acc, z)
        if secure_agg:
            m = client_mask(mk, cid, ids, normal_fn, zeros_fn)
            acc = jax.tree_util.tree_map(jnp.add, acc, m)
        return acc, None

    extra, _ = jax.lax.scan(one_client, zeros_fn(),
                            jnp.arange(ids.shape[0]))
    return extra


# ---------------------------------------------------------------------------
# round aggregates (host engine) — tree oracle and fused twin
# ---------------------------------------------------------------------------

def tree_dp_aggregate(dp: Optional[DPSpec], secure_agg: bool,
                      key: jax.Array, ids: jnp.ndarray, params: Pytree,
                      w_locals: Pytree, weights: jnp.ndarray) -> Pytree:
    """The privacy-aware FedAvg aggregate over stacked (K, ...) local
    trees — the parity oracle for :func:`fused_dp_aggregate`:
    ``cast(p₃₂ + Σₖ w̄ₖ·scaleₖ·(wₖ − p) + extra)`` per leaf."""
    wbar = (weights / jnp.sum(weights)).astype(jnp.float32)
    scales = stacked_clip_scales(dp, jax.tree_util.tree_leaves(params),
                                 jax.tree_util.tree_leaves(w_locals))
    coeffs = wbar if scales is None else wbar * scales
    extra = round_extra(
        dp, secure_agg, key, ids, wbar,
        zeros_fn=lambda: jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params),
        normal_fn=lambda k: tree_normal(k, params))

    def leaf(p, wl, e):
        p32 = p.astype(jnp.float32)
        d = jnp.tensordot(coeffs, wl.astype(jnp.float32) - p32[None],
                          axes=1)
        if e is not None:
            d = d + e
        return (p32 + d).astype(p.dtype)

    if extra is None:
        return jax.tree_util.tree_map(lambda p, wl: leaf(p, wl, None),
                                      params, w_locals)
    return jax.tree_util.tree_map(leaf, params, w_locals, extra)


def fused_dp_aggregate(dp: Optional[DPSpec], secure_agg: bool, fops,
                       key: jax.Array, ids: jnp.ndarray,
                       p_bufs: Dict[str, jnp.ndarray],
                       stacked_bufs: Dict[str, jnp.ndarray],
                       weights: jnp.ndarray) -> Dict[str, jnp.ndarray]:
    """The same aggregate on the flat path: per-client clip scales fold
    into the aggregation coefficients and the noise/mask total rides the
    ``extra`` operand of ONE ``weighted_delta`` kernel pass per bucket.
    With the identity spec (``clip=inf, sigma=0, secure_agg=False``)
    every privacy term is STATICALLY absent and this is bitwise the
    baseline ``fused_aggregate`` program."""
    wbar = (weights / jnp.sum(weights)).astype(jnp.float32)
    scales = stacked_clip_scales(
        dp, [p_bufs[name] for name in stacked_bufs],
        [s for s in stacked_bufs.values()])
    coeffs = wbar if scales is None else wbar * scales
    extra = round_extra(dp, secure_agg, key, ids, wbar,
                        zeros_fn=lambda: fops.zeros(jnp.float32),
                        normal_fn=fops.normal)
    return fops.weighted_delta(p_bufs, stacked_bufs, coeffs, extra=extra)
