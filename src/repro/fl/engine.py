"""The federated round engine — ONE driver for both CyclicFL phases.

The paper's P1 (cyclic relay) and P2 (FedAvg-style rounds) are two
phases of one training process; this module is the single loop that runs
either, parameterized by a ``RoundStrategy``:

  RelayStrategy     : P1 — sequential ``lax.scan`` over the selected
                      clients carrying the model, NO aggregation
                      (Algorithm 1's server-relayed download/upload).
  AggregateStrategy : P2 — ``vmap`` over the selected clients + weighted
                      mean, with pluggable algorithm state for
                      fedavg / fedprox / scaffold / moon and an optional
                      server-side optimizer (FedAvgM / FedAdam).

The engine owns everything the three seed drivers each re-implemented:

  * client selection — ON DEVICE by default: a
    ``jax.random.permutation``-based without-replacement draw folded
    into the jitted round program (``sampling="host"`` reproduces the
    seed drivers' ``np.random.default_rng`` streams bit-for-bit for
    parity testing);
  * round chunking — ``lax.scan`` over a chunk of R rounds per XLA
    dispatch with donated carries, so the host dispatches once per
    chunk and losses come back as one stacked array.  Chunks never
    cross an eval boundary, so histories are chunk-size invariant;
  * the lr-decay schedule, eval cadence, ``CommLedger`` recording and
    history rows;
  * switch policies (core.switch) at any phase boundary — when a policy
    is installed the engine pins chunk=1 so per-round early exit keeps
    the seed drivers' semantics.

``core.cyclic.cyclic_pretrain`` and ``fl.simulation.run_federated`` are
thin shims over :func:`run_rounds`; ``core.pipeline`` sequences phases
declaratively.

Backend contract
----------------
The loop machinery above is generic over WHERE a round runs.  A strategy
is also a *backend*: three hooks (defaulted by :class:`HostBackend` to
the single-process jit path) decide how data, params and the compiled
chunk program are placed:

  prepare_data(data)            -> (x_all, y_all, n_real) device arrays;
                                   a sharded backend device_puts the
                                   stacked client arrays with mesh
                                   placements (see repro.fl.pod).
  place_params(params)          -> the engine's working copy of the
                                   model (host: plain copy so donation
                                   cannot invalidate the caller's tree;
                                   pod: device_put with
                                   rules.param_shardings).
  jit_chunk(chunk, task, n)     -> the compiled R-round program.  The
                                   host backend jits with donated
                                   carries only; the pod backend adds
                                   in_shardings/out_shardings for every
                                   carry so chunked dispatch runs as one
                                   SPMD program on the mesh.

ClientStateStore contract
-------------------------
Per-client algorithm state (SCAFFOLD control variates, Moon previous
local models) lives behind a ``ClientStateStore`` so its residency is a
backend decision, not an algorithm decision:

  init(template, n_clients)     -> stacked ``(n_clients, ...)`` state
  gather(state, ids)            -> the selected K rows (inside jit)
  shardings(p_specs, n, mesh)   -> placement tree for jit in_shardings
  scatter(state, ids, rows)     -> state with rows written back

``DenseClientStateStore`` keeps the dense host stacks (seed semantics);
``repro.fl.pod.ShardedClientStateStore`` shards the leading client axis
over the mesh ``data`` axis so scaffold/moon run at pod scale without a
replicated (n_clients, model) blow-up.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.federated import FederatedDataset
from repro.fl.local import LocalSpec, make_local_fn
from repro.fl.task import Task
from repro.utils import tree_math as tm

Pytree = Any

ALGORITHMS = ("fedavg", "fedprox", "scaffold", "moon")


# ---------------------------------------------------------------------------
# pytree helpers shared by the aggregation algorithms
# ---------------------------------------------------------------------------

def stack_copies(tree: Pytree, n: int) -> Pytree:
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (n,) + x.shape).copy(), tree)


def tree_rows(tree: Pytree, ids: jnp.ndarray) -> Pytree:
    return jax.tree_util.tree_map(lambda x: x[ids], tree)


def tree_set_rows(tree: Pytree, ids: jnp.ndarray, rows: Pytree) -> Pytree:
    return jax.tree_util.tree_map(lambda x, r: x.at[ids].set(r.astype(x.dtype)),
                                  tree, rows)


# ---------------------------------------------------------------------------
# backends + per-client state stores
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DenseClientStateStore:
    """Per-client state as dense host stacks — the seed representation.

    All three ops are jit-traceable; ``init`` runs eagerly once per
    engine run.  See the module docstring for the full contract.
    """

    def init(self, template: Pytree, n_clients: int) -> Pytree:
        return stack_copies(template, n_clients)

    def gather(self, state: Pytree, ids: jnp.ndarray) -> Pytree:
        return tree_rows(state, ids)

    def scatter(self, state: Pytree, ids: jnp.ndarray, rows: Pytree) -> Pytree:
        return tree_set_rows(state, ids, rows)

    def shardings(self, p_specs: Pytree, n_clients: int, mesh) -> Any:
        return None                     # host: no placement constraint


DENSE_STORE = DenseClientStateStore()


class HostBackend:
    """Default backend hooks: single-process jit, host-resident data."""

    def prepare_data(self, data: FederatedDataset):
        return data.device_arrays()

    def place_params(self, params: Pytree) -> Pytree:
        # donated carries: copy so the caller's init_params buffer survives
        return jax.tree_util.tree_map(jnp.array, params)

    def jit_chunk(self, chunk: Callable, task: Task,
                  n_clients: int) -> Callable:
        return jax.jit(chunk, donate_argnums=(0, 1, 2, 3))


# ---------------------------------------------------------------------------
# strategies
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RelayStrategy(HostBackend):
    """P1 — Algorithm 1's sequential relay.  The model hops client →
    client inside one scan; the carry IS the relay."""
    spec: LocalSpec
    participation: float = 0.25

    name = "relay"

    def n_selected(self, n_clients: int) -> int:
        return max(1, int(round(self.participation * n_clients)))

    def init_state(self, task: Task, params: Pytree, n_clients: int) -> Dict:
        return {}

    def make_server_update(self):
        return None

    def build_round(self, task: Task) -> Callable:
        local = make_local_fn(task, self.spec)

        def body(key, params, x_all, y_all, ids, weights, lr_scale, algo_state):
            del weights  # relay has no aggregation, hence no weighting
            cx = x_all[ids]                       # (K, n, ...)
            cy = y_all[ids]
            keys = jax.random.split(key, ids.shape[0])

            def relay(w, inp):
                k, cxi, cyi = inp
                w_next, aux = local(k, w, {}, cxi, cyi, lr_scale)
                return w_next, aux["loss"]

            params, losses = jax.lax.scan(relay, params, (keys, cx, cy))
            return params, algo_state, jnp.mean(losses)

        return body

    def record(self, ledger, k: int, params: Pytree) -> None:
        ledger.record_cyclic_round(k, params)


@dataclasses.dataclass(frozen=True)
class AggregateStrategy(HostBackend):
    """P2 — one federated round: vmapped local runs over the stacked
    client axis + weighted-mean aggregation, with per-algorithm state
    (scaffold control variates, moon previous-local models) carried
    through the engine's scan behind ``state_store``."""
    spec: LocalSpec
    algorithm: str = "fedavg"
    participation: float = 0.1
    server_opt: str = "none"        # none | momentum | adam
    server_lr: float = 1.0
    server_momentum: float = 0.9
    state_store: Any = DENSE_STORE

    @property
    def name(self) -> str:
        return self.algorithm

    def n_selected(self, n_clients: int) -> int:
        return max(1, int(round(self.participation * n_clients)))

    def init_state(self, task: Task, params: Pytree, n_clients: int) -> Dict:
        if self.algorithm == "scaffold":
            zeros = tm.zeros_like(params)
            return {"c_global": zeros,
                    "c_clients": self.state_store.init(zeros, n_clients)}
        if self.algorithm == "moon":
            return {"w_prev": self.state_store.init(params, n_clients)}
        return {}

    def make_server_update(self) -> Optional[Tuple[Callable, Callable]]:
        """Server-side optimizer (Reddi et al., adaptive federated
        optimization): pseudo-gradient g = w − w_avg.  Returns
        (init_fn, update_fn) or None for "none" (w ← w_avg exactly)."""
        if self.server_opt == "none":
            return None
        from repro.optim.optimizers import adamw, sgd
        if self.server_opt == "momentum":
            opt = sgd(self.server_lr, momentum=self.server_momentum)
        elif self.server_opt == "adam":
            opt = adamw(self.server_lr, b1=0.9, b2=0.99)
        else:
            raise ValueError(f"unknown server_opt {self.server_opt!r}")

        def update(params, avg_params, state):
            pseudo_grad = tm.sub(params, avg_params)
            return opt.apply(pseudo_grad, state, params)

        return opt.init, update

    def build_round(self, task: Task) -> Callable:
        spec = self.spec
        local = make_local_fn(task, spec)
        algo = self.algorithm
        store = self.state_store

        def body(key, params, x_all, y_all, ids, weights, lr_scale, algo_state):
            K = ids.shape[0]
            keys = jax.random.split(key, K)
            cx = x_all[ids]
            cy = y_all[ids]

            if algo in ("fedavg", "fedprox"):
                extras = {"w_global": params} if algo == "fedprox" else {}
                in_ext = jax.tree_util.tree_map(lambda _: None, extras)
                w_locals, aux = jax.vmap(
                    local, in_axes=(0, None, in_ext, 0, 0, None))(
                    keys, params, extras, cx, cy, lr_scale)
                new_params = tm.stacked_weighted_mean(w_locals, weights)
                return new_params, algo_state, jnp.mean(aux["loss"])

            if algo == "scaffold":
                c, c_all = algo_state["c_global"], algo_state["c_clients"]
                c_i = store.gather(c_all, ids)
                # per-client extras carry (c − c_i) with a leading K axis
                c_diff = jax.tree_util.tree_map(
                    lambda g, l: jnp.broadcast_to(g[None], l.shape) - l, c, c_i)
                extras = {"c_diff": c_diff}
                w_locals, aux = jax.vmap(
                    local, in_axes=(0, None, {"c_diff": 0}, 0, 0, None))(
                    keys, params, extras, cx, cy, lr_scale)
                # control-variate update (option II):
                # c_i⁺ = c_i − c + (w−w_i)/(S·lr)
                denom = spec.n_steps * spec.lr * lr_scale
                c_i_new = jax.tree_util.tree_map(
                    lambda ci, cg, w, wl: ci - cg[None] + (w[None] - wl) / denom,
                    c_i, c, params, w_locals)
                new_params = tm.stacked_weighted_mean(w_locals, weights)
                # c ← c + (K/N)·mean_i(c_i⁺ − c_i)
                n_clients = jax.tree_util.tree_leaves(c_all)[0].shape[0]
                frac = K / n_clients
                c_new = jax.tree_util.tree_map(
                    lambda cg, new, old: cg + frac * jnp.mean(new - old, axis=0),
                    c, c_i_new, c_i)
                c_all_new = store.scatter(c_all, ids, c_i_new)
                state = {"c_global": c_new, "c_clients": c_all_new}
                return new_params, state, jnp.mean(aux["loss"])

            if algo == "moon":
                w_prev_all = algo_state["w_prev"]
                w_prev = store.gather(w_prev_all, ids)
                extras = {"w_global": params, "w_prev": w_prev}
                w_locals, aux = jax.vmap(
                    local,
                    in_axes=(0, None, {"w_global": None, "w_prev": 0}, 0, 0, None))(
                    keys, params, extras, cx, cy, lr_scale)
                new_params = tm.stacked_weighted_mean(w_locals, weights)
                state = {"w_prev": store.scatter(w_prev_all, ids, w_locals)}
                return new_params, state, jnp.mean(aux["loss"])

            raise ValueError(f"unknown algorithm {algo!r}")

        return body

    def record(self, ledger, k: int, params: Pytree) -> None:
        ledger.record_round(self.algorithm, k, params)


# ---------------------------------------------------------------------------
# evaluation
# ---------------------------------------------------------------------------

def make_eval_fn(task: Task, batch: int) -> Callable:
    @jax.jit
    def eval_batch(params, bx, by):
        return task.accuracy(params, bx, by)

    def evaluate(params, test_x, test_y) -> float:
        n = len(test_y)
        accs, ws = [], []
        for s in range(0, n, batch):
            bx = jnp.asarray(test_x[s:s + batch])
            by = jnp.asarray(test_y[s:s + batch])
            accs.append(float(eval_batch(params, bx, by)))
            ws.append(len(by))
        return float(np.average(accs, weights=ws))

    return evaluate


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RoundSchedule:
    """Host-side schedule knobs shared by every strategy.

    sampling="device" draws the per-round client subset inside the jitted
    chunk program (``jax.random.permutation(k, n_clients)[:K]``);
    "host" reproduces the seed drivers' ``np.random.default_rng(seed +
    host_rng_offset)`` stream (the offset was 31 for P1, 17 for P2) and
    feeds the precomputed ids in as scan inputs.

    eval_every ≤ 0 disables evaluation entirely (benchmark mode);
    otherwise the engine evaluates every ``eval_every`` rounds and on
    the final round, exactly like the seed drivers.
    """
    rounds: int
    lr_decay: float = 0.998
    eval_every: int = 10
    eval_batch: int = 256
    seed: int = 0
    chunk_size: int = 1
    sampling: str = "device"        # device | host
    host_rng_offset: int = 0

    def __post_init__(self):
        if self.sampling not in ("device", "host"):
            raise ValueError(f"unknown sampling mode {self.sampling!r}")


@dataclasses.dataclass
class EngineResult:
    params: Pytree
    history: List[Dict[str, float]]
    algo_state: Dict[str, Pytree]
    server_state: Any = None


def make_chunk_fn(task: Task, strategy, schedule: RoundSchedule,
                  n_clients: int) -> Callable:
    """Build the jitted R-round program.

    signature: chunk_fn(key, params, algo_state, server_state,
                        x_all, y_all, n_real, ids, lr_scales)
               -> (key, params, algo_state, server_state, losses)
    The per-round keys are derived INSIDE the scan by the same
    ``key, rk = jax.random.split(key)`` recurrence the seed drivers ran
    on the host (threefry is deterministic, so the streams are
    bit-identical) — the host does zero per-round work.  lr_scales is
    the (R,)-stacked decay schedule, ids is (R, K) for host sampling or
    None for on-device sampling, and the four carries are donated so
    chunk i+1 reuses chunk i's buffers.

    Programs are cached on (task, strategy, sampling, n_clients) —
    Task and the strategies are frozen dataclasses — so repeated engine
    runs (benchmark sweeps, schedule phases reusing a config) skip
    retracing; jax.jit then caches per chunk length R underneath.
    """
    return _cached_chunk_fn(task, strategy, schedule.sampling, n_clients)


@functools.lru_cache(maxsize=64)
def _cached_chunk_fn(task: Task, strategy, sampling: str,
                     n_clients: int) -> Callable:
    body = strategy.build_round(task)
    server = strategy.make_server_update()
    on_device = sampling == "device"
    K = strategy.n_selected(n_clients)

    def chunk(key, params, algo_state, server_state, x_all, y_all, n_real,
              ids, lr_scales):
        def one_round(carry, xs):
            key, params, algo_state, server_state = carry
            ids_r, lr_scale = xs
            key, rk = jax.random.split(key)
            if on_device:
                k_sel, rk = jax.random.split(rk)
                ids_r = jax.random.permutation(k_sel, n_clients)[:K]
            weights = n_real[ids_r].astype(jnp.float32)
            new_params, algo_state, loss = body(
                rk, params, x_all, y_all, ids_r, weights, lr_scale, algo_state)
            if server is not None:
                new_params, server_state = server[1](params, new_params,
                                                     server_state)
            return (key, new_params, algo_state, server_state), loss

        (key, params, algo_state, server_state), losses = jax.lax.scan(
            one_round, (key, params, algo_state, server_state),
            (ids, lr_scales))
        return key, params, algo_state, server_state, losses

    return strategy.jit_chunk(chunk, task, n_clients)


def _rounds_until_eval(rnd: int, eval_every: int) -> int:
    if eval_every <= 0:
        return 1 << 30
    return eval_every - (rnd % eval_every)


def run_rounds(task: Task, data: FederatedDataset, strategy,
               schedule: RoundSchedule, *,
               init_params: Optional[Pytree] = None,
               ledger=None, verbose: bool = False,
               eval_fn: Optional[Callable] = None,
               switch_policy=None,
               phase: str = "P2",
               label: Optional[str] = None) -> EngineResult:
    """Run ``schedule.rounds`` rounds of ``strategy`` and return the
    final params plus the per-round history.

    The per-round key stream (split once per round from
    ``PRNGKey(schedule.seed)``) and the lr-decay scalars are derived on
    the host independently of chunking, so histories are invariant to
    ``chunk_size`` and, with sampling="host" + the right offset,
    bit-compatible with the seed drivers.
    """
    key = jax.random.PRNGKey(schedule.seed)
    params = init_params if init_params is not None else task.init(key)
    # backend hook: copy (host) or device_put with shardings (pod) so the
    # donated carries never invalidate the caller's init_params buffers
    params = strategy.place_params(params)

    n_clients = data.n_clients
    K = strategy.n_selected(n_clients)
    algo_state = strategy.init_state(task, params, n_clients)
    server = strategy.make_server_update()
    server_state = server[0](params) if server is not None else ()

    chunk_fn = make_chunk_fn(task, strategy, schedule, n_clients)
    evaluate = eval_fn or make_eval_fn(task, schedule.eval_batch)
    x_all, y_all, n_real = strategy.prepare_data(data)

    host_rng = None
    if schedule.sampling == "host":
        host_rng = np.random.default_rng(schedule.seed + schedule.host_rng_offset)

    label = label or getattr(strategy, "name", phase)
    # per-round switch decisions need per-round dispatch
    chunk = 1 if switch_policy is not None else max(1, schedule.chunk_size)

    history: List[Dict[str, float]] = []
    rnd = 0
    while rnd < schedule.rounds:
        R = min(chunk, schedule.rounds - rnd,
                _rounds_until_eval(rnd, schedule.eval_every))
        ids = None
        if host_rng is not None:
            ids = jnp.asarray(np.stack([
                host_rng.choice(n_clients, size=K, replace=False)
                for _ in range(R)]))
        lr_scales = jnp.asarray(
            [schedule.lr_decay ** (rnd + j) for j in range(R)], jnp.float32)

        key, params, algo_state, server_state, losses = chunk_fn(
            key, params, algo_state, server_state, x_all, y_all, n_real,
            ids, lr_scales)
        losses = np.asarray(losses)

        for j in range(R):
            if ledger is not None:
                strategy.record(ledger, K, params)
            history.append({"round": rnd + j, "local_loss": float(losses[j]),
                            "phase": phase})
        rnd += R

        if schedule.eval_every > 0 and (
                rnd % schedule.eval_every == 0 or rnd == schedule.rounds):
            row = history[-1]
            row["acc"] = evaluate(params, data.test_x, data.test_y)
            if verbose:
                print(f"[{label}] round {rnd}/{schedule.rounds} "
                      f"loss={row['local_loss']:.4f} acc={row['acc']:.4f}",
                      flush=True)
        if switch_policy is not None and switch_policy.should_switch(
                rnd - 1, history):
            break

    return EngineResult(params=params, history=history,
                        algo_state=algo_state, server_state=server_state)
