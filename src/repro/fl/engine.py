"""The federated round engine — ONE driver for both CyclicFL phases.

The paper's P1 (cyclic relay) and P2 (FedAvg-style rounds) are two
phases of one training process; this module is the single loop that runs
either, parameterized by a ``RoundStrategy``:

  RelayStrategy     : P1 — sequential ``lax.scan`` over the selected
                      clients carrying the model, NO aggregation
                      (Algorithm 1's server-relayed download/upload).
  AggregateStrategy : P2 — ``vmap`` over the selected clients + weighted
                      mean, with pluggable algorithm state for
                      fedavg / fedprox / scaffold / moon and an optional
                      server-side optimizer (FedAvgM / FedAdam) — on
                      BOTH backends: the pod shards the optimizer
                      moments exactly like the params they mirror.

Both the per-step client update and the per-round aggregation/server
step run either as per-leaf tree algebra (``update_impl="tree"``, the
parity oracle) or FLAT-FIRST (``update_impl="fused"``): the chunk
carries params and server-optimizer moments as contiguous FlatParamOps
buffers from phase start to phase end, the vmapped local outputs arrive
as already-stacked ``(K, N)`` buffers (no re-concatenate), and every
update stage is a blocked kernel per bucket
(repro.kernels.fused_update).  Trees materialize in exactly three
places: inside the loss closure (the model's forward/backward
boundary), at the in-program eval metric, and in the final
:class:`EngineResult` — the spec-level knob threads from LocalSpec
through every strategy, and the strategy's :meth:`flat_ops` picks the
buffer flavor (host FlatView; pod ShardedFlatView, see repro.fl.pod).

The engine owns everything the three seed drivers each re-implemented:

  * client selection — ON DEVICE by default: a
    ``jax.random.permutation``-based without-replacement draw folded
    into the jitted round program (``sampling="host"`` reproduces the
    seed drivers' ``np.random.default_rng`` streams bit-for-bit for
    parity testing);
  * round chunking — ``lax.scan`` over a chunk of R rounds per XLA
    dispatch with donated carries, so the host dispatches once per
    chunk and losses come back as one stacked array;
  * evaluation — IN PROGRAM: the chunk takes a per-round eval mask as a
    scan input and a pre-batched test stream as arguments, computes the
    eval metric under ``lax.cond`` on rounds where the mask is set
    (NaN-masked otherwise) and emits an (R,) metric stream next to the
    losses.  ``eval_every`` and ``chunk_size`` are therefore fully
    decoupled: evaluating runs cost zero extra dispatches, and
    histories stay chunk-size invariant because the mask is computed
    from global round indices on the host;
  * the lr-decay schedule, ``CommLedger`` recording and history rows;
  * switch policies (core.switch) at any phase boundary — when a policy
    is installed the engine pins chunk=1 so per-round early exit keeps
    the seed drivers' semantics.

``core.cyclic.cyclic_pretrain`` and ``fl.simulation.run_federated`` are
thin shims over :func:`run_rounds`; ``core.pipeline`` sequences phases
declaratively.

Backend contract
----------------
The loop machinery above is generic over WHERE a round runs.  A strategy
is also a *backend*: three hooks (defaulted by :class:`HostBackend` to
the single-process jit path) decide how data, params and the compiled
chunk program are placed:

  prepare_data(data)            -> (x_all, y_all, n_real) device arrays;
                                   a sharded backend device_puts the
                                   stacked client arrays with mesh
                                   placements (see repro.fl.pod).
  prepare_eval_data(batched)    -> (ev_x, ev_y, ev_w) device arrays for
                                   the in-program eval stream — the
                                   (n_batches, B, ...) batched test set
                                   plus the (n_batches, B) pad-validity
                                   weights (pod: batch axis sharded
                                   over (pod, data)).
  place_params(params)          -> the engine's working copy of the
                                   model (host: plain copy so donation
                                   cannot invalidate the caller's tree;
                                   pod: device_put with
                                   rules.param_shardings).
  place_server_state(state, t)  -> placement for the server-optimizer
                                   moments (host: identity; pod:
                                   device_put with param shardings so
                                   FedAvgM/FedAdam state shards like
                                   the params it mirrors).
  jit_chunk(chunk, task, n)     -> the compiled R-round program.  The
                                   host backend jits with donated
                                   carries only; the pod backend adds
                                   in_shardings/out_shardings for every
                                   carry so chunked dispatch runs as one
                                   SPMD program on the mesh.

ClientStateStore contract
-------------------------
Per-client algorithm state (SCAFFOLD control variates, Moon previous
local models) lives behind a ``ClientStateStore`` so its residency is a
backend decision, not an algorithm decision.  The state is an opaque
pytree owned by the store; the round body only ever sees the K selected
rows, which makes the stores representation-agnostic — the same store
holds tree rows on the tree path and flat ``(N,)`` buffer-dict rows on
the fused path:

  init(template, n_clients)     -> the store's state pytree (eager,
                                   once per engine run)
  gather(state, ids)            -> the selected K rows (inside jit)
  scatter(state, ids, rows)     -> state with rows written back
  population(state)             -> n_clients (the K/N scaffold fraction
                                   must count the population, not the
                                   store's physical rows)
  shardings(template, n, mesh)  -> placement pytree for jit
                                   in_shardings (None on the host)
  needs_host_ids                -> class attr; True if the store must
                                   see the NEXT dispatch's client ids
                                   before the chunk runs
  prepare_chunk(state, ids)     -> host-side residency step run between
                                   dispatches when ``needs_host_ids``
                                   (no-op for dense stores)

``DenseClientStateStore`` keeps the dense host stacks (seed semantics);
``SparseClientStateStore`` is the participation-indexed store — a
bounded ``(capacity, ...)`` active-set table plus an id→slot index,
with LRU eviction and host-spilled cold rows, so state memory scales
with *participation* (capacity) instead of population and million-client
populations fit where the dense stacks OOM.
``repro.fl.pod.ShardedClientStateStore`` /
``ShardedSparseClientStateStore`` shard the leading row axis over the
mesh ``data`` axis so scaffold/moon run at pod scale without a
replicated (n_clients, model) blow-up.
"""
from __future__ import annotations

import concurrent.futures
import dataclasses
import functools
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.federated import FederatedDataset
from repro.fl import compression, privacy
from repro.fl.local import (
    FlatParamOps,
    LocalSpec,
    effective_trainable_filter,
    host_flat_ops,
    make_local_fn,
)
from repro.fl.task import Task
from repro.kernels import ops
from repro.utils import tree_math as tm

Pytree = Any

ALGORITHMS = ("fedavg", "fedprox", "scaffold", "moon")

# FedAdam (server_opt="adam") moment decays — shared by the tree
# optimizer construction, the fused kernel call AND its bias-correction
# scalars, so the two implementations cannot drift apart
SERVER_ADAM_B1 = 0.9
SERVER_ADAM_B2 = 0.99


# ---------------------------------------------------------------------------
# pytree helpers shared by the aggregation algorithms
# ---------------------------------------------------------------------------

def stack_copies(tree: Pytree, n: int) -> Pytree:
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (n,) + x.shape).copy(), tree)


def tree_rows(tree: Pytree, ids: jnp.ndarray) -> Pytree:
    return jax.tree_util.tree_map(lambda x: x[ids], tree)


def tree_set_rows(tree: Pytree, ids: jnp.ndarray, rows: Pytree) -> Pytree:
    return jax.tree_util.tree_map(lambda x, r: x.at[ids].set(r.astype(x.dtype)),
                                  tree, rows)


def fused_aggregate(fops: FlatParamOps, p_bufs: Dict, stacked_bufs: Dict,
                    weights: jnp.ndarray) -> Dict:
    """FedAvg aggregation on the flat path: the vmapped flat local
    outputs are ALREADY the stacked ``(K, N)`` buffers (one per bucket),
    so aggregation is one blocked kernel per bucket
    (``ops.fused_weighted_delta``) with zero packing — the
    ``flatten_stacked`` re-concatenate of the PR-4 flow is gone."""
    wbar = (weights / jnp.sum(weights)).astype(jnp.float32)
    return fops.weighted_delta(p_bufs, stacked_bufs, wbar)


@functools.lru_cache(maxsize=64)
def _logical_model_bytes(task: Task) -> int:
    """X for the comm ledger: the LOGICAL model capacity from the task's
    param shapes — never the engine's carried representation, whose
    grid-padded flat buffers would over-count, and whose padding differs
    between P1/P2 and host/pod while the wire cost does not."""
    p_specs = jax.eval_shape(task.init, jax.random.PRNGKey(0))
    return tm.size_bytes(p_specs)


@functools.lru_cache(maxsize=64)
def _upload_payload_bytes(task: Task, comp,
                          filter_spec: Optional[str] = None) -> int:
    """Closed-form wire bytes of ONE client upload over the task's
    logical TRAINABLE flat bucket sizes (the accounting wire model on
    both backends — the pod's per-shard split carries the same logical
    elements).  With a trainable filter the sizes are the trainable
    slice only — frozen leaves never hit the wire — so the PEFT ratio
    composes multiplicatively with the compression ratio.  Uncompressed
    uploads count dtype-aware logical bytes (the bucket name IS the
    dtype), matching :func:`_logical_model_bytes` for ``filter=None``.
    """
    view = host_flat_ops(task, True, filter_spec).view
    if compression.compression_on(comp):
        return compression.payload_bytes(
            comp, tuple(view.buffer_sizes.values()))
    return int(sum(np.dtype(name).itemsize * size
                   for name, size in view.buffer_sizes.items()))


def unpack_server_state(fops: FlatParamOps, state: Any) -> Any:
    """Materialize a flat server OptState's moment buffers back into
    param-shaped trees (the EngineResult boundary)."""
    from repro.optim.optimizers import AdamWState, OptState
    if not isinstance(state, OptState):
        return state
    inner = state.inner
    if isinstance(inner, AdamWState):
        inner = AdamWState(mu=fops.unflatten(inner.mu),
                           nu=fops.unflatten(inner.nu))
    elif isinstance(inner, dict) and inner:
        inner = fops.unflatten(inner)
    return OptState(step=state.step, inner=inner)


# ---------------------------------------------------------------------------
# backends + per-client state stores
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DenseClientStateStore:
    """Per-client state as dense host stacks — the seed representation.

    gather/scatter are jit-traceable; ``init`` runs eagerly once per
    engine run.  See the module docstring for the full contract.  This
    store is the parity oracle for :class:`SparseClientStateStore`.
    """

    needs_host_ids = False

    def init(self, template: Pytree, n_clients: int) -> Pytree:
        return stack_copies(template, n_clients)

    def gather(self, state: Pytree, ids: jnp.ndarray) -> Pytree:
        return tree_rows(state, ids)

    def scatter(self, state: Pytree, ids: jnp.ndarray, rows: Pytree) -> Pytree:
        return tree_set_rows(state, ids, rows)

    def population(self, state: Pytree) -> int:
        return jax.tree_util.tree_leaves(state)[0].shape[0]

    def prepare_chunk(self, state: Pytree, ids_block) -> Pytree:
        return state                    # dense rows are always resident

    def shardings(self, template: Pytree, n_clients: int, mesh) -> Any:
        return None                     # host: no placement constraint


DENSE_STORE = DenseClientStateStore()


_SPILL_POOL: Optional[concurrent.futures.ThreadPoolExecutor] = None


def _spill_pool() -> concurrent.futures.ThreadPoolExecutor:
    """One background worker shared by every sparse store: spill blocks
    convert their device rows to numpy OFF the engine thread.  A single
    worker serializes the conversions, so at most one competes with the
    engine's dispatch enqueue for host cycles."""
    global _SPILL_POOL
    if _SPILL_POOL is None:
        _SPILL_POOL = concurrent.futures.ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="spill-materialize")
    return _SPILL_POOL


class _SpillBlock:
    """One dispatch's stacked evicted rows, parked on the CPU device by a
    single (async) batched transfer at commit time.  ``commit_chunk``
    submits the numpy materialization to a background worker
    (:meth:`materialize_async`) — the conversion blocks until the
    dispatch that produced the source table drains, so running it on the
    worker hides that wait AND the copy itself off the critical path; by
    the time a refault burst needs the rows in ``stage_chunk``,
    ``leaves()`` just joins the (usually finished) worker.  Blocks that
    were never submitted (direct construction in tests) keep the old
    lazy first-refault conversion."""

    __slots__ = ("rows", "_np", "_future")

    def __init__(self, rows):
        self.rows = rows                # list of (n_evicted, ...) leaves
        self._np = None
        self._future = None

    def materialize_async(self, meta: Optional[dict] = None) -> None:
        """Convert to numpy on the shared background worker; ``meta``
        (the owning store's ``_meta``) accumulates the off-thread ms
        under ``"spill_ms"`` — single-writer, the one pool worker."""
        if self._future is None and self._np is None:
            self._future = _spill_pool().submit(self._materialize, meta)

    def _materialize(self, meta: Optional[dict]):
        t0 = time.perf_counter()
        out = [np.asarray(leaf) for leaf in self.rows]
        if meta is not None:
            meta["spill_ms"] = meta.get("spill_ms", 0.0) + \
                (time.perf_counter() - t0) * 1e3
        self._np = out
        self.rows = None                # drop the device handles
        return out

    def leaves(self):
        f = self._future
        if f is not None:
            f.result()                  # join the background conversion
            self._future = None
        if self._np is None:
            self._np = [np.asarray(leaf) for leaf in self.rows]
            self.rows = None
        return self._np


@dataclasses.dataclass(frozen=True, eq=False)
class SparseClientStateStore:
    """Participation-indexed per-client state: a bounded active-set
    table instead of a dense population stack.

    The state pytree is ``{"table", "slot_of", "owner", "stamp"}``:
    ``table`` stacks ``capacity`` rows of the per-client template,
    ``slot_of`` is the ``(n_clients,)`` id→slot index (−1 = cold),
    ``owner``/``stamp`` the ``(capacity,)`` slot→id back-map and LRU
    clock.  gather/scatter run inside jit over *slots* — O(capacity)
    device memory however large the population — while residency is
    managed eagerly between dispatches in two halves:

      stage_chunk(ids_block) -> staged   (host planning + async H2D)
      commit_chunk(state, staged) -> state  (device-side splice, enqueued)

    :meth:`stage_chunk` plans against HOST MIRRORS of the residency
    index (kept in ``_meta``), so it never reads — and never blocks
    on — the device carries of an in-flight dispatch: the engine's
    overlapped loop stages dispatch N+1 while dispatch N is still
    executing.  Cold participants fault in from the spill dict (evicting
    the least-recently-used non-participating slots); the refill rows
    are stacked into a reused pinned staging buffer and shipped as ONE
    ``jax.device_put`` per template leaf, without ``block_until_ready``.
    :meth:`commit_chunk` then enqueues one batched spill gather of the
    evicted live rows (reading the LATEST table, so rows written by the
    previous dispatch spill with their updates, async-copied to the CPU
    device) and splices the staged rows plus the index updates in —
    pure functional device ops, nothing blocks.  ``prepare_chunk``
    composes the two for the synchronous path, so the classic contract
    is unchanged; ``spill=False`` drops evicted rows instead — a
    documented *forgetful* mode that trades parity for zero host
    traffic.

    ``capacity`` must cover the distinct participants of one dispatch
    (chunk_size × K in the worst case); stage_chunk raises otherwise.
    Eager members (the spill dict, the mirrors, the staging buffers)
    make this store identity-hashed (``eq=False``), which is exactly
    what the chunk cache wants — two stores are two cache entries.
    """

    capacity: int
    spill: bool = True
    _cold: dict = dataclasses.field(default_factory=dict, repr=False)
    _meta: dict = dataclasses.field(default_factory=dict, repr=False)

    needs_host_ids = True

    def __post_init__(self):
        if self.capacity < 1:
            raise ValueError("SparseClientStateStore capacity must be >= 1")

    def init(self, template: Pytree, n_clients: int) -> Pytree:
        leaves, treedef = jax.tree_util.tree_flatten(template)
        self._cold.clear()
        cap = max(1, min(self.capacity, n_clients))
        self._meta["treedef"] = treedef
        self._meta["template"] = [np.asarray(leaf) for leaf in leaves]
        # host mirrors of the residency index: stage_chunk plans against
        # these, so planning never synchronizes with the device
        self._meta["slot_of"] = np.full((n_clients,), -1, np.int32)
        self._meta["owner"] = np.full((cap,), -1, np.int32)
        self._meta["stamp"] = np.zeros((cap,), np.int32)
        self._meta["stage_bufs"] = None
        self._meta["transfer_ms"] = 0.0
        self._meta["spill_ms"] = 0.0
        return {
            "table": stack_copies(template, cap),
            "slot_of": jnp.full((n_clients,), -1, jnp.int32),
            "owner": jnp.full((cap,), -1, jnp.int32),
            "stamp": jnp.zeros((cap,), jnp.int32),
        }

    def gather(self, state: Pytree, ids: jnp.ndarray) -> Pytree:
        # residency is a precondition: prepare_chunk ran for these ids
        return tree_rows(state["table"], state["slot_of"][ids])

    def scatter(self, state: Pytree, ids: jnp.ndarray, rows: Pytree) -> Pytree:
        slots = state["slot_of"][ids]
        return dict(state, table=tree_set_rows(state["table"], slots, rows))

    def population(self, state: Pytree) -> int:
        return state["slot_of"].shape[0]

    def shardings(self, template: Pytree, n_clients: int, mesh) -> Any:
        return None                     # host flavor: no constraint

    @property
    def staged_transfer_ms(self) -> float:
        """Cumulative wall time spent enqueueing refill transfers."""
        return float(self._meta.get("transfer_ms", 0.0))

    @property
    def spill_materialize_ms(self) -> float:
        """Cumulative background time converting spilled rows to numpy —
        host ms moved OFF the stage/commit critical path (satellite of
        the overlapped pipeline: a refault burst no longer pays the
        device→numpy conversion inside ``stage_chunk``)."""
        return float(self._meta.get("spill_ms", 0.0))

    # -- host-side residency (eager, between dispatches) --------------------

    def _pop_cold(self, cid: int):
        row = self._cold.pop(cid, None)
        if isinstance(row, tuple):      # lazy ref into a spill block
            block, j = row
            return [leaf[j] for leaf in block.leaves()]
        return row

    def _cold_row(self, cid: int):
        row = self._cold.get(cid)
        if isinstance(row, tuple):
            block, j = row
            return [leaf[j] for leaf in block.leaves()]
        return row

    def _stage_rows(self, fill):
        """Stack the refill rows into a pinned staging buffer (grown
        geometrically, reused across dispatches — safe because at most
        one staged plan exists at a time and ``jax.device_put`` copies
        out of numpy before returning)."""
        tmpl = self._meta["template"]
        if not tmpl:
            return []
        n = len(fill)
        bufs = self._meta.get("stage_bufs")
        if bufs is None or bufs[0].shape[0] < n:
            rows_cap = max(n, 2 * (bufs[0].shape[0] if bufs else 4))
            bufs = [np.empty((rows_cap,) + t.shape, t.dtype) for t in tmpl]
            self._meta["stage_bufs"] = bufs
        for j, row in enumerate(fill):
            for i in range(len(tmpl)):
                bufs[i][j] = row[i]
        return [buf[:n] for buf in bufs]

    def _refill_placement(self, victims: np.ndarray):
        return None                     # host flavor: default device

    def stage_chunk(self, ids_block) -> Dict[str, Any]:
        """Plan residency for the NEXT dispatch and start its refill
        transfer — host work only, against the mirror index, so it can
        run while the previous dispatch is still executing on device."""
        ids = np.unique(np.asarray(ids_block))
        slot_of = self._meta["slot_of"]
        owner = self._meta["owner"]
        stamp = self._meta["stamp"]
        cap = owner.shape[0]
        slots_ids = slot_of[ids]
        miss = ids[slots_ids < 0]
        staged: Dict[str, Any] = {"victims": None}
        if miss.size:
            resident = slots_ids[slots_ids >= 0]
            cand = np.setdiff1d(np.arange(cap), resident)
            # free slots first, then coldest-first among the owned ones
            order = np.argsort(np.where(owner[cand] < 0, -1, stamp[cand]),
                               kind="stable")
            cand = cand[order]
            if miss.size > cand.size:
                raise ValueError(
                    f"store capacity {cap} cannot hold the {ids.size} "
                    f"distinct clients of the next dispatch "
                    f"({miss.size} cold, {cand.size} evictable slots) — "
                    f"raise --store-capacity above chunk_size × K")
            # sorted victims keep the staged rows in slot order, so a
            # sharded flavor can land each row on its owning shard
            victims = np.sort(cand[:miss.size])
            evicted = owner[victims].copy()
            # refill: spilled row if the client was seen before, else
            # the init template
            tmpl = self._meta["template"]
            fill = [self._pop_cold(int(cid)) or tmpl for cid in miss]
            rows_np = self._stage_rows(fill)
            t0 = time.perf_counter()
            placement = self._refill_placement(victims)
            rows_dev = [jax.device_put(r) if placement is None
                        else jax.device_put(r, s)
                        for r, s in zip(rows_np, _broadcast(placement,
                                                            len(rows_np)))]
            self._meta["transfer_ms"] += (time.perf_counter() - t0) * 1e3
            gone = evicted[evicted >= 0]
            slot_of[gone] = -1
            slot_of[miss] = victims
            owner[victims] = miss
            staged.update(victims=victims, miss=miss, gone=gone,
                          evicted=evicted, rows=rows_dev)
        # touch every participant's slot so the LRU order tracks rounds
        touch = int(stamp.max()) + 1
        slots = slot_of[ids]
        stamp[slots] = touch
        staged.update(touch_slots=slots.copy(), touch_value=touch)
        return staged

    def commit_chunk(self, state: Pytree, staged: Dict[str, Any]) -> Pytree:
        """Apply a staged plan to the device-side state.  Everything here
        is an enqueued functional update on the carry handles — spilling
        gathers from the LATEST table (the output of the dispatch that
        last wrote it) in one stacked transfer, and the staged refill
        rows splice in with one scatter — so committing on top of an
        in-flight chunk's outputs just extends the device queue."""
        table, slot_of = state["table"], state["slot_of"]
        owner, stamp = state["owner"], state["stamp"]
        victims = staged["victims"]
        if victims is not None:
            evicted = staged["evicted"]
            live = evicted >= 0
            if self.spill and np.any(live):
                rows = tree_rows(table, jnp.asarray(victims[live]))
                try:                    # cold rows park on the CPU device
                    rows = jax.device_put(rows, jax.devices("cpu")[0])
                except RuntimeError:
                    pass                # no CPU device: plain device refs
                block = _SpillBlock(jax.tree_util.tree_leaves(rows))
                # eager off-thread materialization: the conversion waits
                # for the in-flight dispatch on the WORKER, not here
                block.materialize_async(self._meta)
                for j, cid in enumerate(evicted[live]):
                    self._cold[int(cid)] = (block, j)
            rows_tree = jax.tree_util.tree_unflatten(
                self._meta["treedef"], [jnp.asarray(r)
                                        for r in staged["rows"]])
            table = tree_set_rows(table, jnp.asarray(victims), rows_tree)
            gone = staged["gone"]
            if gone.size:
                slot_of = slot_of.at[jnp.asarray(gone)].set(-1)
            slot_of = slot_of.at[jnp.asarray(staged["miss"])].set(
                jnp.asarray(victims, jnp.int32))
            owner = owner.at[jnp.asarray(victims)].set(
                jnp.asarray(staged["miss"], jnp.int32))
        stamp = stamp.at[jnp.asarray(staged["touch_slots"])].set(
            jnp.int32(staged["touch_value"]))
        return {"table": table, "slot_of": slot_of,
                "owner": owner, "stamp": stamp}

    def prepare_chunk(self, state: Pytree, ids_block) -> Pytree:
        return self.commit_chunk(state, self.stage_chunk(ids_block))

    # -- debugging / parity helper ------------------------------------------

    def to_dense(self, state: Pytree) -> Pytree:
        """Materialize the full ``(n_clients, ...)`` stack (hot rows from
        the table, cold rows from the spill dict, template otherwise) —
        test/debug only; defeats the point at scale."""
        slot_of = np.asarray(state["slot_of"])
        n = slot_of.shape[0]
        tmpl = self._meta["template"]
        table_leaves = [np.asarray(leaf) for leaf
                        in jax.tree_util.tree_leaves(state["table"])]
        out = [np.broadcast_to(leaf, (n,) + leaf.shape).copy()
               for leaf in tmpl]
        for cid in range(n):
            slot = slot_of[cid]
            row = table_leaves if slot >= 0 else self._cold_row(cid)
            if row is None:
                continue
            for i in range(len(out)):
                out[i][cid] = row[i][slot] if slot >= 0 else row[i]
        return jax.tree_util.tree_unflatten(
            self._meta["treedef"], [jnp.asarray(o) for o in out])


def _broadcast(placement, n: int):
    """Per-leaf placements for the staged refill transfer: a list is
    taken as-is, anything else repeats for every leaf."""
    if isinstance(placement, (list, tuple)):
        return list(placement)
    return [placement] * n


def _replay_device_sampling(key, n_clients: int, K: int, R: int):
    """Replay the chunk's in-program client draws on the host: the chunk
    derives round r's selection key by the fixed split recurrence below
    (see ``_cached_chunk_fn.one_round``), and threefry is deterministic,
    so the replay is bit-identical to what the next dispatch will draw.
    Sparse stores use this under ``sampling="device"`` to fault rows in
    *before* the chunk runs — residency only, the program itself still
    draws its ids in-program, unchanged.  Costs O(R · n_clients) host
    work per chunk; prefer ``sampling="host"`` at very large n_clients.

    Returns ``(ids, key_after)`` — the advanced key lets the overlapped
    loop replay chunk N+1's draws before chunk N's carried key exists as
    anything but an in-flight device handle.
    """
    out = []
    for _ in range(R):
        key, rk = jax.random.split(key)
        k_sel, _ = jax.random.split(rk)
        out.append(np.asarray(jax.random.permutation(k_sel, n_clients)[:K]))
    return np.stack(out), key


class HostBackend:
    """Default backend hooks: single-process jit, host-resident data."""

    def flat_ops(self, task: Task):
        """The strategy's flat-buffer representation, or None on the
        tree path.  When set, the engine's chunk carries params and
        server moments as this object's buffer dicts (flat-first); the
        pod backend overrides it with mesh-sharded buffers."""
        if self.spec.update_impl == "tree":
            return None
        return host_flat_ops(task, ops.fused_interpret(self.spec.update_impl),
                             effective_trainable_filter(self.spec))

    def prepare_data(self, data: FederatedDataset):
        return data.device_arrays()

    def prepare_eval_data(self, batched: Tuple) -> Tuple:
        return tuple(jnp.asarray(a) for a in batched)

    def place_params(self, params: Pytree) -> Pytree:
        # donated carries: copy so the caller's init_params buffer survives
        return jax.tree_util.tree_map(jnp.array, params)

    def place_server_state(self, state: Pytree, task: Task) -> Pytree:
        return state

    def prepare_chunk_state(self, algo_state: Dict, ids_block) -> Dict:
        """Hook run before every chunk dispatch when the strategy's
        store needs host-side residency management (see the
        ClientStateStore contract); the default is a no-op."""
        return algo_state

    def stage_chunk_state(self, ids_block) -> Any:
        """First half of :meth:`prepare_chunk_state`: host planning +
        async staging transfers only, no device-state reads — safe to
        run while the previous dispatch is still executing.  Returns an
        opaque token for :meth:`commit_chunk_state` (None = nothing to
        do)."""
        return None

    def commit_chunk_state(self, algo_state: Dict, staged: Any) -> Dict:
        """Second half: splice a staged plan into the (possibly still
        in-flight) algo-state carry.  Must be enqueue-only."""
        return algo_state

    def jit_chunk(self, chunk: Callable, task: Task,
                  n_clients: int) -> Callable:
        return jax.jit(chunk, donate_argnums=(0, 1, 2, 3))


# ---------------------------------------------------------------------------
# strategies
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RelayStrategy(HostBackend):
    """P1 — Algorithm 1's sequential relay.  The model hops client →
    client inside one scan; the carry IS the relay."""
    spec: LocalSpec
    participation: float = 0.25

    name = "relay"

    def __post_init__(self):
        # P1 has no aggregation step: there is nothing to clip, noise or
        # mask, so a privacy spec on the relay is a config error
        if self.spec.dp is not None or self.spec.secure_agg:
            raise ValueError("RelayStrategy (P1) has no aggregation; "
                             "dp/secure_agg apply to P2 only")
        # ... and the relayed model IS the next client's start state, so
        # a lossy upload would corrupt training, not just the aggregate
        if compression.compression_on(self.spec.compression):
            raise ValueError("RelayStrategy (P1) relays the model itself; "
                             "lossy compression applies to P2 round "
                             "deltas only")
        # ... and the relay hops the FULL model client → client — a
        # trainable-slice filter would freeze most of what P1 exists to
        # pre-train, so it is a config error here (the pod launcher
        # strips it for P1 like dp/compression)
        if self.spec.peft is not None or self.spec.trainable_filter is not None:
            raise ValueError("RelayStrategy (P1) relays the full model; "
                             "peft/trainable_filter applies to P2 rounds "
                             "only")

    def n_selected(self, n_clients: int) -> int:
        return max(1, int(round(self.participation * n_clients)))

    def init_state(self, task: Task, params: Pytree, n_clients: int) -> Dict:
        return {}

    def make_server_update(self, task: Optional[Task] = None):
        return None

    def build_round(self, task: Task) -> Callable:
        # the relay body is representation-agnostic: the scan carry is
        # whatever `local` consumes — param trees on the tree path, flat
        # buffer dicts on the fused path
        local = make_local_fn(task, self.spec, self.flat_ops(task))

        def body(key, params, x_all, y_all, ids, weights, lr_scale, algo_state,
                 frozen=None):
            del weights  # relay has no aggregation, hence no weighting
            cx = x_all[ids]                       # (K, n, ...)
            cy = y_all[ids]
            keys = jax.random.split(key, ids.shape[0])

            def relay(w, inp):
                k, cxi, cyi = inp
                w_next, aux = local(k, w, {}, cxi, cyi, lr_scale, frozen)
                return w_next, aux["loss"]

            params, losses = jax.lax.scan(relay, params, (keys, cx, cy))
            return params, algo_state, jnp.mean(losses)

        return body

    def record(self, ledger, k: int, params: Pytree, task=None) -> None:
        x = _logical_model_bytes(task) if task is not None else None
        ledger.record_cyclic_round(k, params, x_bytes=x)


@dataclasses.dataclass(frozen=True)
class AggregateStrategy(HostBackend):
    """P2 — one federated round: vmapped local runs over the stacked
    client axis + weighted-mean aggregation, with per-algorithm state
    (scaffold control variates, moon previous-local models) carried
    through the engine's scan behind ``state_store``."""
    spec: LocalSpec
    algorithm: str = "fedavg"
    participation: float = 0.1
    server_opt: str = "none"        # none | momentum | adam
    server_lr: float = 1.0
    server_momentum: float = 0.9
    state_store: Any = DENSE_STORE

    @property
    def name(self) -> str:
        return self.algorithm

    def n_selected(self, n_clients: int) -> int:
        return max(1, int(round(self.participation * n_clients)))

    # the store key each algorithm keeps its per-client rows under
    _STORE_KEYS = {"scaffold": "c_clients", "moon": "w_prev"}

    @functools.cached_property
    def _ef_store(self):
        """A FRESH store instance for the error-feedback residual rows —
        sparse stores keep eager per-pytree residency state (host
        mirrors, the spill dict), so the algorithm rows and the residual
        rows cannot share one instance.  Dense stores are stateless and
        reused as-is."""
        store = self.state_store
        if isinstance(store, SparseClientStateStore):
            return dataclasses.replace(store, _cold={}, _meta={})
        return store

    def _residency_entries(self):
        """``(algo_state key, store)`` pairs carrying per-client rows —
        the algorithm's own state plus, under compressed communication
        with error feedback, the residual rows.  Order is stable; the
        staged-token lists below index into it."""
        out = []
        key = self._STORE_KEYS.get(self.algorithm)
        if key is not None:
            out.append((key, self.state_store))
        comp = self.spec.compression
        if compression.compression_on(comp) and comp.error_feedback:
            out.append(("ef_residuals", self._ef_store))
        return out

    @property
    def residency_stores(self):
        """Every store instance holding per-client rows (engine timing
        aggregates their transfer/materialization counters)."""
        return [s for _, s in self._residency_entries()]

    def init_state(self, task: Task, params: Pytree, n_clients: int) -> Dict:
        # flat-first: ``params`` arrive as the engine's placed flat
        # buffers, so the per-client state is flat too — the store is
        # representation-agnostic and the round bodies below run the
        # scaffold/moon state algebra directly on the (K, N) row buffers
        fops = self.flat_ops(task)
        state: Dict[str, Pytree] = {}
        if self.algorithm == "scaffold":
            zeros = fops.zeros() if fops is not None else tm.zeros_like(params)
            state = {"c_global": zeros,
                     "c_clients": self.state_store.init(zeros, n_clients)}
        elif self.algorithm == "moon":
            state = {"w_prev": self.state_store.init(params, n_clients)}
        comp = self.spec.compression
        if compression.compression_on(comp) and comp.error_feedback:
            # error-feedback residuals are per-client f32 rows in the
            # engine's flat bucket layout on BOTH paths (compression is
            # defined on the flat buckets): padded carry buffers on the
            # fused path, the host FlatView's logical buckets on tree
            tmpl = (fops.zeros(jnp.float32) if fops is not None
                    else host_flat_ops(task, True).view.zeros(jnp.float32))
            state["ef_residuals"] = self._ef_store.init(tmpl, n_clients)
        return state

    def prepare_chunk_state(self, algo_state: Dict, ids_block) -> Dict:
        out = algo_state
        for key, store in self._residency_entries():
            if not getattr(store, "needs_host_ids", False):
                continue
            out = dict(out, **{key: store.prepare_chunk(out[key], ids_block)})
        return out

    def stage_chunk_state(self, ids_block) -> Any:
        toks = []
        for key, store in self._residency_entries():
            if not getattr(store, "needs_host_ids", False):
                toks.append(None)
            elif hasattr(store, "stage_chunk"):
                toks.append(("staged", key, store.stage_chunk(ids_block)))
            else:
                # stores without a staged contract degrade gracefully:
                # remember the ids and run the classic synchronous
                # prepare at commit time
                toks.append(("ids", key, np.asarray(ids_block)))
        return toks if any(t is not None for t in toks) else None

    def commit_chunk_state(self, algo_state: Dict, staged: Any) -> Dict:
        if staged is None:
            return algo_state
        out = dict(algo_state)
        stores = dict(self._residency_entries())
        for tok in staged:
            if tok is None:
                continue
            tag, key, val = tok
            if tag == "ids":
                out[key] = stores[key].prepare_chunk(out[key], val)
            else:
                out[key] = stores[key].commit_chunk(out[key], val)
        return out

    def make_server_update(self, task: Optional[Task] = None
                           ) -> Optional[Tuple[Callable, Callable]]:
        """Server-side optimizer (Reddi et al., adaptive federated
        optimization): pseudo-gradient g = w − w_avg.  Returns
        (init_fn, update_fn) or None for "none" (w ← w_avg exactly).

        On the tree path both functions speak param trees (the optax
        style ``repro.optim.optimizers`` pair).  With
        ``update_impl="fused"`` the WHOLE OptState is flat: init takes
        the flat param buffers and builds moment buffers mirroring
        them, update runs one blocked kernel per bucket
        (``ops.fused_server_update``) — the moments materialize back
        into trees only in :func:`unpack_server_state` at the
        EngineResult boundary.  ``task`` is required on the fused path
        (it keys the strategy's :meth:`flat_ops`).
        """
        if self.server_opt == "none":
            return None
        if self.server_opt not in ("momentum", "adam"):
            raise ValueError(f"unknown server_opt {self.server_opt!r}")
        from repro.optim.optimizers import AdamWState, OptState, adamw, sgd

        if self.spec.update_impl == "tree":
            if self.server_opt == "momentum":
                opt = sgd(self.server_lr, momentum=self.server_momentum)
            else:
                opt = adamw(self.server_lr, b1=SERVER_ADAM_B1,
                            b2=SERVER_ADAM_B2)

            def update(params, avg_params, state):
                pseudo_grad = tm.sub(params, avg_params)
                return opt.apply(pseudo_grad, state, params)

            return opt.init, update

        if task is None:
            raise ValueError("the fused server update is built per task — "
                             "pass the engine's Task")
        fops = self.flat_ops(task)
        server_opt, lr, beta = self.server_opt, self.server_lr, \
            self.server_momentum
        with_moments = server_opt == "adam" or beta != 0.0

        def init(p_bufs):
            zeros = lambda: {k: jnp.zeros_like(b)      # noqa: E731
                             for k, b in p_bufs.items()}
            if not with_moments:
                inner = ()          # momentum=0 keeps no moment buffers
            elif server_opt == "momentum":
                inner = zeros()
            else:
                inner = AdamWState(mu=zeros(), nu=zeros())
            return OptState(step=jnp.zeros((), jnp.int32), inner=inner)

        def update(p_bufs, avg_bufs, state):
            delta = {k: avg_bufs[k].astype(jnp.float32) -
                     p_bufs[k].astype(jnp.float32) for k in p_bufs}
            step = state.step + 1
            if not with_moments:
                new_p = fops.apply_delta(
                    p_bufs, {k: lr * d for k, d in delta.items()})
                return new_p, OptState(step=step, inner=())
            if server_opt == "momentum":
                new_p, (m,) = fops.server_update(
                    p_bufs, delta, (state.inner,), (lr,), opt="momentum",
                    beta=beta)
                return new_p, OptState(step=step, inner=m)
            t = step.astype(jnp.float32)
            scalars = (lr, 1.0 - SERVER_ADAM_B1 ** t,
                       1.0 - SERVER_ADAM_B2 ** t)
            new_p, (mu, nu) = fops.server_update(
                p_bufs, delta, (state.inner.mu, state.inner.nu), scalars,
                opt="adam", b1=SERVER_ADAM_B1, b2=SERVER_ADAM_B2)
            return new_p, OptState(step=step, inner=AdamWState(mu=mu, nu=nu))

        return init, update

    def build_round(self, task: Task) -> Callable:
        spec = self.spec
        fops = self.flat_ops(task)
        local = make_local_fn(task, spec, fops)
        algo = self.algorithm
        store = self.state_store
        # aggregation takes (round_key, ids, params, w_locals, weights,
        # algo_state) and returns (new_params, algo_state): the key/ids
        # thread the DP noise and secure-agg mask derivation
        # (repro.fl.privacy) into the round program, and the state rides
        # through so compressed communication (repro.fl.compression) can
        # gather/scatter its error-feedback residual rows; with privacy
        # and compression off the closures ignore all three and reduce
        # to the exact baseline math
        private = privacy.privacy_on(spec.dp, spec.secure_agg)
        comp = spec.compression
        compressed = compression.compression_on(comp)
        ef = compressed and comp.error_feedback
        ef_store = self._ef_store if ef else None

        def with_ef(agg_fn):
            def run(rk, ids, p, wl, w, st):
                res = (ef_store.gather(st["ef_residuals"], ids)
                       if ef else None)
                new_p, new_r = agg_fn(p, wl, w, res)
                if ef:
                    st = dict(st, ef_residuals=ef_store.scatter(
                        st["ef_residuals"], ids, new_r))
                return new_p, st
            return run

        def stateless(agg_fn):
            return lambda rk, ids, p, wl, w, st: (agg_fn(rk, ids, p, wl, w),
                                                  st)

        if fops is None:
            if compressed:
                view = host_flat_ops(task, True).view
                aggregate = with_ef(functools.partial(
                    compression.tree_compressed_aggregate, comp, view))
            elif private:
                aggregate = stateless(functools.partial(
                    privacy.tree_dp_aggregate, spec.dp, spec.secure_agg))
            else:
                aggregate = stateless(
                    lambda rk, ids, p, wl, w: tm.stacked_weighted_mean(wl, w))
            unpack = stacked_unpack = lambda t, fz=None: t                # noqa: E731
        else:
            # the vmapped flat local outputs ARE the stacked (K, N)
            # buffers — aggregation consumes them with zero packing
            if compressed:
                aggregate = with_ef(functools.partial(
                    compression.fused_compressed_aggregate, comp, fops))
            elif private:
                aggregate = stateless(functools.partial(
                    privacy.fused_dp_aggregate, spec.dp, spec.secure_agg,
                    fops))
            else:
                aggregate = stateless(
                    lambda rk, ids, p, wl, w: fused_aggregate(fops, p, wl, w))
            unpack = fops.unflatten
            stacked_unpack = fops.stacked_unflatten

        def body(key, params, x_all, y_all, ids, weights, lr_scale, algo_state,
                 frozen=None):
            K = ids.shape[0]
            keys = jax.random.split(key, K)
            cx = x_all[ids]
            cy = y_all[ids]

            if algo in ("fedavg", "fedprox"):
                # extras are TREES (they feed the loss at the forward
                # boundary) — materialized from the flat carry if needed
                extras = {"w_global": unpack(params, frozen)} \
                    if algo == "fedprox" else {}
                in_ext = jax.tree_util.tree_map(lambda _: None, extras)
                w_locals, aux = jax.vmap(
                    local, in_axes=(0, None, in_ext, 0, 0, None, None))(
                    keys, params, extras, cx, cy, lr_scale, frozen)
                new_params, algo_state = aggregate(key, ids, params,
                                                   w_locals, weights,
                                                   algo_state)
                return new_params, algo_state, jnp.mean(aux["loss"])

            if algo == "scaffold":
                c, c_all = algo_state["c_global"], algo_state["c_clients"]
                c_i = store.gather(c_all, ids)
                # control-variate update (option II):
                # c_i⁺ = c_i − c + (w−w_i)/(S·lr)
                denom = spec.n_steps * spec.lr * lr_scale
                if fops is not None:
                    # FLAT per-client state: c and the gathered (K, N)
                    # rows are buffer dicts, the whole control-variate
                    # algebra runs on the stacked buffers — no
                    # per-client unflatten anywhere in the round
                    c_diff = jax.tree_util.tree_map(
                        lambda g, l: g[None] - l, c, c_i)
                    w_locals, aux = jax.vmap(
                        local, in_axes=(0, None, {"c_diff_flat": 0}, 0, 0,
                                        None, None))(
                        keys, params, {"c_diff_flat": c_diff}, cx, cy,
                        lr_scale, frozen)
                    c_i_new = jax.tree_util.tree_map(
                        lambda ci, cg, p, wl: ci - cg[None] +
                        (p[None] - wl) / denom,
                        c_i, c, params, w_locals)
                else:
                    # per-client extras carry (c − c_i) with a leading K axis
                    c_diff = jax.tree_util.tree_map(
                        lambda g, l: jnp.broadcast_to(g[None], l.shape) - l,
                        c, c_i)
                    extras = {"c_diff": c_diff}
                    w_locals, aux = jax.vmap(
                        local, in_axes=(0, None, {"c_diff": 0}, 0, 0, None,
                                        None))(
                        keys, params, extras, cx, cy, lr_scale, frozen)
                    c_i_new = jax.tree_util.tree_map(
                        lambda ci, cg, w, wl: ci - cg[None] +
                        (w[None] - wl) / denom,
                        c_i, c, params, w_locals)
                new_params, algo_state = aggregate(key, ids, params,
                                                   w_locals, weights,
                                                   algo_state)
                # c ← c + (K/N)·mean_i(c_i⁺ − c_i); N is the POPULATION
                # (the sparse store's physical table is only capacity rows)
                frac = K / store.population(c_all)
                c_new = jax.tree_util.tree_map(
                    lambda cg, new, old: cg + frac * jnp.mean(new - old, axis=0),
                    c, c_i_new, c_i)
                c_all_new = store.scatter(c_all, ids, c_i_new)
                state = dict(algo_state, c_global=c_new,
                             c_clients=c_all_new)
                return new_params, state, jnp.mean(aux["loss"])

            if algo == "moon":
                w_prev_all = algo_state["w_prev"]
                # flat path: rows gather/scatter as raw (K, N) buffers —
                # ONE stacked unflatten at the loss boundary (extras are
                # trees), zero per-client packing on the way back
                w_prev = stacked_unpack(store.gather(w_prev_all, ids), frozen)
                extras = {"w_global": unpack(params, frozen),
                          "w_prev": w_prev}
                w_locals, aux = jax.vmap(
                    local,
                    in_axes=(0, None, {"w_global": None, "w_prev": 0}, 0, 0,
                             None, None))(
                    keys, params, extras, cx, cy, lr_scale, frozen)
                new_params, algo_state = aggregate(key, ids, params,
                                                   w_locals, weights,
                                                   algo_state)
                state = dict(algo_state,
                             w_prev=store.scatter(w_prev_all, ids, w_locals))
                return new_params, state, jnp.mean(aux["loss"])

            raise ValueError(f"unknown algorithm {algo!r}")

        return body

    def record(self, ledger, k: int, params: Pytree, task=None) -> None:
        comp = self.spec.compression
        filt = effective_trainable_filter(self.spec)
        x = _logical_model_bytes(task) if task is not None else None
        # the upload payload departs from the full model X whenever the
        # wire carries less: compressed deltas, a trainable slice, or
        # both (the ratios compose multiplicatively in the closed form)
        payload = (_upload_payload_bytes(task, comp, filt)
                   if task is not None and
                   (compression.compression_on(comp) or filt is not None)
                   else None)
        ledger.record_round(self.algorithm, k, params,
                            secure_agg=self.spec.secure_agg,
                            x_bytes=x, payload_bytes=payload)


# ---------------------------------------------------------------------------
# evaluation — the in-program eval stream
# ---------------------------------------------------------------------------
#
# The engine evaluates INSIDE the compiled chunk program: the test set is
# batched once into (n_batches, B, ...) arrays (the tail batch padded by
# wrap-around, with a (n_batches, B) 0/1 weight marking real samples),
# handed to the backend for placement, and scanned under a per-round
# ``lax.cond`` so non-eval rounds pay nothing.  The metric contract is
# PER-SAMPLE: ``metric(params, bx, by) -> (B,)`` — the engine returns the
# weight-averaged mean over the whole stream, which for the default
# accuracy metric equals full-test-set accuracy exactly (every sample
# carries the same number of label elements).

def make_eval_fn(task: Task, batch: int) -> Callable:
    """Host-side reference evaluation (one jit dispatch per test batch).

    Kept as the parity oracle for the in-program stream and for
    evaluating a model outside an engine run; the training loop itself
    evaluates in-program (see ``make_accuracy_metric``)."""
    @jax.jit
    def eval_batch(params, bx, by):
        return task.accuracy(params, bx, by)

    def evaluate(params, test_x, test_y) -> float:
        n = len(test_y)
        accs, ws = [], []
        for s in range(0, n, batch):
            bx = jnp.asarray(test_x[s:s + batch])
            by = jnp.asarray(test_y[s:s + batch])
            accs.append(float(eval_batch(params, bx, by)))
            ws.append(len(by))
        return float(np.average(accs, weights=ws))

    return evaluate


@functools.lru_cache(maxsize=64)
def make_accuracy_metric(task: Task) -> Callable:
    """Default in-program eval metric: per-sample accuracy.

    ``metric(params, bx, by) -> (B,)`` mean correctness per sample (the
    trailing label dims — sequence positions for token tasks — are
    averaged within each sample, matching ``Task.accuracy``)."""

    def metric(params, bx, by):
        correct = (task.predict_fn(params, bx) == by).astype(jnp.float32)
        return correct.reshape(correct.shape[0], -1).mean(axis=1)

    return metric


def batch_test_set(test_x, test_y, batch: int) -> Tuple:
    """Batch the held-out test set for the in-program eval stream.

    Returns host arrays ``(ev_x, ev_y, ev_w)``: ``(n_batches, B, ...)``
    data (tail batch padded by wrapping around to the front of the test
    set) and ``(n_batches, B)`` float32 weights — 1 for real samples, 0
    for pad — so the weighted mean over the stream is exact."""
    test_x, test_y = np.asarray(test_x), np.asarray(test_y)
    n = len(test_y)
    B = max(1, min(batch, n))
    n_batches = -(-n // B)
    pad = n_batches * B - n
    idx = np.concatenate([np.arange(n), np.arange(pad) % n])
    w = np.concatenate([np.ones(n, np.float32), np.zeros(pad, np.float32)])
    shape = (n_batches, B)
    return (test_x[idx].reshape(shape + test_x.shape[1:]),
            test_y[idx].reshape(shape + test_y.shape[1:]),
            w.reshape(shape))


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RoundSchedule:
    """Host-side schedule knobs shared by every strategy.

    sampling="device" draws the per-round client subset inside the jitted
    chunk program (``jax.random.permutation(k, n_clients)[:K]``);
    "host" reproduces the seed drivers' ``np.random.default_rng(seed +
    host_rng_offset)`` stream (the offset was 31 for P1, 17 for P2) and
    feeds the precomputed ids in as scan inputs.

    eval_every ≤ 0 disables evaluation entirely (benchmark mode);
    otherwise the engine evaluates every ``eval_every`` rounds and on
    the final round — the same cadence as the seed drivers, but computed
    in-program from a per-round mask, so any ``eval_every`` composes
    with any ``chunk_size`` without splitting a dispatch.

    ``overlap=True`` pipelines the chunk loop: while dispatch N runs on
    device, the engine plans residency for dispatch N+1 (sampling
    replay, LRU eviction plan) and stages its refill rows with
    non-blocking transfers, so host residency cost hides behind device
    compute.  Staging only re-orders HOST work (the device-side op
    stream is identical), so overlapped == synchronous is bitwise; the
    knob is a pure throughput trade and a no-op for dense stores.  It
    is ignored (forced off) when a switch policy pins per-round
    dispatch.
    """
    rounds: int
    lr_decay: float = 0.998
    eval_every: int = 10
    eval_batch: int = 256
    seed: int = 0
    chunk_size: int = 1
    sampling: str = "device"        # device | host
    host_rng_offset: int = 0
    overlap: bool = False

    def __post_init__(self):
        if self.sampling not in ("device", "host"):
            raise ValueError(f"unknown sampling mode {self.sampling!r}")


@dataclasses.dataclass
class EngineResult:
    params: Pytree
    history: List[Dict[str, float]]
    algo_state: Dict[str, Pytree]
    server_state: Any = None
    dispatches: int = 0             # chunk-program invocations this run
    # wall-time breakdown of the chunk loop (totals over the run, ms):
    # host_residency_ms = stage planning + staging-transfer enqueue,
    # staged_transfer_ms = the device_put slice of that (store-reported),
    # dispatch_enqueue_ms = commit + chunk_fn call overhead,
    # device_wait_ms = blocking on the dispatched chunk's outputs,
    # spill_materialize_ms = background spill→numpy conversion time
    # (work moved OFF the critical path, not added to it)
    timing: Optional[Dict[str, float]] = None


def make_chunk_fn(task: Task, strategy, schedule: RoundSchedule,
                  n_clients: int, metric: Optional[Callable] = None
                  ) -> Callable:
    """Build the jitted R-round program.

    signature: chunk_fn(key, params, algo_state, server_state,
                        x_all, y_all, n_real, ids, lr_scales, eval_mask,
                        ev_x, ev_y, ev_w, frozen)
               -> (key, params, algo_state, server_state, losses, metrics)

    ``frozen`` is the read-only frozen-leaf constant bucket of a
    trainable-filtered run ({} for full-filter) — NOT donated, NOT in
    the scan carry: the same buffers serve every round of every chunk
    and merge with the trainable carry only at the loss / eval tree
    boundaries.
    The per-round keys are derived INSIDE the scan by the same
    ``key, rk = jax.random.split(key)`` recurrence the seed drivers ran
    on the host (threefry is deterministic, so the streams are
    bit-identical) — the host does zero per-round work.  lr_scales is
    the (R,)-stacked decay schedule, ids is (R, K) for host sampling or
    None for on-device sampling, and the four carries are donated so
    chunk i+1 reuses chunk i's buffers.

    ``metric`` is the in-program eval metric (per-sample contract, see
    ``make_accuracy_metric``) or None for no-eval programs.  With a
    metric, eval_mask is an (R,) bool scan input and ev_x/ev_y/ev_w the
    backend-placed test stream from :func:`batch_test_set`; the chunk
    evaluates under ``lax.cond`` on masked-in rounds and emits an (R,)
    metric stream (NaN on masked-out rounds).  Without one, those four
    args are None and the metrics output is None.

    Programs are cached on (task, strategy, sampling, n_clients,
    metric) — Task and the strategies are frozen dataclasses — so
    repeated engine runs (benchmark sweeps, schedule phases reusing a
    config) skip retracing; jax.jit then caches per chunk length R
    underneath.
    """
    return _cached_chunk_fn(task, strategy, schedule.sampling, n_clients,
                            metric)


@functools.lru_cache(maxsize=64)
def _cached_chunk_fn(task: Task, strategy, sampling: str,
                     n_clients: int, metric: Optional[Callable]) -> Callable:
    body = strategy.build_round(task)
    server = strategy.make_server_update(task)
    fops = strategy.flat_ops(task)
    on_device = sampling == "device"
    K = strategy.n_selected(n_clients)

    def chunk(key, params, algo_state, server_state, x_all, y_all, n_real,
              ids, lr_scales, eval_mask, ev_x, ev_y, ev_w, frozen):
        def evaluate(params):
            # the eval metric speaks param trees — the flat carry
            # materializes one here, at the model's forward boundary
            # (merging the frozen constant bucket on filtered views)
            if fops is not None:
                params = fops.unflatten(params, frozen)

            # weighted mean over the batched test stream; ev_w zeroes
            # the wrap-around pad in the tail batch
            def eval_batch(tot, inp):
                bx, by, w = inp
                return tot + jnp.sum(metric(params, bx, by) * w), None

            tot, _ = jax.lax.scan(eval_batch, jnp.float32(0.0),
                                  (ev_x, ev_y, ev_w))
            return tot / jnp.sum(ev_w)

        def one_round(carry, xs):
            key, params, algo_state, server_state = carry
            ids_r, lr_scale, do_eval = xs
            key, rk = jax.random.split(key)
            if on_device:
                k_sel, rk = jax.random.split(rk)
                ids_r = jax.random.permutation(k_sel, n_clients)[:K]
            weights = n_real[ids_r].astype(jnp.float32)
            new_params, algo_state, loss = body(
                rk, params, x_all, y_all, ids_r, weights, lr_scale, algo_state,
                frozen)
            if server is not None:
                new_params, server_state = server[1](params, new_params,
                                                     server_state)
            m = None
            if metric is not None:
                m = jax.lax.cond(do_eval, evaluate,
                                 lambda _: jnp.float32(jnp.nan), new_params)
            return (key, new_params, algo_state, server_state), (loss, m)

        (key, params, algo_state, server_state), (losses, metrics) = \
            jax.lax.scan(one_round, (key, params, algo_state, server_state),
                         (ids, lr_scales, eval_mask))
        return key, params, algo_state, server_state, losses, metrics

    return strategy.jit_chunk(chunk, task, n_clients)


@dataclasses.dataclass
class _ChunkPlan:
    """One dispatch's host-derived inputs, computable ahead of time so
    the overlapped loop can plan chunk N+1 while chunk N executes."""
    rnd: int
    R: int
    ids: Optional[jnp.ndarray]
    ids_block: Optional[np.ndarray]
    lr_scales: jnp.ndarray
    eval_mask: Optional[jnp.ndarray]
    do_eval: List[bool]
    staged: Any = None


def run_rounds(task: Task, data: FederatedDataset, strategy,
               schedule: RoundSchedule, *,
               init_params: Optional[Pytree] = None,
               ledger=None, verbose: bool = False,
               eval_fn: Optional[Callable] = None,
               switch_policy=None,
               phase: str = "P2",
               label: Optional[str] = None) -> EngineResult:
    """Run ``schedule.rounds`` rounds of ``strategy`` and return the
    final params plus the per-round history.

    The per-round key stream (split once per round from
    ``PRNGKey(schedule.seed)``) and the lr-decay scalars are derived on
    the host independently of chunking, so histories are invariant to
    ``chunk_size`` and, with sampling="host" + the right offset,
    bit-compatible with the seed drivers.

    Evaluation runs IN PROGRAM (see ``make_chunk_fn``): rounds where
    ``(round + 1) % eval_every == 0`` — plus the final round — compute
    the eval metric inside the chunk scan, so evaluating never splits a
    chunk or adds a dispatch.  ``eval_fn`` overrides the default
    accuracy metric and must follow the traceable per-sample contract
    ``eval_fn(params, bx, by) -> (B,)``; the history rows record the
    stream's weighted mean under the ``"acc"`` key either way.
    """
    key = jax.random.PRNGKey(schedule.seed)
    params = init_params if init_params is not None else task.init(key)
    # flat-first: on the fused path the engine's working params are the
    # strategy's flat buffers from here to the EngineResult — the server
    # OptState inits flat too, and trees reappear only at the eval /
    # forward boundaries inside the chunk.  Packing replaces the
    # place_params hook outright: fops.place commits the packed buffers
    # to the flat shardings AND de-aliases any flatten passthrough (a
    # single-1-D-leaf bucket packs to the caller's own array), so the
    # donated carries never eat the caller's tree and the per-leaf
    # placement would be dead work.
    fops = strategy.flat_ops(task)
    frozen: Dict[str, jnp.ndarray] = {}
    if fops is None:
        # backend hook: copy (host) or device_put with shardings (pod) so
        # the donated carries never invalidate the caller's init_params
        params = strategy.place_params(params)
    else:
        # pack + place FIRST: init_state sees the engine's working
        # representation, so per-client state initializes flat too.
        # Frozen leaves pack ONCE per phase into the read-only constant
        # bucket ({} for an unfiltered view): non-donated, outside the
        # chunk carry, merged back only at tree boundaries.
        frozen = fops.place_frozen(fops.flatten_frozen(params))
        params = fops.place(fops.flatten(params))

    n_clients = data.n_clients
    K = strategy.n_selected(n_clients)
    algo_state = strategy.init_state(task, params, n_clients)
    server = strategy.make_server_update(task)
    server_state = server[0](params) if server is not None else ()
    server_state = strategy.place_server_state(server_state, task)

    with_eval = schedule.eval_every > 0 and len(np.asarray(data.test_y)) > 0
    metric = None
    if with_eval:
        metric = eval_fn if eval_fn is not None else make_accuracy_metric(task)
    chunk_fn = make_chunk_fn(task, strategy, schedule, n_clients, metric)
    x_all, y_all, n_real = strategy.prepare_data(data)
    ev_x = ev_y = ev_w = None
    if with_eval:
        ev_x, ev_y, ev_w = strategy.prepare_eval_data(
            batch_test_set(data.test_x, data.test_y, schedule.eval_batch))

    host_rng = None
    if schedule.sampling == "host":
        host_rng = np.random.default_rng(schedule.seed + schedule.host_rng_offset)

    label = label or getattr(strategy, "name", phase)
    # per-round switch decisions need per-round dispatch
    chunk = 1 if switch_policy is not None else max(1, schedule.chunk_size)
    # the overlapped pipeline pre-plans the NEXT chunk while the current
    # one runs; a switch policy decides per round, so it forces sync
    overlap = bool(getattr(schedule, "overlap", False)) \
        and switch_policy is None

    # sparse stores manage residency on the host between dispatches: they
    # must see each chunk's client ids before the chunk runs.  A strategy
    # may carry several stores (algorithm rows + EF residual rows).
    store = getattr(strategy, "state_store", None)
    stores = getattr(strategy, "residency_stores", None)
    if stores is None:
        stores = [store] if store is not None else []
    sparse_residency = any(getattr(s, "needs_host_ids", False)
                           for s in stores) and bool(algo_state)
    # device sampling: the replay key advances on the host by the same
    # split recurrence the program runs, so chunk N+1's draws are known
    # before chunk N's carried key has materialized
    replay_key = key

    timing = {"host_residency_ms": 0.0, "staged_transfer_ms": 0.0,
              "dispatch_enqueue_ms": 0.0, "device_wait_ms": 0.0,
              "spill_materialize_ms": 0.0}

    def stores_ms(attr: str) -> float:
        return sum(float(getattr(s, attr, 0.0) or 0.0) for s in stores)

    transfer_ms0 = stores_ms("staged_transfer_ms")
    spill_ms0 = stores_ms("spill_materialize_ms")

    def make_plan(rnd: int) -> _ChunkPlan:
        """Everything host-derived a dispatch needs: the round window,
        sampled ids, residency id block, lr scales and the eval mask —
        all pure functions of the (host) rng streams and the global
        round index, so planning order == execution order keeps the
        streams bit-identical whether or not the loop overlaps."""
        nonlocal replay_key
        R = min(chunk, schedule.rounds - rnd)
        ids = None
        if host_rng is not None:
            ids = jnp.asarray(np.stack([
                host_rng.choice(n_clients, size=K, replace=False)
                for _ in range(R)]))
        ids_block = None
        if sparse_residency:
            # host sampling: the ids are already known; device sampling:
            # replay the chunk's in-program draw (bit-identical threefry
            # recurrence) — residency only, the program still samples
            # in-program unchanged
            if ids is not None:
                ids_block = np.asarray(ids)
            else:
                ids_block, replay_key = _replay_device_sampling(
                    replay_key, n_clients, K, R)
        lr_scales = jnp.asarray(
            [schedule.lr_decay ** (rnd + j) for j in range(R)], jnp.float32)
        # the eval cadence is a host-computed mask over GLOBAL round
        # indices, so it is independent of how rounds chunk into dispatches
        eval_mask = None
        do_eval = [False] * R
        if with_eval:
            do_eval = [(rnd + j + 1) % schedule.eval_every == 0
                       or rnd + j + 1 == schedule.rounds for j in range(R)]
            eval_mask = jnp.asarray(do_eval)
        return _ChunkPlan(rnd=rnd, R=R, ids=ids, ids_block=ids_block,
                          lr_scales=lr_scales, eval_mask=eval_mask,
                          do_eval=do_eval)

    def stage(plan: _ChunkPlan) -> None:
        if plan.ids_block is None:
            return
        t0 = time.perf_counter()
        plan.staged = strategy.stage_chunk_state(plan.ids_block.reshape(-1))
        timing["host_residency_ms"] += (time.perf_counter() - t0) * 1e3

    history: List[Dict[str, float]] = []
    dispatches = 0
    plan = make_plan(0) if schedule.rounds > 0 else None
    staged_plan = None
    while plan is not None:
        if staged_plan is not plan:     # sync path (or the first chunk)
            stage(plan)
        t0 = time.perf_counter()
        algo_state = strategy.commit_chunk_state(algo_state, plan.staged)
        key, params, algo_state, server_state, losses, metrics = chunk_fn(
            key, params, algo_state, server_state, x_all, y_all, n_real,
            plan.ids, plan.lr_scales, plan.eval_mask, ev_x, ev_y, ev_w,
            frozen)
        dispatches += 1
        timing["dispatch_enqueue_ms"] += (time.perf_counter() - t0) * 1e3

        nxt = None
        if overlap and plan.rnd + plan.R < schedule.rounds:
            # the pipeline: plan + stage chunk N+1 while chunk N runs
            nxt = make_plan(plan.rnd + plan.R)
            stage(nxt)
            staged_plan = nxt

        t0 = time.perf_counter()
        losses = np.asarray(losses)     # blocks: the dispatch drains here
        timing["device_wait_ms"] += (time.perf_counter() - t0) * 1e3
        metrics = np.asarray(metrics) if metrics is not None else None

        rnd, R = plan.rnd, plan.R
        for j in range(R):
            if ledger is not None:
                strategy.record(ledger, K, params, task)
            row = {"round": rnd + j, "local_loss": float(losses[j]),
                   "phase": phase}
            if plan.do_eval[j]:
                row["acc"] = float(metrics[j])
                if verbose:
                    print(f"[{label}] round {rnd + j + 1}/{schedule.rounds} "
                          f"loss={row['local_loss']:.4f} acc={row['acc']:.4f}",
                          flush=True)
            history.append(row)

        if switch_policy is not None and switch_policy.should_switch(
                rnd + R - 1, history):
            break
        if not overlap:
            nxt = (make_plan(rnd + R) if rnd + R < schedule.rounds else None)
        plan = nxt

    timing["staged_transfer_ms"] = stores_ms("staged_transfer_ms") \
        - transfer_ms0
    # background spill-materialization ms accrued this run (off the
    # critical path — host work the refault bursts no longer pay)
    timing["spill_materialize_ms"] = stores_ms("spill_materialize_ms") \
        - spill_ms0

    if fops is not None:                # EngineResult speaks trees
        params = fops.unflatten(params, frozen)
        server_state = unpack_server_state(fops, server_state)
        # algo_state stays in the carried representation (flat row
        # buffers / sparse store tables) — materializing an
        # (n_clients, model) tree here would defeat the sparse store
    return EngineResult(params=params, history=history,
                        algo_state=algo_state, server_state=server_state,
                        dispatches=dispatches, timing=timing)
