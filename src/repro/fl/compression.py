"""Compressed client→server communication — blockwise symmetric
quantization + magnitude top-k sparsification with error feedback,
shared by the host and pod backends.

CyclicFL's own Table IV analysis makes per-round communication volume
THE cost model of the system; this module compresses the only payload
that actually scales with the model — the P2 client upload
``δᵢ = wᵢ − w`` — before it enters the round aggregate, so the
aggregation consumes exactly the values a decompressed wire payload
would carry.

The mechanism (:class:`CompressionSpec`), applied per flat per-dtype
bucket (``repro.utils.flatten.FlatView`` / ``ShardedFlatView``):

top-k sparsification (``density < 1``)
    Keep the ``k = max(1, ceil(density·n))`` largest-magnitude elements
    of the bucket, zero the rest.  Implemented as a THRESHOLD mask
    ``d·[|d| ≥ τ]`` with ``τ`` = the k-th largest ``|d|`` — ties at τ
    are all kept, which keeps the kernel one elementwise pass and makes
    the pod's shard-local form exact (each shard thresholds its own
    ``k`` over its own ``per_shard`` elements: zero collectives).

blockwise symmetric quantization (``bits ∈ {8, 16}``)
    Per 128-lane block, ``scale = bf16((amax/qmax)·SCALE_PAD)`` and
    ``c = round(d/scale)·scale`` (round half-even, clip ±qmax).  Scales
    ship as bf16 — 2 bytes per 128 elements — because the padded-up
    cast guarantees ``scale ≥ amax/qmax`` (no clipping distortion,
    per-element error ≤ scale/2) while keeping the int8 payload ratio
    at 4/(1 + 2/128) ≈ 3.94×; f32 scales would cap it at 3.88×.

error feedback (``error_feedback=True``)
    The compression error ``r = δ − compress(δ + r_prev)`` is carried
    per client and added to the NEXT round's delta before compression,
    so sparsified/quantized-away mass is deferred, not lost (SEC-style
    memory).  Residuals are per-client flat f32 rows behind the
    unchanged ClientStateStore contract (``algo_state["ef_residuals"]``)
    — dense, sparse and sharded-sparse stores all carry them, so they
    survive LRU eviction/host spill at 10^6-client scale.

The identity spec (``bits=32, density=1.0``) is STATICALLY off —
``compression_on`` returns False and every caller keeps the exact
baseline program, bitwise (tests/test_compression.py).  Lossy
compression composes with neither ``secure_agg`` (pairwise masks cancel
only over exact-real uploads) nor DP (the sensitivity bound is
certified on the exact clipped delta) — both are rejected at spec
construction (:func:`validate_compression`).

Parity chain: :func:`numpy_compress` (host NumPy, the ground-truth
oracle) == :func:`reference_compress` (pure jnp) ==
``repro.kernels.fused_update.compress_delta`` (the blocked Pallas
kernel), bitwise; :func:`tree_compressed_aggregate` is the engine-level
reference the fused host aggregate must match bitwise.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.fused_update import LANES, QMAX, SCALE_PAD

Pytree = Any

# bytes per wire element: quantized values ship at bits/8, coordinates
# of surviving top-k elements as int32, block scales as bf16
_INDEX_BYTES = 4
_SCALE_BYTES = 2
_FULL_BYTES = 4                 # uncompressed deltas ship as f32


@dataclasses.dataclass(frozen=True)
class CompressionSpec:
    """Static compressed-communication parameters for the P2 upload.

    Frozen + hashable so it rides ``LocalSpec`` through the engine's
    lru-cached strategy/chunk builders.  ``bits=32, density=1.0`` is the
    identity spec — statically OFF, callers keep the exact baseline
    program (the same contract as ``DPSpec(inf, 0)``).
    """
    bits: int = 32
    density: float = 1.0
    error_feedback: bool = False

    def __post_init__(self):
        if self.bits not in (8, 16, 32):
            raise ValueError(f"compression bits must be one of 8|16|32, "
                             f"got {self.bits!r}")
        if not (0.0 < self.density <= 1.0):
            raise ValueError(f"compression density must be in (0, 1], "
                             f"got {self.density!r}")
        if self.error_feedback and self.identity:
            raise ValueError(
                "error_feedback=True needs lossy compression (bits<32 or "
                "density<1): the identity spec has a zero residual by "
                "definition")

    @property
    def quantizes(self) -> bool:
        return self.bits != 32

    @property
    def sparsifies(self) -> bool:
        return self.density < 1.0

    @property
    def identity(self) -> bool:
        """Statically-off spec: no quantization, no sparsification."""
        return not self.quantizes and not self.sparsifies

    @property
    def lossy(self) -> bool:
        return not self.identity


def compression_on(spec: Optional[CompressionSpec]) -> bool:
    """Whether the round aggregate needs the compressed path at all —
    None and the identity spec both compile to the exact baseline."""
    return spec is not None and spec.lossy


def validate_compression(spec: Optional[CompressionSpec], *,
                         dp=None, secure_agg: bool = False) -> None:
    """Reject invalid mechanism combinations at construction time
    (mirrors ``repro.fl.local.validate_update_impl``: fail loudly at the
    spec, not deep inside a traced round body)."""
    if not compression_on(spec):
        return
    if secure_agg:
        raise ValueError(
            "secure_agg=True is incompatible with lossy compression "
            "(bits<32 or density<1): pairwise masks cancel only over "
            "exact-real uploads — quantizing or sparsifying the masked "
            "field breaks the telescoping sum (see docs/ARCHITECTURE.md, "
            "'Compressed communication')")
    if dp is not None:
        raise ValueError(
            "dp is incompatible with lossy compression: the DP "
            "sensitivity bound is certified on the exact clipped delta, "
            "not its quantized form — run DP-FedAvg uncompressed or "
            "compression without DP")


# ---------------------------------------------------------------------------
# top-k threshold
# ---------------------------------------------------------------------------

def topk_k(spec: CompressionSpec, n: int) -> int:
    """Elements kept per bucket of LOGICAL size n (never 0, never > n)."""
    return min(n, max(1, int(math.ceil(spec.density * n))))


def topk_threshold(d: jnp.ndarray, k: int) -> jnp.ndarray:
    """τ = the k-th largest |d| (traced), the exact value
    ``np.partition(|d|, n-k)[n-k]`` selects.  Appending zero padding to
    ``d`` never changes τ as long as ``k`` counts LOGICAL elements, so
    callers may pass GRID_ALIGN-padded buffers with a logical ``k``.

    Selection runs as a 31-step binary search over the IEEE-754 bit
    space: |x| is non-negative, so its uint32 pattern orders like the
    float and the greedy MSB→LSB prefix with ``count(bits ≥ t) ≥ k``
    lands exactly on the k-th largest element's bits.  Each step is one
    vectorized compare-and-count pass — O(31·n) streaming work instead
    of ``lax.top_k``'s O(n·log n) sort, whose CPU lowering costs more
    than an entire fused round at benchmark sizes."""
    a = jnp.abs(d.reshape(-1).astype(jnp.float32))
    bits = jax.lax.bitcast_convert_type(a, jnp.uint32)

    def step(prefix, shift):
        cand = prefix | (jnp.uint32(1) << shift)
        keep = jnp.sum(bits >= cand) >= jnp.uint32(k)
        return jnp.where(keep, cand, prefix), None

    prefix, _ = jax.lax.scan(step, jnp.uint32(0),
                             jnp.arange(30, -1, -1, dtype=jnp.uint32))
    return jax.lax.bitcast_convert_type(prefix, jnp.float32)


# ---------------------------------------------------------------------------
# reference compressors — jnp twin and NumPy ground truth of the kernel
# ---------------------------------------------------------------------------

def reference_compress(d: jnp.ndarray, spec: CompressionSpec, *,
                       logical_size: Optional[int] = None
                       ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Pure-jnp twin of ``repro.kernels.ops.fused_compress_delta`` —
    bitwise equal to the kernel in interpret mode (same elementwise f32
    ops over the same 128-lane block boundaries; zero padding to the
    kernel grid changes neither block scales nor τ).  Returns ``(c, r)``
    with the residual against the ORIGINAL delta.  ``logical_size``
    overrides the top-k population when ``d`` carries trailing zero
    padding."""
    n = d.shape[-1]
    d32 = d.astype(jnp.float32)
    x = d32
    if spec.sparsifies:
        tau = topk_threshold(d32, topk_k(spec, logical_size or n))
        x = jnp.where(jnp.abs(x) >= tau, x, 0.0)
    if spec.quantizes:
        rows = -(-n // LANES)
        xb = jnp.pad(x, (0, rows * LANES - n)).reshape(rows, LANES)
        qmax = QMAX[spec.bits]
        amax = jnp.max(jnp.abs(xb), axis=-1, keepdims=True)
        scale = ((amax / qmax) * SCALE_PAD) \
            .astype(jnp.bfloat16).astype(jnp.float32)
        q = jnp.where(scale > 0.0, xb / jnp.where(scale > 0.0, scale, 1.0),
                      0.0)
        q = jnp.clip(jnp.round(q), -qmax, qmax)
        x = (q * scale).reshape(-1)[:n]
    return x, d32 - x


def numpy_compress(d: np.ndarray, spec: CompressionSpec, *,
                   logical_size: Optional[int] = None
                   ) -> Tuple[np.ndarray, np.ndarray]:
    """Host-NumPy ground truth of the compress kernel (bitwise: same f32
    elementwise ops, same half-even rounding, same bf16 scale cast via
    ml_dtypes).  The parity anchor of tests/test_compression.py."""
    import ml_dtypes                       # ships with jax
    d = np.asarray(d, np.float32)
    n = d.shape[-1]
    x = d
    if spec.sparsifies:
        k = topk_k(spec, logical_size or n)
        a = np.abs(d)
        tau = np.partition(a, a.size - k)[a.size - k]
        x = np.where(a >= tau, x, np.float32(0.0))
    if spec.quantizes:
        rows = -(-n // LANES)
        xb = np.pad(x, (0, rows * LANES - n)).reshape(rows, LANES)
        qmax = np.float32(QMAX[spec.bits])
        amax = np.max(np.abs(xb), axis=-1, keepdims=True)
        scale = ((amax / qmax) * np.float32(SCALE_PAD)) \
            .astype(ml_dtypes.bfloat16).astype(np.float32)
        q = np.where(scale > 0.0, xb / np.where(scale > 0.0, scale,
                                                np.float32(1.0)),
                     np.float32(0.0))
        q = np.clip(np.round(q), -qmax, qmax)
        x = (q * scale).reshape(-1)[:n].astype(np.float32)
    return x, (d - x).astype(np.float32)


# ---------------------------------------------------------------------------
# wire accounting — the closed-form payload the ledger checks against
# ---------------------------------------------------------------------------

def payload_bytes(spec: Optional[CompressionSpec], sizes) -> int:
    """Closed-form wire bytes of ONE client's upload over the per-bucket
    LOGICAL element counts ``sizes`` (deltas ship as f32 when
    uncompressed).  Per lossy bucket: kept values at ``bits/8`` bytes,
    an int32 coordinate per kept value when sparsified, and one bf16
    scale per 128-lane block when quantized."""
    total = 0
    for n in sizes:
        if n == 0:
            continue
        if not compression_on(spec):
            total += _FULL_BYTES * n
            continue
        k = topk_k(spec, n) if spec.sparsifies else n
        total += k * (spec.bits // 8)
        if spec.sparsifies:
            total += _INDEX_BYTES * k
        if spec.quantizes:
            total += _SCALE_BYTES * (-(-n // LANES))
    return int(total)


def payload_ratio(spec: Optional[CompressionSpec], sizes) -> float:
    """Uncompressed-over-compressed upload bytes (1.0 when off)."""
    comp = payload_bytes(spec, sizes)
    full = _FULL_BYTES * sum(sizes)
    return (full / comp) if comp else 1.0


# ---------------------------------------------------------------------------
# round aggregates (host engine) — flat-reference oracle and fused twin
# ---------------------------------------------------------------------------

def tree_compressed_aggregate(spec: CompressionSpec, view, params: Pytree,
                              w_locals: Pytree, weights: jnp.ndarray,
                              residuals: Optional[Dict[str, jnp.ndarray]]
                              = None):
    """The compressed FedAvg aggregate on the TREE path — the parity
    reference for the fused twin.  Compression is defined on the flat
    per-dtype buckets (block boundaries are a property of the packing,
    not of any leaf), so the reference flattens through the SAME
    ``FlatView`` the fused path uses, compresses each client's delta
    with :func:`reference_compress`, and aggregates
    ``cast(p₃₂ + Σₖ w̄ₖ·cₖ)`` in the kernel's accumulation order —
    bitwise equal to the fused host aggregate.

    ``residuals`` (error feedback) is the gathered per-client rows
    ``{bucket: (K, n)}``; returns ``(new_params_tree, new_residuals)``
    with ``new_residuals=None`` when error feedback is off."""
    wbar = (weights / jnp.sum(weights)).astype(jnp.float32)
    p_bufs = view.flatten(params)
    stacked = view.flatten_stacked(w_locals)
    new_p: Dict[str, jnp.ndarray] = {}
    new_r: Dict[str, jnp.ndarray] = {}
    for name, s in stacked.items():
        p32 = p_bufs[name].astype(jnp.float32)
        d = s.astype(jnp.float32) - p32[None]
        if residuals is not None:
            d = d + residuals[name]
        K = d.shape[0]
        cs, rs = [], []
        for k in range(K):                 # K is static and small
            c, r = reference_compress(d[k], spec)
            cs.append(c)
            rs.append(r)
        acc = jnp.zeros_like(p32)
        for k in range(K):                 # kernel accumulation order
            acc = acc + wbar[k] * cs[k]
        new_p[name] = (p32 + acc).astype(p_bufs[name].dtype)
        new_r[name] = jnp.stack(rs)
    out_params = view.unflatten(new_p)
    return out_params, (new_r if spec.error_feedback else None)


def fused_compressed_aggregate(spec: CompressionSpec, fops,
                               p_bufs: Dict[str, jnp.ndarray],
                               stacked_bufs: Dict[str, jnp.ndarray],
                               weights: jnp.ndarray,
                               residuals: Optional[Dict[str, jnp.ndarray]]
                               = None):
    """The compressed aggregate on the flat path: per client,
    ``δₖ = stacked[k] − p (+ rₖ)`` → ``(cₖ, rₖ′) = compress(δₖ)``
    (vmapped over K — one blocked kernel pass per bucket per client),
    then ONE ``weighted_delta(deltas=True)`` pass consumes the stacked
    compressed deltas: ``cast(p₃₂ + Σₖ w̄ₖ·cₖ)``.  Returns
    ``(new_p_bufs, new_residual_rows-or-None)``."""
    wbar = (weights / jnp.sum(weights)).astype(jnp.float32)

    def one_client(w_row, r_row):
        d = {name: w_row[name].astype(jnp.float32) -
             p_bufs[name].astype(jnp.float32) for name in w_row}
        if r_row is not None:
            d = {name: d[name] + r_row[name] for name in d}
        return fops.compress_delta(d, spec)

    if residuals is None:
        c_stacked, r_new = jax.vmap(lambda w: one_client(w, None))(
            stacked_bufs)
    else:
        c_stacked, r_new = jax.vmap(one_client)(stacked_bufs, residuals)
    new_p = fops.weighted_delta(p_bufs, c_stacked, wbar, deltas=True)
    return new_p, (r_new if spec.error_feedback else None)
