"""Federated-training simulation driver (P2).

Each round compiles to ONE XLA program: the K selected clients' local
runs are a ``vmap`` over the stacked client axis, and the FedAvg
aggregation is a weighted mean over that axis — the exact computation
that becomes a ``psum`` over the mesh ``data`` axis on a pod (see
repro/launch/train.py for the sharded version; this module is the
host-simulation used for the paper's accuracy/convergence experiments).

Algorithms: FedAvg, FedProx, SCAFFOLD, Moon — selected by name so
CyclicFL ("Cyclic+Y") composes with any of them.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.federated import FederatedDataset
from repro.fl.local import LocalSpec, make_local_fn
from repro.fl.task import Task
from repro.utils import tree_math as tm

Pytree = Any

ALGORITHMS = ("fedavg", "fedprox", "scaffold", "moon")


@dataclasses.dataclass(frozen=True)
class FLConfig:
    algorithm: str = "fedavg"
    rounds: int = 100
    participation: float = 0.1      # fraction of clients per round (K_P2)
    local_steps: int = 25           # SGD steps per client per round
    batch_size: int = 32
    lr: float = 0.01
    momentum: float = 0.0
    weight_decay: float = 0.0
    lr_decay: float = 0.998         # per-round multiplicative decay (paper)
    mu: float = 0.01                # fedprox / moon coefficient
    temperature: float = 0.5        # moon
    grad_clip: Optional[float] = None
    # server-side optimizer (beyond-paper; Reddi et al. "Adaptive
    # Federated Optimization"): treat the aggregated client delta as a
    # pseudo-gradient.  "none" = vanilla parameter averaging (paper).
    server_opt: str = "none"        # none | momentum | adam
    server_lr: float = 1.0
    server_momentum: float = 0.9
    eval_every: int = 10
    eval_batch: int = 256
    seed: int = 0

    def n_selected(self, n_clients: int) -> int:
        return max(1, int(round(self.participation * n_clients)))

    def local_spec(self) -> LocalSpec:
        variant = {"fedavg": "plain", "fedprox": "fedprox",
                   "scaffold": "scaffold", "moon": "moon"}[self.algorithm]
        return LocalSpec(
            n_steps=self.local_steps, batch_size=self.batch_size, lr=self.lr,
            momentum=self.momentum, weight_decay=self.weight_decay,
            variant=variant, mu=self.mu, temperature=self.temperature,
            grad_clip=self.grad_clip)


@dataclasses.dataclass
class ServerState:
    params: Pytree
    round: int = 0
    c_global: Optional[Pytree] = None      # scaffold
    c_clients: Optional[Pytree] = None     # scaffold, stacked (n_clients, ...)
    w_prev: Optional[Pytree] = None        # moon, stacked (n_clients, ...)


@dataclasses.dataclass
class FLResult:
    params: Pytree
    history: List[Dict[str, float]]
    state: ServerState

    def best(self, key: str = "acc") -> Dict[str, float]:
        rows = [h for h in self.history if key in h]
        return max(rows, key=lambda h: h[key]) if rows else {}


def _stack_copies(tree: Pytree, n: int) -> Pytree:
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (n,) + x.shape).copy(), tree)


def _tree_rows(tree: Pytree, ids: jnp.ndarray) -> Pytree:
    return jax.tree_util.tree_map(lambda x: x[ids], tree)


def _tree_set_rows(tree: Pytree, ids: jnp.ndarray, rows: Pytree) -> Pytree:
    return jax.tree_util.tree_map(lambda x, r: x.at[ids].set(r.astype(x.dtype)),
                                  tree, rows)


def make_round_fn(task: Task, cfg: FLConfig) -> Callable:
    """Build the jitted one-round update.

    signature: round_fn(key, params, x_all, y_all, ids, weights, lr_scale,
                        algo_state) -> (params, algo_state, metrics)
    where algo_state carries the algorithm's extra tensors (see below) and
    x_all/y_all are the full stacked client arrays living on device.
    """
    spec = cfg.local_spec()
    local = make_local_fn(task, spec)
    algo = cfg.algorithm

    @jax.jit
    def round_fn(key, params, x_all, y_all, ids, weights, lr_scale, algo_state):
        K = ids.shape[0]
        keys = jax.random.split(key, K)
        cx = x_all[ids]
        cy = y_all[ids]

        if algo in ("fedavg", "fedprox"):
            extras = {"w_global": params} if algo == "fedprox" else {}
            in_ext = jax.tree_util.tree_map(lambda _: None, extras)
            w_locals, aux = jax.vmap(
                local, in_axes=(0, None, in_ext, 0, 0, None))(
                keys, params, extras, cx, cy, lr_scale)
            new_params = tm.stacked_weighted_mean(w_locals, weights)
            return new_params, algo_state, {"local_loss": jnp.mean(aux["loss"])}

        if algo == "scaffold":
            c, c_all = algo_state["c_global"], algo_state["c_clients"]
            c_i = _tree_rows(c_all, ids)
            # per-client extras carry (c − c_i) with a leading K axis
            c_diff = jax.tree_util.tree_map(
                lambda g, l: jnp.broadcast_to(g[None], l.shape) - l, c, c_i)
            extras = {"c_diff": c_diff}
            w_locals, aux = jax.vmap(
                local, in_axes=(0, None, {"c_diff": 0}, 0, 0, None))(
                keys, params, extras, cx, cy, lr_scale)
            # control-variate update (option II): c_i⁺ = c_i − c + (w−w_i)/(S·lr)
            denom = spec.n_steps * spec.lr * lr_scale
            c_i_new = jax.tree_util.tree_map(
                lambda ci, cg, w, wl: ci - cg[None] + (w[None] - wl) / denom,
                c_i, c, params, w_locals)
            new_params = tm.stacked_weighted_mean(w_locals, weights)
            # c ← c + (K/N)·mean_i(c_i⁺ − c_i)
            n_clients = jax.tree_util.tree_leaves(c_all)[0].shape[0]
            frac = K / n_clients
            c_new = jax.tree_util.tree_map(
                lambda cg, new, old: cg + frac * jnp.mean(new - old, axis=0),
                c, c_i_new, c_i)
            c_all_new = _tree_set_rows(c_all, ids, c_i_new)
            state = {"c_global": c_new, "c_clients": c_all_new}
            return new_params, state, {"local_loss": jnp.mean(aux["loss"])}

        if algo == "moon":
            w_prev_all = algo_state["w_prev"]
            w_prev = _tree_rows(w_prev_all, ids)
            extras = {"w_global": params, "w_prev": w_prev}
            w_locals, aux = jax.vmap(
                local, in_axes=(0, None, {"w_global": None, "w_prev": 0}, 0, 0, None))(
                keys, params, extras, cx, cy, lr_scale)
            new_params = tm.stacked_weighted_mean(w_locals, weights)
            state = {"w_prev": _tree_set_rows(w_prev_all, ids, w_locals)}
            return new_params, state, {"local_loss": jnp.mean(aux["loss"])}

        raise ValueError(f"unknown algorithm {algo!r}")

    return round_fn


def make_server_update(cfg: FLConfig):
    """Server-side optimizer step (beyond-paper, Reddi et al. adaptive
    federated optimization): pseudo-gradient g = w − w_avg, so
    server_opt="momentum" with lr=1 reduces to FedAvgM and
    server_opt="none" to vanilla FedAvg (w ← w_avg exactly).

    Returns (init_fn, update_fn) or None for "none"."""
    if cfg.server_opt == "none":
        return None
    from repro.optim.optimizers import adamw, sgd
    if cfg.server_opt == "momentum":
        opt = sgd(cfg.server_lr, momentum=cfg.server_momentum)
    elif cfg.server_opt == "adam":
        opt = adamw(cfg.server_lr, b1=0.9, b2=0.99)
    else:
        raise ValueError(f"unknown server_opt {cfg.server_opt!r}")

    @jax.jit
    def update(params, avg_params, state):
        pseudo_grad = tm.sub(params, avg_params)
        return opt.apply(pseudo_grad, state, params)

    return opt.init, update


def make_eval_fn(task: Task, batch: int) -> Callable:
    @functools.partial(jax.jit, static_argnums=())
    def eval_batch(params, bx, by):
        return task.accuracy(params, bx, by)

    def evaluate(params, test_x, test_y) -> float:
        n = len(test_y)
        accs, ws = [], []
        for s in range(0, n, batch):
            bx = jnp.asarray(test_x[s:s + batch])
            by = jnp.asarray(test_y[s:s + batch])
            accs.append(float(eval_batch(params, bx, by)))
            ws.append(len(by))
        return float(np.average(accs, weights=ws))

    return evaluate


def init_server_state(task: Task, cfg: FLConfig, n_clients: int,
                      init_params: Optional[Pytree] = None,
                      key: Optional[jax.Array] = None) -> ServerState:
    if init_params is None:
        key = key if key is not None else jax.random.PRNGKey(cfg.seed)
        init_params = task.init(key)
    st = ServerState(params=init_params)
    if cfg.algorithm == "scaffold":
        st.c_global = tm.zeros_like(init_params)
        st.c_clients = _stack_copies(tm.zeros_like(init_params), n_clients)
    if cfg.algorithm == "moon":
        st.w_prev = _stack_copies(init_params, n_clients)
    return st


def run_federated(task: Task, data: FederatedDataset, cfg: FLConfig,
                  init_params: Optional[Pytree] = None,
                  ledger=None, verbose: bool = False,
                  eval_fn: Optional[Callable] = None) -> FLResult:
    """The P2 driver.  ``init_params`` is where CyclicFL plugs in: pass the
    P1-pre-trained model to get "Cyclic+<algorithm>"."""
    assert cfg.algorithm in ALGORITHMS, cfg.algorithm
    rng = np.random.default_rng(cfg.seed + 17)
    key = jax.random.PRNGKey(cfg.seed)

    state = init_server_state(task, cfg, data.n_clients, init_params, key)
    round_fn = make_round_fn(task, cfg)
    evaluate = eval_fn or make_eval_fn(task, cfg.eval_batch)

    x_all, y_all, n_real = data.device_arrays()
    K = cfg.n_selected(data.n_clients)
    history: List[Dict[str, float]] = []

    algo_state: Dict[str, Pytree] = {}
    if cfg.algorithm == "scaffold":
        algo_state = {"c_global": state.c_global, "c_clients": state.c_clients}
    elif cfg.algorithm == "moon":
        algo_state = {"w_prev": state.w_prev}

    server = make_server_update(cfg)
    server_state = server[0](state.params) if server else None

    params = state.params
    for rnd in range(cfg.rounds):
        ids = jnp.asarray(rng.choice(data.n_clients, size=K, replace=False))
        weights = n_real[ids].astype(jnp.float32)
        lr_scale = jnp.asarray(cfg.lr_decay ** rnd, jnp.float32)
        key, rk = jax.random.split(key)
        avg_params, algo_state, metrics = round_fn(
            rk, params, x_all, y_all, ids, weights, lr_scale, algo_state)
        if server is not None:
            params, server_state = server[1](params, avg_params, server_state)
        else:
            params = avg_params
        if ledger is not None:
            ledger.record_round(cfg.algorithm, K, params)
        row = {"round": rnd, "local_loss": float(metrics["local_loss"]),
               "phase": "P2"}
        if (rnd + 1) % cfg.eval_every == 0 or rnd == cfg.rounds - 1:
            row["acc"] = evaluate(params, data.test_x, data.test_y)
            if verbose:
                print(f"[{cfg.algorithm}] round {rnd + 1}/{cfg.rounds} "
                      f"loss={row['local_loss']:.4f} acc={row['acc']:.4f}",
                      flush=True)
        history.append(row)

    state.params = params
    state.round = cfg.rounds
    if cfg.algorithm == "scaffold":
        state.c_global = algo_state["c_global"]
        state.c_clients = algo_state["c_clients"]
    elif cfg.algorithm == "moon":
        state.w_prev = algo_state["w_prev"]
    return FLResult(params=params, history=history, state=state)
