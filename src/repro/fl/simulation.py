"""Federated-training simulation driver (P2) — a configuration shim over
the shared round engine (repro.fl.engine).

Each round compiles into ONE XLA program: the K selected clients' local
runs are a ``vmap`` over the stacked client axis, and the FedAvg
aggregation is a weighted mean over that axis — the exact computation
that becomes a ``psum`` over the mesh ``data`` axis on a pod (see
repro/launch/train.py for the sharded version).  The engine additionally
scans ``chunk_size`` rounds per dispatch and samples clients on device.

Algorithms: FedAvg, FedProx, SCAFFOLD, Moon — selected by name so
CyclicFL ("Cyclic+Y") composes with any of them.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional

import jax

from repro.data.federated import FederatedDataset
from repro.fl.engine import (
    ALGORITHMS,
    AggregateStrategy,
    RoundSchedule,
    make_eval_fn,
    run_rounds,
)
from repro.fl.compression import CompressionSpec, validate_compression
from repro.fl.local import LocalSpec
from repro.fl.privacy import DPSpec
from repro.fl.task import Task

Pytree = Any

__all__ = [
    "ALGORITHMS", "FLConfig", "ServerState", "FLResult", "make_round_fn",
    "make_server_update", "make_eval_fn", "init_server_state", "run_federated",
]

# the seed driver drew P2 client ids from np.random.default_rng(seed + 17)
HOST_RNG_OFFSET_P2 = 17


@dataclasses.dataclass(frozen=True)
class FLConfig:
    algorithm: str = "fedavg"
    rounds: int = 100
    participation: float = 0.1      # fraction of clients per round (K_P2)
    local_steps: int = 25           # SGD steps per client per round
    batch_size: int = 32
    lr: float = 0.01
    momentum: float = 0.0
    weight_decay: float = 0.0
    lr_decay: float = 0.998         # per-round multiplicative decay (paper)
    mu: float = 0.01                # fedprox / moon coefficient
    temperature: float = 0.5        # moon
    grad_clip: Optional[float] = None
    # server-side optimizer (beyond-paper; Reddi et al. "Adaptive
    # Federated Optimization"): treat the aggregated client delta as a
    # pseudo-gradient.  "none" = vanilla parameter averaging (paper).
    server_opt: str = "none"        # none | momentum | adam
    server_lr: float = 1.0
    server_momentum: float = 0.9
    eval_every: int = 10
    eval_batch: int = 256
    seed: int = 0
    chunk_size: int = 8             # rounds per XLA dispatch (engine)
    sampling: str = "device"        # device | host (seed-compatible)
    # step-tail/aggregation implementation: per-leaf tree algebra (the
    # parity oracle) or the fused flat-first path (params/moments ride
    # the engine as FlatView buffers, repro.kernels.fused_update);
    # "fused" auto-interprets off-TPU
    update_impl: str = "tree"       # tree | fused | fused_interpret
    # round-aggregate privacy (repro.fl.privacy): DP-FedAvg clip/noise
    # and/or the pairwise secure-agg mask simulation
    dp: Optional[DPSpec] = None
    secure_agg: bool = False
    # compressed P2 uploads (repro.fl.compression): block-quantized +
    # top-k sparsified client deltas with optional error feedback.
    # None / the identity spec compile to the exact baseline program.
    compression: Optional[CompressionSpec] = None
    # trainable-slice / PEFT (repro.fl.local): peft="lora:<r>" trains
    # only the adapter leaves — frozen leaves never enter the kernels,
    # the donated carry or the wire; trainable_filter selects a named
    # filter from repro.sharding.rules.TRAINABLE_FILTERS directly.
    # Needs the fused flat path.
    peft: Optional[str] = None
    trainable_filter: Optional[str] = None

    def __post_init__(self):
        from repro.fl.local import validate_peft, validate_update_impl
        validate_update_impl(self.update_impl)
        validate_compression(self.compression, dp=self.dp,
                             secure_agg=self.secure_agg)
        validate_peft(self.peft, trainable_filter=self.trainable_filter,
                      update_impl=self.update_impl)

    def n_selected(self, n_clients: int) -> int:
        return max(1, int(round(self.participation * n_clients)))

    def local_spec(self) -> LocalSpec:
        variant = {"fedavg": "plain", "fedprox": "fedprox",
                   "scaffold": "scaffold", "moon": "moon"}[self.algorithm]
        return LocalSpec(
            n_steps=self.local_steps, batch_size=self.batch_size, lr=self.lr,
            momentum=self.momentum, weight_decay=self.weight_decay,
            variant=variant, mu=self.mu, temperature=self.temperature,
            grad_clip=self.grad_clip, update_impl=self.update_impl,
            dp=self.dp, secure_agg=self.secure_agg,
            compression=self.compression, peft=self.peft,
            trainable_filter=self.trainable_filter)

    def strategy(self) -> AggregateStrategy:
        return AggregateStrategy(
            spec=self.local_spec(), algorithm=self.algorithm,
            participation=self.participation, server_opt=self.server_opt,
            server_lr=self.server_lr, server_momentum=self.server_momentum)

    def schedule(self) -> RoundSchedule:
        return RoundSchedule(
            rounds=self.rounds, lr_decay=self.lr_decay,
            eval_every=self.eval_every, eval_batch=self.eval_batch,
            seed=self.seed, chunk_size=self.chunk_size,
            sampling=self.sampling, host_rng_offset=HOST_RNG_OFFSET_P2)


@dataclasses.dataclass
class ServerState:
    params: Pytree
    round: int = 0
    c_global: Optional[Pytree] = None      # scaffold
    c_clients: Optional[Pytree] = None     # scaffold, stacked (n_clients, ...)
    w_prev: Optional[Pytree] = None        # moon, stacked (n_clients, ...)


@dataclasses.dataclass
class FLResult:
    params: Pytree
    history: List[Dict[str, float]]
    state: ServerState
    dispatches: int = 0             # chunk-program invocations (engine)

    def best(self, key: str = "acc") -> Dict[str, float]:
        rows = [h for h in self.history if key in h]
        return max(rows, key=lambda h: h[key]) if rows else {}


def make_round_fn(task: Task, cfg: FLConfig) -> Callable:
    """Build the jitted one-round update (single-round compatibility
    surface over AggregateStrategy — the loop lives in repro.fl.engine).

    signature: round_fn(key, params, x_all, y_all, ids, weights, lr_scale,
                        algo_state) -> (params, algo_state, metrics)
    The params contract is TREES regardless of ``update_impl`` — on the
    fused path this shim packs/unpacks at the boundary (the engine
    proper carries flat buffers end to end instead).
    """
    strategy = cfg.strategy()
    body = strategy.build_round(task)
    fops = strategy.flat_ops(task)

    @jax.jit
    def round_fn(key, params, x_all, y_all, ids, weights, lr_scale, algo_state):
        if fops is not None:
            params = fops.flatten(params)
        params, algo_state, loss = body(key, params, x_all, y_all, ids,
                                        weights, lr_scale, algo_state)
        if fops is not None:
            params = fops.unflatten(params)
        return params, algo_state, {"local_loss": loss}

    return round_fn


def make_server_update(cfg: FLConfig, task: Optional[Task] = None):
    """Server-side optimizer step; see AggregateStrategy.make_server_update.
    Returns (init_fn, jitted_update_fn) or None for "none" — both speak
    param TREES regardless of ``update_impl`` (on the fused path this
    shim packs/unpacks and the OptState moments ride flat inside).
    ``task`` is required on the fused path."""
    strategy = cfg.strategy()
    server = strategy.make_server_update(task)
    if server is None:
        return None
    fops = strategy.flat_ops(task) if cfg.update_impl != "tree" else None
    if fops is None:
        return server[0], jax.jit(server[1])

    def init(params):
        return server[0](fops.flatten(params))

    @jax.jit
    def update(params, avg_params, state):
        new_p, state = server[1](fops.flatten(params),
                                 fops.flatten(avg_params), state)
        return fops.unflatten(new_p), state

    return init, update


def init_server_state(task: Task, cfg: FLConfig, n_clients: int,
                      init_params: Optional[Pytree] = None,
                      key: Optional[jax.Array] = None) -> ServerState:
    if init_params is None:
        key = key if key is not None else jax.random.PRNGKey(cfg.seed)
        init_params = task.init(key)
    st = ServerState(params=init_params)
    algo_state = cfg.strategy().init_state(task, init_params, n_clients)
    st.c_global = algo_state.get("c_global")
    st.c_clients = algo_state.get("c_clients")
    st.w_prev = algo_state.get("w_prev")
    return st


def run_federated(task: Task, data: FederatedDataset, cfg: FLConfig,
                  init_params: Optional[Pytree] = None,
                  ledger=None, verbose: bool = False,
                  eval_fn: Optional[Callable] = None,
                  switch_policy=None, phase: str = "P2") -> FLResult:
    """The P2 driver.  ``init_params`` is where CyclicFL plugs in: pass the
    P1-pre-trained model to get "Cyclic+<algorithm>"."""
    assert cfg.algorithm in ALGORITHMS, cfg.algorithm
    res = run_rounds(task, data, cfg.strategy(), cfg.schedule(),
                     init_params=init_params, ledger=ledger, verbose=verbose,
                     eval_fn=eval_fn, switch_policy=switch_policy,
                     phase=phase, label=cfg.algorithm)
    state = ServerState(params=res.params, round=len(res.history),
                        c_global=res.algo_state.get("c_global"),
                        c_clients=res.algo_state.get("c_clients"),
                        w_prev=res.algo_state.get("w_prev"))
    return FLResult(params=res.params, history=res.history, state=state,
                    dispatches=res.dispatches)
