"""Client local training — the inner loop shared by P1 (cyclic) and P2 (FL).

One jit-friendly function runs ``n_steps`` of SGD on one client's shard,
with the algorithm-specific loss/gradient shaping injected through
``variant``:

  plain    : vanilla local SGD (FedAvg, and CyclicFL's P1)
  fedprox  : + (mu/2)·||w − w_global||²          [Li et al., MLSys'20]
  scaffold : g ← g − c_i + c  gradient correction [Karimireddy, ICML'20]
  moon     : + mu·contrastive(z, z_glob, z_prev)  [Li et al., CVPR'21]

The whole local run is a ``lax.scan`` over steps so a round compiles to
a single XLA program; batches are sampled inside the scan from the
client's fixed-size shard (uniform with replacement — the stochastic
approximation of the paper's epoch shuffling that keeps shapes static).

The post-gradient *step tail* — global-norm clip, scaffold correction,
decoupled weight decay, heavy-ball momentum, SGD axpy — has two
implementations behind ``LocalSpec.update_impl``:

  tree            : per-leaf ``tree_math`` algebra (the parity oracle);
                    the local fn takes and returns parameter TREES.
  fused[_interpret]: FLAT-FIRST — the local fn takes and returns
                    FlatView buffers; params/momentum ride the scan as
                    contiguous buffers, ``value_and_grad`` differentiates
                    w.r.t. the buffers themselves (the tree materializes
                    only inside the loss closure, at the model's
                    forward/backward boundary), so the backward emits
                    PACKED gradients — there is no per-step pack copy —
                    and the whole tail is ONE blocked Pallas pass per
                    step (repro.kernels.fused_update).  "fused" lowers
                    to Mosaic on TPU and auto-interprets on CPU;
                    "fused_interpret" forces the interpreter.

The buffer flavor is a backend decision carried by a
:class:`FlatParamOps` (host: 1-D per-dtype FlatView buffers, kernels
called directly; pod: ``repro.fl.pod.ShardedFlatOps`` — per-mesh-axis
group ``(n_shards, per_shard)`` buffers, kernels run shard-locally
under ``shard_map``).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.fl.compression import (CompressionSpec, topk_k, topk_threshold,
                                  validate_compression)
from repro.fl.privacy import DPSpec
from repro.fl.task import Task
from repro.kernels import ops
from repro.kernels.fused_update import GRID_ALIGN
from repro.utils import tree_math as tm
from repro.utils.flatten import FlatView

Pytree = Any

UPDATE_IMPLS = ("tree", "fused", "fused_interpret")


def validate_update_impl(update_impl: str) -> str:
    """Reject an unknown ``update_impl`` with the allowed values spelled
    out — shared by every spec/config so a typo fails at construction
    time, not deep inside the engine."""
    if update_impl not in UPDATE_IMPLS:
        raise ValueError(f"unknown update_impl {update_impl!r} "
                         f"(choose from {UPDATE_IMPLS})")
    return update_impl


def parse_peft(peft: str) -> Tuple[str, int]:
    """``"lora:<r>"`` → ``("lora", r)``, rejecting malformed specs the
    way :func:`validate_update_impl` rejects impls."""
    kind, sep, rank_s = peft.partition(":")
    if not sep or kind != "lora":
        raise ValueError(f"unknown peft spec {peft!r} "
                         f"(expected 'lora:<rank>')")
    try:
        rank = int(rank_s)
    except ValueError:
        raise ValueError(f"lora rank must be a positive integer, "
                         f"got {rank_s!r}") from None
    if rank <= 0:
        raise ValueError(f"lora rank must be a positive integer, got {rank}")
    return kind, rank


def validate_peft(peft: Optional[str], *,
                  trainable_filter: Optional[str] = None,
                  update_impl: str = "tree") -> Optional[str]:
    """Construction-time checks for the trainable-slice knobs: the peft
    spec must parse, and either knob requires the fused flat path —
    the tree backend has no trainable/frozen partition."""
    if peft is not None:
        parse_peft(peft)
    if (peft is not None or trainable_filter is not None) \
            and update_impl == "tree":
        raise ValueError(
            "peft/trainable_filter needs the fused flat path "
            "(update_impl='fused'|'fused_interpret') — the tree backend "
            "has no trainable-slice partition")
    return peft


def effective_trainable_filter(spec: "LocalSpec") -> Optional[str]:
    """The filter spec the round program runs under: an explicit
    ``trainable_filter`` wins; otherwise ``peft`` implies the named
    ``"lora"`` filter; ``None`` = every leaf trains (the full-filter
    oracle path, bitwise identical to the pre-filter program)."""
    if spec.trainable_filter is not None:
        return spec.trainable_filter
    if spec.peft is not None:
        return "lora"
    return None


@dataclasses.dataclass(frozen=True)
class LocalSpec:
    """Static description of one client's local-training run."""
    n_steps: int
    batch_size: int
    lr: float
    momentum: float = 0.0
    weight_decay: float = 0.0
    variant: str = "plain"          # plain | fedprox | scaffold | moon
    mu: float = 0.0                 # prox / moon coefficient
    temperature: float = 0.5        # moon
    grad_clip: Optional[float] = None
    update_impl: str = "tree"       # tree | fused | fused_interpret
    # round-aggregate privacy (repro.fl.privacy): DP-FedAvg clip+noise
    # on each client's round delta, and/or pairwise secure-agg masks.
    # Both apply at AGGREGATION — the local run itself is unchanged.
    dp: Optional[DPSpec] = None
    secure_agg: bool = False
    # compressed client→server uploads (repro.fl.compression): blockwise
    # int8/int16 quantization + magnitude top-k on each round delta,
    # optionally with error-feedback residuals.  Like dp/secure_agg this
    # applies at AGGREGATION only; None and the identity spec keep the
    # exact baseline program.
    compression: Optional[CompressionSpec] = None
    # trainable-slice / PEFT (ISSUE 10): peft="lora:<r>" declares the
    # model carries LoRA adapters of rank r (the model config must be
    # built with the matching ``lora_rank`` — see parse_peft) and
    # implies the "lora" trainable filter; trainable_filter names a
    # filter from repro.sharding.rules.TRAINABLE_FILTERS (or is a raw
    # path regex) selecting WHICH leaves train.  Either knob makes the
    # entire fused round program — grads, clip, step tail, aggregation,
    # server moments, the chunk carry, upload bytes — operate on the
    # trainable buckets only; frozen leaves ride outside the carry as a
    # read-only constant.  None/None is the full-filter oracle.
    peft: Optional[str] = None
    trainable_filter: Optional[str] = None

    def __post_init__(self):
        validate_update_impl(self.update_impl)
        validate_compression(self.compression, dp=self.dp,
                             secure_agg=self.secure_agg)
        validate_peft(self.peft, trainable_filter=self.trainable_filter,
                      update_impl=self.update_impl)


def _moon_contrastive(z: jnp.ndarray, z_glob: jnp.ndarray, z_prev: jnp.ndarray,
                      temperature: float) -> jnp.ndarray:
    """Model-contrastive loss: pull local representation toward the global
    model's, push away from the previous local model's."""

    def cos(a, b):
        a = a / (jnp.linalg.norm(a, axis=-1, keepdims=True) + 1e-12)
        b = b / (jnp.linalg.norm(b, axis=-1, keepdims=True) + 1e-12)
        return jnp.sum(a * b, axis=-1)

    sim_g = cos(z, z_glob) / temperature
    sim_p = cos(z, z_prev) / temperature
    return jnp.mean(-sim_g + jax.nn.logsumexp(jnp.stack([sim_g, sim_p]), axis=0))


# ---------------------------------------------------------------------------
# FlatParamOps — the canonical flat-buffer representation of one task
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FlatParamOps:
    """Bundle a packing plan with how to run the fused kernels on its
    buffers.  This is the *representation object* of the flat-first
    path: the engine carries params / momentum / server moments as the
    buffer dicts this produces, and every update stage goes through the
    dict-level methods below (one blocked kernel per bucket).

    The host flavor wraps a 1-D :class:`repro.utils.flatten.FlatView`
    and calls the kernels directly; the pod flavor
    (``repro.fl.pod.ShardedFlatOps``) swaps the view for a
    ShardedFlatView and overrides :meth:`_run` to execute each kernel
    shard-locally under ``shard_map`` — same math, mesh-resident
    buffers.
    """
    view: Any                       # FlatView | ShardedFlatView
    interpret: bool

    # -- representation -----------------------------------------------------

    def flatten(self, tree: Pytree) -> Dict[str, jnp.ndarray]:
        return self.view.flatten(tree)

    def unflatten(self, bufs: Dict[str, jnp.ndarray],
                  frozen: Optional[Dict[str, jnp.ndarray]] = None) -> Pytree:
        """Rebuild the tree from trainable buffers, merging ``frozen``
        (the read-only constant bucket dict) for filtered views; absent
        frozen buckets zero-fill — the right semantics for trees whose
        frozen slots are definitionally zero (server moments, deltas)."""
        return self.view.unflatten(bufs, frozen)

    @staticmethod
    def _pad_len(n: int) -> int:
        """Next GRID_ALIGN multiple ≥ n — the buffer length at which the
        kernel wrappers' per-call row pad degenerates to a reshape."""
        return -(-n // GRID_ALIGN) * GRID_ALIGN if n else 0

    @property
    def padded_sizes(self) -> Dict[str, int]:
        """Per-bucket carried length: logical size rounded up to the
        kernel grid, so every kernel call over a carried buffer hits the
        pad==0 fast path."""
        return {name: self._pad_len(size)
                for name, size in self.view.buffer_sizes.items()}

    def pad(self, bufs: Dict[str, jnp.ndarray]) -> Dict[str, jnp.ndarray]:
        """Right-pad each buffer's last axis up to the next GRID_ALIGN
        multiple (no-op on already-padded buffers).  Pad lanes start —
        and, by the kernel invariant, stay — zero, and unflatten reads
        only the logical prefix, so padded buffers flow through every
        dict-level op unchanged."""
        def _p(b):
            target = self._pad_len(b.shape[-1])
            if target == b.shape[-1]:
                return b
            widths = [(0, 0)] * (b.ndim - 1) + [(0, target - b.shape[-1])]
            return jnp.pad(b, widths)
        return {name: _p(b) for name, b in bufs.items()}

    def zeros(self, dtype=None) -> Dict[str, jnp.ndarray]:
        return self.pad(self.view.zeros(dtype))

    def normal(self, key) -> Dict[str, jnp.ndarray]:
        """Per-leaf standard-normal f32 buffers in carry layout (padded
        to the kernel grid — pad lanes zero, like every carried buffer).
        The draws are leaf-keyed (``view.normal``), so the tree oracle
        and both buffer flavors see identical bits for one key."""
        return self.pad(self.view.normal(key))

    def place(self, bufs: Dict[str, jnp.ndarray]) -> Dict[str, jnp.ndarray]:
        """Commit freshly packed buffers to their home placement AND
        guarantee they do not alias the caller's arrays — flatten is a
        NO-OP for a bucket holding exactly one 1-D leaf (concatenate of
        one array returns the operand), and the engine donates its
        carries, which would delete the caller's leaf.  Placement also
        pads to the kernel grid: carries enter the chunk pre-padded and
        every later kernel call skips its pad copy.  Host: copy (same
        cost as the tree path's place_params); pod: device_put with the
        per-bucket shardings, copying any passthrough."""
        return jax.tree_util.tree_map(jnp.array, self.pad(bufs))

    def shardings(self):
        """Per-bucket placement for jit in/out shardings (host: None)."""
        return None

    def stacked_flatten(self, tree: Pytree) -> Dict[str, jnp.ndarray]:
        return self.view.flatten_stacked(tree)

    def stacked_unflatten(self, bufs: Dict[str, jnp.ndarray],
                          frozen: Optional[Dict[str, jnp.ndarray]] = None
                          ) -> Pytree:
        """Stacked twin of :meth:`unflatten` — ``frozen`` rows (no K
        axis) broadcast over the stack."""
        return self.view.unflatten_stacked(bufs, frozen)

    # -- frozen bucket (filtered views; all no-ops when filter=None) --------

    def flatten_frozen(self, tree: Pytree) -> Dict[str, jnp.ndarray]:
        """Pack the FROZEN leaves — once per phase, never re-packed
        inside the round program.  Empty dict for an unfiltered view."""
        return self.view.flatten_frozen(tree)

    def frozen_zeros(self) -> Dict[str, jnp.ndarray]:
        return self.view.frozen_zeros()

    def place_frozen(self, bufs: Dict[str, jnp.ndarray]
                     ) -> Dict[str, jnp.ndarray]:
        """Commit the frozen constant bucket to its home placement.  NOT
        padded — frozen buffers never enter the kernels (unflatten reads
        the logical prefix only) — and NEVER donated: the same arrays
        are closed over by every chunk of a phase.  Host: plain copy;
        pod: device_put with the frozen-group shardings."""
        return jax.tree_util.tree_map(jnp.array, bufs)

    def frozen_shardings(self):
        """Placement of the frozen constant bucket (host: None)."""
        return None

    # -- kernel execution ---------------------------------------------------

    def _run(self, name: str, fn: Callable, bufs, scalars) -> Tuple:
        """Run ``fn(*1-D buffers, *traced scalars) -> tuple of 1-D
        buffers`` for bucket ``name``.  Subclasses reroute this through
        shard_map; ``n_out`` only matters there."""
        del name
        return fn(*bufs, *scalars)

    def _logical_size(self, name: str) -> int:
        """Logical element count of bucket ``name`` as ONE kernel
        invocation sees it — the top-k population (pad lanes are zero
        and zeros never change the k-th largest |d|, so a logical k over
        a padded buffer is exact).  Host: the FlatView bucket size; the
        pod override returns the PER-SHARD size (shard-local top-k)."""
        return self.view.buffer_sizes[name]

    def grad_sqsum(self, g_bufs: Dict[str, jnp.ndarray]) -> jnp.ndarray:
        """Σ‖g‖² over every bucket — the global clip norm is one
        reduction per bucket (sharded buffers reduce over the mesh)."""
        return sum(jnp.vdot(g, g) for g in g_bufs.values())

    def local_step(self, p_bufs, g_bufs, m_bufs, c_bufs, clip_scale,
                   step_size, *, weight_decay: float, momentum: float):
        """The fused client step tail over every bucket.  Returns
        ``(p_bufs, m_bufs)`` (``m_bufs`` empty when momentum is off)."""
        has_m, has_c = bool(momentum), c_bufs is not None
        interpret = self.interpret

        def fn(*a):
            it = iter(a)
            p1, g1 = next(it), next(it)
            m1 = next(it) if has_m else None
            c1 = next(it) if has_c else None
            cs, ss = next(it), next(it)
            pn, mn = ops.fused_local_step(
                p1, g1, m1, c1, cs, ss, weight_decay=weight_decay,
                momentum=momentum, interpret=interpret)
            return (pn, mn) if has_m else (pn,)

        new_p, new_m = {}, {}
        for name, p in p_bufs.items():
            bufs = [p, g_bufs[name]]
            if has_m:
                bufs.append(m_bufs[name])
            if has_c:
                bufs.append(c_bufs[name])
            outs = self._run(name, fn, bufs, (clip_scale, step_size))
            new_p[name] = outs[0]
            if has_m:
                new_m[name] = outs[1]
        return new_p, new_m

    def weighted_delta(self, p_bufs, stacked_bufs, wbar, extra=None, *,
                       deltas: bool = False):
        """Host FedAvg aggregation: the vmapped local outputs arrive as
        already-stacked ``(K, N)`` buffers — no re-concatenate.
        ``extra`` (optional f32 buffer dict — the round's DP noise +
        secure-agg mask total) folds into the same kernel pass.
        ``deltas=True`` reads the stack as already-formed client deltas
        (the compressed-communication aggregate)."""
        return {name: ops.fused_weighted_delta(
            stacked_bufs[name], p, wbar,
            None if extra is None else extra[name],
            deltas=deltas, interpret=self.interpret)
            for name, p in p_bufs.items()}

    def compress_delta(self, d_bufs, spec: CompressionSpec):
        """Compressed-communication form of one client's f32 delta dict
        — ``(c_bufs, r_bufs)``, ``r_bufs=None`` unless error feedback.
        The top-k threshold is computed INSIDE the per-bucket fn (one
        ``lax.top_k`` + one blocked kernel pass), so the pod flavor
        thresholds shard-locally under shard_map with zero collectives
        — each shard keeps its own k over its own elements."""
        interpret = self.interpret
        with_r = spec.error_feedback

        def make_fn(k):
            def fn(d1):
                tau = (topk_threshold(d1, k) if spec.sparsifies
                       else jnp.float32(0.0))
                out = ops.fused_compress_delta(
                    d1, tau, bits=spec.bits, topk=spec.sparsifies,
                    with_residual=with_r, interpret=interpret)
                return out if with_r else (out,)
            return fn

        c_out, r_out = {}, {}
        for name, d in d_bufs.items():
            k = topk_k(spec, self._logical_size(name))
            outs = self._run(name, make_fn(k), [d], ())
            c_out[name] = outs[0]
            if with_r:
                r_out[name] = outs[1]
        return c_out, (r_out if with_r else None)

    def dp_clip_noise(self, d_bufs, z_bufs, clip_scale, noise_scale):
        """One client's DP upload per bucket in ONE blocked pass:
        ``clip_scale·d₃₂ (+ noise_scale·z)`` (``z_bufs=None`` statically
        drops the Gaussian term).  The production aggregates fold these
        terms into ``weighted_delta``/``delta_accum`` coefficients and
        extras instead; this is the standalone kernel form for callers
        that materialize per-client uploads."""
        interpret = self.interpret
        has_z = z_bufs is not None

        def fn(*a):
            it = iter(a)
            d1 = next(it)
            z1 = next(it) if has_z else None
            cs, ns = next(it), next(it)
            return (ops.fused_dp_clip_noise(d1, z1, cs, ns,
                                            interpret=interpret),)

        out = {}
        for name, d in d_bufs.items():
            bufs = [d] + ([z_bufs[name]] if has_z else [])
            out[name] = self._run(name, fn, bufs,
                                  (clip_scale, noise_scale))[0]
        return out

    def delta_accum(self, delta_bufs, w_bufs, p_bufs, coeff):
        """One client's contribution to the pod's running f32 delta.
        ``p_bufs=None`` selects the accum-only form ``acc += coeff·w``
        (compressed uploads ARE deltas — there is no −coeff·p term)."""
        interpret = self.interpret
        with_p = p_bufs is not None

        def fn(*a):
            if with_p:
                d1, w1, p1, c1 = a
            else:
                (d1, w1, c1), p1 = a, None
            return (ops.fused_delta_accum(d1, w1, p1, c1,
                                          interpret=interpret),)

        return {name: self._run(
                    name, fn,
                    [d, w_bufs[name]] + ([p_bufs[name]] if with_p else []),
                    (coeff,))[0]
                for name, d in delta_bufs.items()}

    def apply_delta(self, p_bufs, delta_bufs):
        """p ← cast(p₃₂ + delta) per bucket (server_opt="none")."""
        new_p, _ = self.server_update(p_bufs, delta_bufs, (), (1.0,),
                                      opt="none")
        return new_p

    def server_update(self, p_bufs, delta_bufs, moments, scalars, *,
                      opt: str, beta: float = 0.9, b1: float = 0.9,
                      b2: float = 0.99):
        """Server optimizer over every bucket.  ``moments`` is a tuple
        of buffer dicts mirroring ``p_bufs`` (() for "none", (m,) for
        momentum, (mu, nu) for adam); ``scalars`` the traced scalars the
        kernel expects.  Returns ``(p_bufs, new_moments)``."""
        interpret = self.interpret
        n_m = len(moments)

        def fn(*a):
            it = iter(a)
            p1, d1 = next(it), next(it)
            ms = tuple(next(it) for _ in range(n_m))
            sc = tuple(it)
            pn, new = ops.fused_server_update(
                p1, d1, ms, sc, opt=opt, beta=beta, b1=b1, b2=b2,
                interpret=interpret)
            return (pn,) + tuple(new)

        new_p = {}
        new_ms: Tuple[Dict, ...] = tuple({} for _ in range(n_m))
        for name, p in p_bufs.items():
            bufs = [p, delta_bufs[name]] + [m[name] for m in moments]
            outs = self._run(name, fn, bufs, tuple(scalars))
            new_p[name] = outs[0]
            for i in range(n_m):
                new_ms[i][name] = outs[1 + i]
        return new_p, new_ms


@functools.lru_cache(maxsize=64)
def host_flat_ops(task: Task, interpret: bool,
                  filter_spec: Optional[str] = None) -> FlatParamOps:
    """The host backend's FlatParamOps for one task (cached — Task is a
    frozen dataclass).  ``filter_spec`` (a TRAINABLE_FILTERS name or a
    path regex) partitions the view into trainable/frozen buckets;
    None keeps the historical all-trainable view bitwise."""
    p_specs = jax.eval_shape(task.init, jax.random.PRNGKey(0))
    filt = None
    if filter_spec is not None:
        from repro.sharding import rules  # local import: rules ← flatten only
        filt = rules.trainable_mask(p_specs, filter_spec)
    return FlatParamOps(view=FlatView.of(p_specs, filter=filt),
                        interpret=interpret)


# ---------------------------------------------------------------------------
# the step tail — tree oracle and fused flat-buffer twin
# ---------------------------------------------------------------------------

def tree_step_tail(spec: LocalSpec, params: Pytree, grads: Pytree,
                   mom: Pytree, c_diff: Optional[Pytree], lr_scale):
    """The per-leaf reference update (clip → correction → decay →
    momentum → axpy).  Returns ``(params, mom)``."""
    # clip the RAW stochastic gradient, then apply the scaffold
    # correction and decoupled weight decay — clipping after decay
    # would rescale the regularizer with the gradient noise
    if spec.grad_clip:
        grads = tm.global_clip(grads, spec.grad_clip)
    if c_diff is not None:
        grads = tm.add(grads, c_diff)
    if spec.weight_decay:
        grads = tm.add_scaled(grads, params, spec.weight_decay)
    if spec.momentum:
        mom = tm.add_scaled(grads, mom, spec.momentum)
        eff = mom
    else:
        eff = grads
    params = jax.tree_util.tree_map(
        lambda p, g: (p - spec.lr * lr_scale * g).astype(p.dtype),
        params, eff)
    return params, mom


def fused_step_tail(spec: LocalSpec, fops: FlatParamOps, p_bufs: Dict,
                    g_bufs: Dict, m_bufs: Dict, c_bufs: Optional[Dict],
                    lr_scale):
    """The same tail over flat buffers: the global clip norm is ONE
    reduction per bucket and the rest is one fused kernel per bucket —
    O(1) ops per step regardless of tree depth."""
    if spec.grad_clip:
        sq = fops.grad_sqsum(g_bufs)
        clip_scale = jnp.minimum(
            1.0, spec.grad_clip / (jnp.sqrt(sq) + 1e-12)).astype(jnp.float32)
    else:
        clip_scale = jnp.float32(1.0)
    step_size = spec.lr * lr_scale
    return fops.local_step(p_bufs, g_bufs, m_bufs, c_bufs, clip_scale,
                           step_size, weight_decay=spec.weight_decay,
                           momentum=spec.momentum)


def make_local_fn(task: Task, spec: LocalSpec,
                  flat_ops: Optional[FlatParamOps] = None) -> Callable:
    """Build the per-client local-training function.

    tree impl : ``local(key, w_start, extras, cx, cy, lr_scale,
                frozen=None) -> (w_end, aux)`` over parameter TREES
                (``frozen`` is ignored — the tree path has no
                trainable-slice partition).
    fused impl: the SAME signature over flat buffer dicts — ``w_start``
                and ``w_end`` are FlatParamOps buffers holding ONLY the
                trainable slice; ``frozen`` is the read-only constant
                bucket dict merged at the loss boundary (never
                differentiated, never in the scan carry).  The tree
                exists only inside the loss closure (forward/backward
                boundary).  ``flat_ops`` selects the buffer flavor and
                defaults to the host FlatView ops for this task.

    extras (algorithm context, zero-size pytrees when unused; always
    TREES — they feed the loss at the forward boundary):
      w_global : anchor for fedprox / moon
      c_diff   : (c − c_i) correction for scaffold
      w_prev   : previous local model for moon
    aux: {'loss': mean local loss}
    """

    def loss_for_variant(params, extras, bx, by, rng):
        base = task.loss_fn(params, bx, by, rng)
        if spec.variant == "fedprox":
            prox = 0.5 * spec.mu * tm.squared_norm(tm.sub(params, extras["w_global"]))
            return base + prox
        if spec.variant == "moon":
            z = task.repr_fn(params, bx)
            z_glob = jax.lax.stop_gradient(task.repr_fn(extras["w_global"], bx))
            z_prev = jax.lax.stop_gradient(task.repr_fn(extras["w_prev"], bx))
            return base + spec.mu * _moon_contrastive(z, z_glob, z_prev,
                                                      spec.temperature)
        return base

    fused = spec.update_impl != "tree"
    if fused and flat_ops is None:
        flat_ops = host_flat_ops(task, ops.fused_interpret(spec.update_impl),
                                 effective_trainable_filter(spec))

    def local_tree(key: jax.Array, w_start: Pytree, extras: Dict[str, Pytree],
                   cx: jnp.ndarray, cy: jnp.ndarray, lr_scale: jnp.ndarray,
                   frozen: Optional[Dict] = None):
        del frozen  # tree path has no trainable-slice partition
        grad_fn = jax.value_and_grad(loss_for_variant)
        n_data = cx.shape[0]
        mom0 = tm.zeros_like(w_start) if spec.momentum else ()
        c_diff = extras["c_diff"] if spec.variant == "scaffold" else None

        def step(carry, step_key):
            params, mom = carry
            bidx = jax.random.randint(step_key, (spec.batch_size,), 0, n_data)
            loss, grads = grad_fn(params, extras, cx[bidx], cy[bidx], step_key)
            params, mom = tree_step_tail(spec, params, grads, mom, c_diff,
                                         lr_scale)
            return (params, mom), loss

        keys = jax.random.split(key, spec.n_steps)
        (w_end, _), losses = jax.lax.scan(step, (w_start, mom0), keys)
        return w_end, {"loss": jnp.mean(losses)}

    def local_fused(key: jax.Array, p_start: Dict, extras: Dict[str, Pytree],
                    cx: jnp.ndarray, cy: jnp.ndarray, lr_scale: jnp.ndarray,
                    frozen: Optional[Dict] = None):
        n_data = cx.shape[0]
        # momentum mirrors the incoming buffers exactly (padded or not),
        # so the scan carry is shape-consistent however p_start arrived
        m0 = ({name: jnp.zeros_like(b) for name, b in p_start.items()}
              if spec.momentum else {})
        if spec.variant != "scaffold":
            c_bufs = None
        elif "c_diff_flat" in extras:
            # flat-state store: the correction is already a buffer dict
            # in carry layout — no per-client flatten
            c_bufs = extras["c_diff_flat"]
        else:
            c_bufs = flat_ops.pad(flat_ops.flatten(extras["c_diff"]))

        # differentiate w.r.t. the FLAT buffers: the tree materializes
        # only here, inside the loss closure, so the backward's
        # cotangents land directly in packed buffer form — the per-step
        # pack copy of the PR-4 flow does not exist.  ``frozen`` enters
        # as a closed-over constant on the non-differentiated side, so
        # the backward never touches (or allocates cotangents for) the
        # frozen leaves.
        def flat_loss(p_bufs, bx, by, rng):
            return loss_for_variant(flat_ops.unflatten(p_bufs, frozen),
                                    extras, bx, by, rng)

        grad_fn = jax.value_and_grad(flat_loss)

        def step(carry, step_key):
            p_bufs, m_bufs = carry
            bidx = jax.random.randint(step_key, (spec.batch_size,), 0, n_data)
            loss, g_bufs = grad_fn(p_bufs, cx[bidx], cy[bidx], step_key)
            p_bufs, m_bufs = fused_step_tail(spec, flat_ops, p_bufs, g_bufs,
                                             m_bufs, c_bufs, lr_scale)
            return (p_bufs, m_bufs), loss

        keys = jax.random.split(key, spec.n_steps)
        (p_end, _), losses = jax.lax.scan(step, (p_start, m0), keys)
        return p_end, {"loss": jnp.mean(losses)}

    return local_fused if fused else local_tree
