"""Client local training — the inner loop shared by P1 (cyclic) and P2 (FL).

One jit-friendly function runs ``n_steps`` of SGD on one client's shard,
with the algorithm-specific loss/gradient shaping injected through
``variant``:

  plain    : vanilla local SGD (FedAvg, and CyclicFL's P1)
  fedprox  : + (mu/2)·||w − w_global||²          [Li et al., MLSys'20]
  scaffold : g ← g − c_i + c  gradient correction [Karimireddy, ICML'20]
  moon     : + mu·contrastive(z, z_glob, z_prev)  [Li et al., CVPR'21]

The whole local run is a ``lax.scan`` over steps so a round compiles to
a single XLA program; batches are sampled inside the scan from the
client's fixed-size shard (uniform with replacement — the stochastic
approximation of the paper's epoch shuffling that keeps shapes static).

The post-gradient *step tail* — global-norm clip, scaffold correction,
decoupled weight decay, heavy-ball momentum, SGD axpy — has two
implementations behind ``LocalSpec.update_impl``:

  tree            : per-leaf ``tree_math`` algebra (the parity oracle)
  fused[_interpret]: params/momentum ride the scan as contiguous
                    FlatView buffers (repro.utils.flatten) and the whole
                    tail is ONE blocked Pallas pass per step
                    (repro.kernels.fused_update) — O(1) update kernels
                    per step instead of O(n_leaves) leaf ops.  "fused"
                    lowers to Mosaic on TPU and auto-interprets on CPU;
                    "fused_interpret" forces the interpreter.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.fl.task import Task
from repro.kernels import ops
from repro.utils import tree_math as tm
from repro.utils.flatten import FlatView

Pytree = Any

UPDATE_IMPLS = ("tree", "fused", "fused_interpret")


@dataclasses.dataclass(frozen=True)
class LocalSpec:
    """Static description of one client's local-training run."""
    n_steps: int
    batch_size: int
    lr: float
    momentum: float = 0.0
    weight_decay: float = 0.0
    variant: str = "plain"          # plain | fedprox | scaffold | moon
    mu: float = 0.0                 # prox / moon coefficient
    temperature: float = 0.5        # moon
    grad_clip: Optional[float] = None
    update_impl: str = "tree"       # tree | fused | fused_interpret

    def __post_init__(self):
        if self.update_impl not in UPDATE_IMPLS:
            raise ValueError(f"unknown update_impl {self.update_impl!r} "
                             f"(choose from {UPDATE_IMPLS})")


def _moon_contrastive(z: jnp.ndarray, z_glob: jnp.ndarray, z_prev: jnp.ndarray,
                      temperature: float) -> jnp.ndarray:
    """Model-contrastive loss: pull local representation toward the global
    model's, push away from the previous local model's."""

    def cos(a, b):
        a = a / (jnp.linalg.norm(a, axis=-1, keepdims=True) + 1e-12)
        b = b / (jnp.linalg.norm(b, axis=-1, keepdims=True) + 1e-12)
        return jnp.sum(a * b, axis=-1)

    sim_g = cos(z, z_glob) / temperature
    sim_p = cos(z, z_prev) / temperature
    return jnp.mean(-sim_g + jax.nn.logsumexp(jnp.stack([sim_g, sim_p]), axis=0))


# ---------------------------------------------------------------------------
# the step tail — tree oracle and fused flat-buffer twin
# ---------------------------------------------------------------------------

def tree_step_tail(spec: LocalSpec, params: Pytree, grads: Pytree,
                   mom: Pytree, c_diff: Optional[Pytree], lr_scale):
    """The per-leaf reference update (clip → correction → decay →
    momentum → axpy).  Returns ``(params, mom)``."""
    # clip the RAW stochastic gradient, then apply the scaffold
    # correction and decoupled weight decay — clipping after decay
    # would rescale the regularizer with the gradient noise
    if spec.grad_clip:
        grads = tm.global_clip(grads, spec.grad_clip)
    if c_diff is not None:
        grads = tm.add(grads, c_diff)
    if spec.weight_decay:
        grads = tm.add_scaled(grads, params, spec.weight_decay)
    if spec.momentum:
        mom = tm.add_scaled(grads, mom, spec.momentum)
        eff = mom
    else:
        eff = grads
    params = jax.tree_util.tree_map(
        lambda p, g: (p - spec.lr * lr_scale * g).astype(p.dtype),
        params, eff)
    return params, mom


def fused_step_tail(spec: LocalSpec, p_bufs: Dict, g_bufs: Dict,
                    m_bufs: Dict, c_bufs: Optional[Dict], lr_scale, *,
                    interpret: bool):
    """The same tail over FlatView buffers: the global clip norm is ONE
    reduction per dtype bucket and the rest is one fused kernel per
    bucket — O(1) ops per step regardless of tree depth."""
    if spec.grad_clip:
        sq = sum(jnp.vdot(g, g) for g in g_bufs.values())
        clip_scale = jnp.minimum(
            1.0, spec.grad_clip / (jnp.sqrt(sq) + 1e-12)).astype(jnp.float32)
    else:
        clip_scale = jnp.float32(1.0)
    step_size = spec.lr * lr_scale
    new_p, new_m = {}, {}
    for name, p in p_bufs.items():
        pn, mn = ops.fused_local_step(
            p, g_bufs[name],
            m_bufs[name] if spec.momentum else None,
            c_bufs[name] if c_bufs is not None else None,
            clip_scale, step_size,
            weight_decay=spec.weight_decay, momentum=spec.momentum,
            interpret=interpret)
        new_p[name] = pn
        if spec.momentum:
            new_m[name] = mn
    return new_p, new_m


def make_local_fn(task: Task, spec: LocalSpec) -> Callable:
    """Build ``local(key, w_start, extras, cx, cy, lr_scale) -> (w_end, aux)``.

    extras (algorithm context, zero-size pytrees when unused):
      w_global : anchor for fedprox / moon
      c_diff   : (c − c_i) correction for scaffold
      w_prev   : previous local model for moon
    aux: {'loss': mean local loss}
    """

    def loss_for_variant(params, extras, bx, by, rng):
        base = task.loss_fn(params, bx, by, rng)
        if spec.variant == "fedprox":
            prox = 0.5 * spec.mu * tm.squared_norm(tm.sub(params, extras["w_global"]))
            return base + prox
        if spec.variant == "moon":
            z = task.repr_fn(params, bx)
            z_glob = jax.lax.stop_gradient(task.repr_fn(extras["w_global"], bx))
            z_prev = jax.lax.stop_gradient(task.repr_fn(extras["w_prev"], bx))
            return base + spec.mu * _moon_contrastive(z, z_glob, z_prev,
                                                      spec.temperature)
        return base

    grad_fn = jax.value_and_grad(loss_for_variant)
    fused = spec.update_impl != "tree"
    interpret = ops.fused_interpret(spec.update_impl)

    def local_tree(key: jax.Array, w_start: Pytree, extras: Dict[str, Pytree],
                   cx: jnp.ndarray, cy: jnp.ndarray, lr_scale: jnp.ndarray):
        n_data = cx.shape[0]
        mom0 = tm.zeros_like(w_start) if spec.momentum else ()
        c_diff = extras["c_diff"] if spec.variant == "scaffold" else None

        def step(carry, step_key):
            params, mom = carry
            bidx = jax.random.randint(step_key, (spec.batch_size,), 0, n_data)
            loss, grads = grad_fn(params, extras, cx[bidx], cy[bidx], step_key)
            params, mom = tree_step_tail(spec, params, grads, mom, c_diff,
                                         lr_scale)
            return (params, mom), loss

        keys = jax.random.split(key, spec.n_steps)
        (w_end, _), losses = jax.lax.scan(step, (w_start, mom0), keys)
        return w_end, {"loss": jnp.mean(losses)}

    def local_fused(key: jax.Array, w_start: Pytree, extras: Dict[str, Pytree],
                    cx: jnp.ndarray, cy: jnp.ndarray, lr_scale: jnp.ndarray):
        n_data = cx.shape[0]
        view = FlatView.of(w_start)
        p0 = view.flatten(w_start)
        m0 = view.zeros() if spec.momentum else {}
        c_bufs = (view.flatten(extras["c_diff"])
                  if spec.variant == "scaffold" else None)

        def step(carry, step_key):
            p_bufs, m_bufs = carry
            params = view.unflatten(p_bufs)
            bidx = jax.random.randint(step_key, (spec.batch_size,), 0, n_data)
            loss, grads = grad_fn(params, extras, cx[bidx], cy[bidx], step_key)
            p_bufs, m_bufs = fused_step_tail(
                spec, p_bufs, view.flatten(grads), m_bufs, c_bufs, lr_scale,
                interpret=interpret)
            return (p_bufs, m_bufs), loss

        keys = jax.random.split(key, spec.n_steps)
        (p_end, _), losses = jax.lax.scan(step, (p0, m0), keys)
        return view.unflatten(p_end), {"loss": jnp.mean(losses)}

    return local_fused if fused else local_tree
