"""Client local training — the inner loop shared by P1 (cyclic) and P2 (FL).

One jit-friendly function runs ``n_steps`` of SGD on one client's shard,
with the algorithm-specific loss/gradient shaping injected through
``variant``:

  plain    : vanilla local SGD (FedAvg, and CyclicFL's P1)
  fedprox  : + (mu/2)·||w − w_global||²          [Li et al., MLSys'20]
  scaffold : g ← g − c_i + c  gradient correction [Karimireddy, ICML'20]
  moon     : + mu·contrastive(z, z_glob, z_prev)  [Li et al., CVPR'21]

The whole local run is a ``lax.scan`` over steps so a round compiles to
a single XLA program; batches are sampled inside the scan from the
client's fixed-size shard (uniform with replacement — the stochastic
approximation of the paper's epoch shuffling that keeps shapes static).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.fl.task import Task
from repro.utils import tree_math as tm

Pytree = Any


@dataclasses.dataclass(frozen=True)
class LocalSpec:
    """Static description of one client's local-training run."""
    n_steps: int
    batch_size: int
    lr: float
    momentum: float = 0.0
    weight_decay: float = 0.0
    variant: str = "plain"          # plain | fedprox | scaffold | moon
    mu: float = 0.0                 # prox / moon coefficient
    temperature: float = 0.5        # moon
    grad_clip: Optional[float] = None


def _moon_contrastive(z: jnp.ndarray, z_glob: jnp.ndarray, z_prev: jnp.ndarray,
                      temperature: float) -> jnp.ndarray:
    """Model-contrastive loss: pull local representation toward the global
    model's, push away from the previous local model's."""

    def cos(a, b):
        a = a / (jnp.linalg.norm(a, axis=-1, keepdims=True) + 1e-12)
        b = b / (jnp.linalg.norm(b, axis=-1, keepdims=True) + 1e-12)
        return jnp.sum(a * b, axis=-1)

    sim_g = cos(z, z_glob) / temperature
    sim_p = cos(z, z_prev) / temperature
    return jnp.mean(-sim_g + jax.nn.logsumexp(jnp.stack([sim_g, sim_p]), axis=0))


def make_local_fn(task: Task, spec: LocalSpec) -> Callable:
    """Build ``local(key, w_start, extras, cx, cy, lr_scale) -> (w_end, aux)``.

    extras (algorithm context, zero-size pytrees when unused):
      w_global : anchor for fedprox / moon
      c_diff   : (c − c_i) correction for scaffold
      w_prev   : previous local model for moon
    aux: {'loss': mean local loss}
    """

    def loss_for_variant(params, extras, bx, by, rng):
        base = task.loss_fn(params, bx, by, rng)
        if spec.variant == "fedprox":
            prox = 0.5 * spec.mu * tm.squared_norm(tm.sub(params, extras["w_global"]))
            return base + prox
        if spec.variant == "moon":
            z = task.repr_fn(params, bx)
            z_glob = jax.lax.stop_gradient(task.repr_fn(extras["w_global"], bx))
            z_prev = jax.lax.stop_gradient(task.repr_fn(extras["w_prev"], bx))
            return base + spec.mu * _moon_contrastive(z, z_glob, z_prev,
                                                      spec.temperature)
        return base

    grad_fn = jax.value_and_grad(loss_for_variant)

    def local(key: jax.Array, w_start: Pytree, extras: Dict[str, Pytree],
              cx: jnp.ndarray, cy: jnp.ndarray, lr_scale: jnp.ndarray):
        n_data = cx.shape[0]
        mom0 = tm.zeros_like(w_start) if spec.momentum else ()

        def step(carry, step_key):
            params, mom = carry
            bidx = jax.random.randint(step_key, (spec.batch_size,), 0, n_data)
            loss, grads = grad_fn(params, extras, cx[bidx], cy[bidx], step_key)
            # clip the RAW stochastic gradient, then apply the scaffold
            # correction and decoupled weight decay — clipping after decay
            # would rescale the regularizer with the gradient noise
            if spec.grad_clip:
                grads = tm.global_clip(grads, spec.grad_clip)
            if spec.variant == "scaffold":
                grads = tm.add(grads, extras["c_diff"])
            if spec.weight_decay:
                grads = tm.add_scaled(grads, params, spec.weight_decay)
            if spec.momentum:
                mom = tm.add_scaled(grads, mom, spec.momentum)
                eff = mom
            else:
                eff = grads
            params = jax.tree_util.tree_map(
                lambda p, g: (p - spec.lr * lr_scale * g).astype(p.dtype),
                params, eff)
            return (params, mom), loss

        keys = jax.random.split(key, spec.n_steps)
        (w_end, _), losses = jax.lax.scan(step, (w_start, mom0), keys)
        return w_end, {"loss": jnp.mean(losses)}

    return local
