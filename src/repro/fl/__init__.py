from repro.fl.task import Task, vision_task, charlm_task, lm_task
from repro.fl.local import LocalSpec, make_local_fn
from repro.fl.engine import (
    AggregateStrategy,
    EngineResult,
    RelayStrategy,
    RoundSchedule,
    batch_test_set,
    make_accuracy_metric,
    run_rounds,
)
from repro.fl.simulation import (
    ALGORITHMS,
    FLConfig,
    FLResult,
    ServerState,
    run_federated,
    make_round_fn,
    make_eval_fn,
    init_server_state,
)
# NOTE: repro.fl.pod (the sharded backend) is intentionally NOT imported
# here — it imports repro.core.pipeline to register its phase configs,
# and pulling it into the package __init__ would close an import cycle
# (core.pipeline -> fl.simulation -> this __init__).  Import it directly:
#   from repro.fl.pod import PodRelayStrategy, PodAggregateStrategy, ...
