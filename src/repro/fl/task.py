"""Task abstraction: binds a model family to loss / representation /
prediction functions so the FL machinery is model-agnostic.

CyclicFL constrains the *training schedule*, not the model, so the same
client-update and aggregation code must drive the paper's CNNs/LSTM and
the assigned LLM-class architectures.  A ``Task`` is the adapter.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.models import paper_models as pm
from repro.models.transformer import TransformerConfig, init_lm, lm_loss, lm_forward

Pytree = Any


@dataclasses.dataclass(frozen=True)
class Task:
    """A learnable task: everything FL algorithms need about the model.

    loss_fn(params, bx, by, rng) -> scalar loss            (local SGD)
    repr_fn(params, bx)          -> (B, d) representation  (Moon contrast)
    predict_fn(params, bx)       -> predicted int labels   (test accuracy)
    """

    name: str
    kind: str                      # vision | charlm | tokenlm
    init: Callable[[jax.Array], Pytree]
    loss_fn: Callable[..., jnp.ndarray]
    repr_fn: Callable[[Pytree, jnp.ndarray], jnp.ndarray]
    predict_fn: Callable[[Pytree, jnp.ndarray], jnp.ndarray]

    def accuracy(self, params: Pytree, x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
        pred = self.predict_fn(params, x)
        return jnp.mean((pred == y).astype(jnp.float32))


def _softmax_xent(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def vision_task(model: str = "lenet5", n_classes: int = 10, in_ch: int = 3,
                seed_kwargs: Optional[dict] = None) -> Task:
    """Paper vision models (LeNet-5, ResNet-8, CNN-FEMNIST, CNN-Fashion)."""
    init_fn, apply_fn, kind = pm.PAPER_MODELS.get(model)
    kw = seed_kwargs or {}

    def init(key):
        return init_fn(key, n_classes=n_classes, in_ch=in_ch, **kw)

    def loss_fn(params, bx, by, rng=None):
        logits = apply_fn(params, bx, train=True, rng=rng)
        return _softmax_xent(logits, by)

    def repr_fn(params, bx):
        # logits-as-representation: the paper's Moon uses a projection head;
        # on these small CNNs the pre-softmax layer is the standard proxy.
        return apply_fn(params, bx, train=False)

    def predict_fn(params, bx):
        return jnp.argmax(apply_fn(params, bx, train=False), axis=-1)

    return Task(name=model, kind="vision", init=init, loss_fn=loss_fn,
                repr_fn=repr_fn, predict_fn=predict_fn)


def charlm_task(vocab: int = 64, d_embed: int = 8, d_hidden: int = 256) -> Task:
    """CharLSTM-256 next-char prediction (Shakespeare stand-in)."""

    def init(key):
        return pm.charlstm_init(key, vocab=vocab, d_embed=d_embed, d_hidden=d_hidden)

    def loss_fn(params, bx, by, rng=None):
        logits = pm.charlstm_apply(params, bx)
        return _softmax_xent(logits, by)

    def repr_fn(params, bx):
        return pm.charlstm_apply(params, bx)[:, -1]  # last-position logits

    def predict_fn(params, bx):
        return jnp.argmax(pm.charlstm_apply(params, bx), axis=-1)

    return Task(name="charlstm", kind="charlm", init=init, loss_fn=loss_fn,
                repr_fn=repr_fn, predict_fn=predict_fn)


def lm_task(cfg: TransformerConfig) -> Task:
    """Federated next-token training over an assigned architecture.

    bx = tokens (B, S) int32, by = labels (B, S) int32 (-1 = ignore).
    """

    def init(key):
        return init_lm(key, cfg)

    def loss_fn(params, bx, by, rng=None):
        loss, _ = lm_loss(params, cfg, {"tokens": bx, "labels": by})
        return loss

    def repr_fn(params, bx):
        _, _, hidden = lm_forward(params, cfg, {"tokens": bx})
        return jnp.mean(hidden.astype(jnp.float32), axis=1)

    def predict_fn(params, bx):
        logits, _, _ = lm_forward(params, cfg, {"tokens": bx})
        return jnp.argmax(logits, axis=-1)

    return Task(name=cfg.name, kind="tokenlm", init=init, loss_fn=loss_fn,
                repr_fn=repr_fn, predict_fn=predict_fn)
