"""Pod-scale backends for the federated round engine.

The host engine (repro.fl.engine) and the pod driver used to be two
parallel codepaths; this module makes the pod a *backend* of the same
``RoundStrategy`` stack.  ``PodRelayStrategy`` / ``PodAggregateStrategy``
reuse the engine's round bodies (the same ``make_local_fn`` inner loop,
the same key derivation, on-device client sampling and chunked
``lax.scan`` dispatch) and add the mesh placement decisions:

  * params enter/leave every round pinned to ``rules.param_shardings``
    (FSDP × TP), and the compiled chunk program carries explicit
    in/out shardings so ``chunk_size`` rounds run as ONE SPMD dispatch;
  * the stacked client data ``(n_clients, n_per_client, ...)`` is
    device_put with the sample pool sharded over (pod, data) —
    ``rules.fl_batch_pspec(batch_axis=1)`` — so every local step's
    gathered batch is data-parallel across the whole mesh ("the mesh
    accelerates one client at a time", DESIGN.md §3);
  * per-client algorithm state lives in a ``ShardedClientStateStore``:
    the ``(n_clients, ...)`` stacks shard their leading client axis over
    the mesh ``data`` axis, rows for the selected K clients are gathered
    inside the program and scattered back — scaffold/moon at pod scale
    without replicating an (n_clients, model) tensor.

P2 aggregation differs from the host backend in schedule only.  The
default topology runs clients *sequentially* (``lax.scan``)
accumulating a weighted f32 delta — at LLM scale a per-client parameter
copy per vmap lane is exactly what does not fit, so peak memory is
~2×params independent of K, and the delta accumulation IS the FedAvg
all-reduce on the mesh.  ``aggregation="hierarchical"`` trades memory
back for critical path: clients group into ``n_pods`` pods (default:
the mesh ``data``-axis size), each pod accumulates a shard-local
partial delta over its own clients (one vmap lane per pod), and a
single cross-pod combine — one per-bucket sum over the lane partials —
produces the global delta, cutting the aggregation critical path from
O(K) to O(K/n_pods) local runs (see PodAggregateStrategy).  Either way
the math is identical to the host vmap+weighted-mean path up to
summation order, which is what the host↔pod parity tests pin down.

Per-client algorithm state scales past dense populations the same way
the host engine does: ``PodFLConfig(store="sparse")`` swaps the dense
``ShardedClientStateStore`` for ``ShardedSparseClientStateStore`` — the
participation-indexed ``(capacity, ...)`` active-set table of
repro.fl.engine with its row axis sharded over the mesh ``data`` axis,
LRU residency managed on the host between chunk dispatches.

The delta accumulation (and the whole client step tail) has two
implementations behind ``PodFLSpec.update_impl``: the per-leaf
``tree_map`` algebra ("tree", the parity oracle) and the FLAT-FIRST
fused path ("fused"/"fused_interpret").  Fused no longer trades away
the mesh layout: params ride the chunk as
:class:`repro.utils.flatten.ShardedFlatView` buffers — leaves bucketed
per (dtype × mesh-axis group) straight from the ``param_shardings``
rules, each bucket a ``(n_shards, per_shard)`` buffer sharded over
exactly its group's axes — so every device holds one contiguous local
buffer per bucket and the fused kernels
(repro.kernels.fused_update) run SHARD-LOCALLY under ``shard_map``
(:class:`ShardedFlatOps`).  The FSDP×TP decomposition is preserved
bit-for-bit (same tiles, packed), the donated chunk carries are the
sharded buffers themselves, and the local step differentiates w.r.t.
them (trees materialize only at the model's forward/backward
boundary), so fused updates run under real multi-device layouts — the
pod CLI defaults to ``--update-impl fused``.

Server-side optimizers (``server_opt="momentum"|"adam"`` — FedAvgM /
FedAdam) run at pod scale too: the optimizer moments mirror the param
tree, so ``rules.param_shardings`` applied to the ``OptState`` pytree
shards every moment exactly like the parameter it tracks (the scalar
step count replicates), and the state rides the donated chunk carry —
one sharded optimizer state per run, zero host round-trips.  The
in-program eval stream's test batches shard their per-batch sample axis
over (pod, data), same policy as the training pool.

``PodCyclicConfig`` / ``PodFLConfig`` are the declarative phase entries:
they register with ``core.pipeline`` so ``run_phase_schedule`` drives
multi-cycle P1↔P2 alternation and switch policies identically on both
backends.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

# canonical seed host-RNG stream offsets (P1 drew from seed+31, P2 from
# seed+17) — imported, not re-declared, so host↔pod sampling="host"
# parity cannot silently diverge
from repro.core.cyclic import HOST_RNG_OFFSET_P1
from repro.data.federated import FederatedDataset
from repro.fl.engine import (
    DENSE_STORE,
    AggregateStrategy,
    RelayStrategy,
    RoundSchedule,
    SparseClientStateStore,
    run_rounds,
    stack_copies,
    tree_rows,
    tree_set_rows,
)
from repro.fl import compression, privacy
from repro.fl.local import (
    FlatParamOps, LocalSpec, effective_trainable_filter, make_local_fn)
from repro.fl.simulation import HOST_RNG_OFFSET_P2
from repro.fl.task import Task
from repro.kernels import ops
from repro.sharding import rules
from repro.utils import tree_math as tm

Pytree = Any

POD_ALGORITHMS = ("fedavg", "fedprox", "scaffold", "moon")

# variant names for make_local_fn, keyed by aggregation algorithm
_VARIANTS = {"fedavg": "plain", "fedprox": "fedprox",
             "scaffold": "scaffold", "moon": "moon"}


@dataclasses.dataclass(frozen=True)
class PodFLSpec:
    """Static description of one pod-scale federated round."""
    local_steps: int = 8            # t_i — SGD steps per client
    batch_size: int = 8             # B — per-step local batch size
    lr: float = 0.01
    momentum: float = 0.0
    weight_decay: float = 0.0
    algorithm: str = "fedavg"       # fedavg | fedprox | scaffold | moon
    mu: float = 0.01                # fedprox proximal / moon coefficient
    temperature: float = 0.5        # moon
    grad_clip: Optional[float] = None
    # server-side optimizer (FedAvgM / FedAdam, Reddi et al.): applied to
    # the aggregated pseudo-gradient, moments sharded like params
    server_opt: str = "none"        # none | momentum | adam
    server_lr: float = 1.0
    server_momentum: float = 0.9
    # step-tail implementation: "tree" leaf-wise algebra (the parity
    # oracle) or the fused flat-first path.  On the pod the fused
    # buffers are ShardedFlatView buckets that preserve the FSDP×TP
    # layout (kernels run shard-locally under shard_map), so "fused" is
    # safe — and the CLI default — on real multi-device meshes.
    update_impl: str = "tree"       # tree | fused | fused_interpret
    # round-aggregate privacy (repro.fl.privacy): per-client delta
    # clipping + Gaussian noise (DP-FedAvg) and/or pairwise secure-agg
    # masks.  Both apply at AGGREGATION — None/False is the exact
    # baseline program.
    dp: Optional[privacy.DPSpec] = None
    secure_agg: bool = False
    # compressed P2 uploads (repro.fl.compression): block-quantized +
    # top-k sparsified client deltas, optional error feedback.  The
    # identity spec / None compile to the exact baseline program.
    compression: Optional[compression.CompressionSpec] = None
    # trainable-slice / PEFT (see repro.fl.local.LocalSpec): frozen
    # leaves stay out of the kernels, the donated carry and the wire;
    # needs the fused flat path.  P1 (relay) strips both knobs — the
    # relay hops the full model.
    peft: Optional[str] = None
    trainable_filter: Optional[str] = None

    def __post_init__(self):
        from repro.fl import compression as comp_mod
        from repro.fl.local import validate_peft, validate_update_impl
        validate_update_impl(self.update_impl)
        comp_mod.validate_compression(
            self.compression, dp=self.dp, secure_agg=self.secure_agg)
        if comp_mod.compression_on(self.compression) and \
                self.update_impl == "tree":
            raise ValueError(
                "pod lossy compression needs the fused flat path "
                "(update_impl='fused'|'fused_interpret') — the tree "
                "backend has no shard-local compress kernel")
        validate_peft(self.peft, trainable_filter=self.trainable_filter,
                      update_impl=self.update_impl)

    def local_spec(self, variant: Optional[str] = None) -> LocalSpec:
        return LocalSpec(
            n_steps=self.local_steps, batch_size=self.batch_size, lr=self.lr,
            momentum=self.momentum, weight_decay=self.weight_decay,
            variant=variant or _VARIANTS[self.algorithm], mu=self.mu,
            temperature=self.temperature, grad_clip=self.grad_clip,
            update_impl=self.update_impl, dp=self.dp,
            secure_agg=self.secure_agg, compression=self.compression,
            peft=self.peft, trainable_filter=self.trainable_filter)


# ---------------------------------------------------------------------------
# client-state store sharded over the mesh data axis
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShardedClientStateStore:
    """Per-client state stacks with the leading client axis sharded over
    the mesh ``data`` axis (see the ClientStateStore contract in
    repro.fl.engine).  Gather pulls the K selected rows into the round
    program; scatter writes them back and re-pins the stack's layout so
    the carry stays sharded across chunks."""
    mesh: Any

    def _shardings(self, tree: Pytree) -> Pytree:
        return rules.client_axis_shardings(tree, self.mesh)

    def init(self, template: Pytree, n_clients: int) -> Pytree:
        stacked = stack_copies(template, n_clients)
        return jax.device_put(stacked, self._shardings(stacked))

    def gather(self, state: Pytree, ids: jnp.ndarray) -> Pytree:
        return tree_rows(state, ids)

    def scatter(self, state: Pytree, ids: jnp.ndarray, rows: Pytree) -> Pytree:
        out = tree_set_rows(state, ids, rows)
        return jax.lax.with_sharding_constraint(out, self._shardings(out))

    needs_host_ids = False

    def population(self, state: Pytree) -> int:
        return jax.tree_util.tree_leaves(state)[0].shape[0]

    def prepare_chunk(self, state: Pytree, ids_block) -> Pytree:
        return state

    def shardings(self, template: Pytree, n_clients: int, mesh=None) -> Pytree:
        mesh = mesh or self.mesh
        return jax.tree_util.tree_map(
            lambda leaf: jax.sharding.NamedSharding(
                mesh, rules.client_axis_pspec(mesh, len(leaf.shape) + 1,
                                              n_clients)),
            template)


@dataclasses.dataclass(frozen=True, eq=False)
class ShardedSparseClientStateStore(SparseClientStateStore):
    """The participation-indexed store on a mesh: the active-set table
    shards its ``capacity`` row axis over the mesh ``data`` axis (same
    policy as the dense sharded store, applied to slots instead of
    clients); the id→slot index and the LRU bookkeeping replicate —
    they are O(n_clients)·int32 and O(capacity), negligible next to one
    model row.  Residency (stage/commit, see the base class) still runs
    eagerly on the host between dispatches; the committed state re-pins
    itself so the donated chunk carry keeps the mesh layout, and staged
    refill rows land DIRECTLY on their owning data shard whenever the
    eviction plan splits evenly across shards (the in-program scatter
    pins the layout either way — placement is a transfer-cost
    optimization, not a correctness requirement)."""
    mesh: Any = None

    def _state_shardings(self, state: Pytree) -> Pytree:
        rep = rules.replicated(self.mesh)
        return {"table": rules.client_axis_shardings(state["table"], self.mesh),
                "slot_of": rep, "owner": rep, "stamp": rep}

    def init(self, template: Pytree, n_clients: int) -> Pytree:
        state = super().init(template, n_clients)
        return jax.device_put(state, self._state_shardings(state))

    def scatter(self, state: Pytree, ids: jnp.ndarray, rows: Pytree) -> Pytree:
        out = super().scatter(state, ids, rows)
        return jax.lax.with_sharding_constraint(
            out, self._state_shardings(out))

    def _refill_placement(self, victims):
        """Placement for the staged ``(n_miss, ...)`` refill rows: the
        table's row axis shards over ``data`` in equal contiguous
        blocks, and the staged victims are sorted, so when the per-shard
        eviction counts are equal the row-sharded transfer puts every
        row straight onto the shard that owns its destination slot.
        Uneven plans fall back to replicated staging."""
        if self.mesh is None:
            return None
        d = rules.mesh_axis_size(self.mesh, rules.DATA)
        cap = self._meta["owner"].shape[0]
        if d <= 1 or cap % d or victims.size % d:
            return rules.replicated(self.mesh)
        per_shard = cap // d
        counts = np.bincount(victims // per_shard, minlength=d)
        if not np.all(counts == victims.size // d):
            return rules.replicated(self.mesh)
        return jax.sharding.NamedSharding(
            self.mesh, rules.client_axis_pspec(self.mesh, 1, victims.size))

    def commit_chunk(self, state: Pytree, staged) -> Pytree:
        new = super().commit_chunk(state, staged)
        return jax.device_put(new, self._state_shardings(new))

    def shardings(self, template: Pytree, n_clients: int, mesh=None) -> Pytree:
        mesh = mesh or self.mesh
        cap = max(1, min(self.capacity, n_clients))
        rep = rules.replicated(mesh)
        table = jax.tree_util.tree_map(
            lambda leaf: jax.sharding.NamedSharding(
                mesh, rules.client_axis_pspec(mesh, len(leaf.shape) + 1, cap)),
            template)
        return {"table": table, "slot_of": rep, "owner": rep, "stamp": rep}


# ---------------------------------------------------------------------------
# sharded flat ops — the pod's flat-first representation
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShardedFlatOps(FlatParamOps):
    """FlatParamOps over ShardedFlatView buffers on a mesh.

    Each bucket's ``(n_shards, per_shard)`` buffer is sharded over its
    group's mesh axes, so a kernel over it is embarrassingly
    shard-local: :meth:`_run` wraps every fused-kernel call in a
    ``shard_map`` whose in/out specs are the bucket's
    ``flat_buffer_pspec`` — each device runs the blocked Pallas pass on
    its own contiguous ``(1, per_shard)`` tile with zero collectives
    (the only cross-shard communication in the whole update path is the
    global clip norm, a scalar psum XLA inserts for
    :meth:`FlatParamOps.grad_sqsum`).
    """
    mesh: Any = None

    def place(self, bufs: Dict[str, jnp.ndarray]) -> Dict[str, jnp.ndarray]:
        # pad each bucket's per-shard axis to the kernel grid (so the
        # shard-local kernel calls skip their pad copy), then device_put.
        # device_put is a NO-OP (returns its operand) on matching
        # placement, and the shard transform itself passes (1, N)-shaped
        # unsharded leaves straight through — copy any passthrough so
        # the engine's donated carries never delete a caller's array
        # (same hazard as PodBackendMixin._put_unaliased)
        bufs = self.pad(bufs)
        placed = jax.device_put(bufs, self.shardings())
        return jax.tree_util.tree_map(
            lambda orig, out: jnp.copy(out) if out is orig else out,
            bufs, placed)

    def shardings(self) -> Dict[str, Any]:
        return rules.flat_param_shardings(self.view, self.mesh)

    def stacked_flatten(self, tree: Pytree):
        raise NotImplementedError("the pod backend aggregates "
                                  "sequentially — no stacked buffers")

    def stacked_unflatten(self, bufs: Dict[str, jnp.ndarray], frozen=None):
        raise NotImplementedError("the pod backend aggregates "
                                  "sequentially — no stacked buffers")

    def place_frozen(self, bufs: Dict[str, jnp.ndarray]) -> Dict[str, jnp.ndarray]:
        # frozen buckets never enter a kernel, so no pad — just pin the
        # read-only constants to their mesh layout (replicated or FSDP
        # per the group's axes) with the same unaliased-copy guard as
        # place(): these live OUTSIDE the donated carry but must not
        # alias a caller's array either.
        placed = jax.device_put(bufs, self.frozen_shardings())
        return jax.tree_util.tree_map(
            lambda orig, out: jnp.copy(out) if out is orig else out,
            bufs, placed)

    def frozen_shardings(self) -> Dict[str, Any]:
        return rules.frozen_flat_shardings(self.view, self.mesh)

    def weighted_delta(self, p_bufs, stacked_bufs, wbar, extra=None):
        raise NotImplementedError("the pod backend aggregates "
                                  "sequentially — use delta_accum")

    def _run(self, name: str, fn: Callable, bufs, scalars):
        group = self.view.group_map[name]
        bspec = rules.flat_buffer_pspec(group)
        scalars = tuple(jnp.asarray(s, jnp.float32) if not hasattr(s, "dtype")
                        else s for s in scalars)
        # per-shard length from the buffer itself, not group.size — the
        # carried buffers are pre-padded to the kernel grid
        local = [jax.ShapeDtypeStruct((b.shape[-1],), b.dtype) for b in bufs]
        sc_specs = [jax.ShapeDtypeStruct(jnp.shape(s), s.dtype)
                    for s in scalars]
        n_out = len(jax.eval_shape(fn, *local, *sc_specs))

        def body(*args):
            bs, sc = args[:len(bufs)], args[len(bufs):]
            outs = fn(*[b.reshape(-1) for b in bs], *sc)
            return tuple(o.reshape(1, -1) for o in outs)

        run = shard_map(body, mesh=self.mesh,
                        in_specs=tuple([bspec] * len(bufs) +
                                       [P()] * len(scalars)),
                        out_specs=(bspec,) * n_out, check_rep=False)
        return run(*bufs, *scalars)

    def _logical_size(self, name: str) -> int:
        # one kernel invocation runs under shard_map on ONE shard's
        # contiguous tile, so the top-k population is the PER-SHARD
        # logical element count — compression keeps k elements per shard
        # (shard-local top-k, zero collectives), not k globally
        return self.view.group_map[name].size

    # -- hierarchical lanes: shard-local partials + one psum combine --------
    #
    # The lane layout stacks the G pod accumulators into (G, n_shards,
    # per_shard) buffers with the LANE axis sharded over the mesh `data`
    # axis (rules.lane_axis_pspec): each data shard owns one pod's whole
    # f32 partial, kept p-free (accum-only fused_delta_accum, so the
    # `−(Σc)·p` term applies once AFTER the combine instead of per lane —
    # that rewrite is what makes the partials independent of the
    # FSDP-sharded params).  The cross-pod combine is then literally one
    # jax.lax.psum over `data` per bucket — asserted on the lowered HLO
    # in tests/test_pod_engine.py.

    def lane_count(self) -> int:
        """Pod lanes the mesh can host shard-locally (= |data| axis)."""
        return rules.mesh_axis_size(self.mesh, rules.DATA)

    def lane_zeros(self, G: int) -> Dict[str, jnp.ndarray]:
        """Lane-stacked f32 zero accumulators, pinned to the lane
        layout (lane axis over ``data``)."""
        if G != self.lane_count():
            raise ValueError(
                f"lane layout needs n_pods == |data| axis "
                f"({G} != {self.lane_count()})")
        zeros = self.zeros(jnp.float32)
        lane_sh = rules.lane_shardings(self.view, self.mesh)
        return {name: jax.lax.with_sharding_constraint(
                    jnp.zeros((G,) + b.shape, b.dtype), lane_sh[name])
                for name, b in zeros.items()}

    def lane_accum(self, acc_bufs, w_bufs, coeffs) -> Dict[str, jnp.ndarray]:
        """``acc[g] += coeffs[g] · w[g]`` per lane, shard-local: each
        data shard runs the blocked accum-only kernel on its own lane's
        contiguous tile — zero collectives."""
        interpret = self.interpret
        coeffs = jnp.asarray(coeffs, jnp.float32)
        lane_spec = rules.lane_axis_pspec()

        def body(a_loc, w_loc, c_loc):
            out = ops.fused_delta_accum(a_loc.reshape(-1), w_loc.reshape(-1),
                                        None, c_loc[0], interpret=interpret)
            return out.reshape(a_loc.shape)

        run = shard_map(body, mesh=self.mesh,
                        in_specs=(lane_spec, lane_spec, P(rules.DATA)),
                        out_specs=lane_spec, check_rep=False)
        return {name: run(acc, w_bufs[name], coeffs)
                for name, acc in acc_bufs.items()}

    def lane_combine(self, acc_bufs) -> Dict[str, jnp.ndarray]:
        """The single cross-pod combine: one ``psum`` over the mesh
        ``data`` axis per bucket (any same-shard lanes fold locally
        first), returning the replicated ``(n_shards, per_shard)``
        total."""
        lane_spec = rules.lane_axis_pspec()

        def body(a_loc):
            return jax.lax.psum(jnp.sum(a_loc, axis=0), rules.DATA)

        run = shard_map(body, mesh=self.mesh, in_specs=(lane_spec,),
                        out_specs=P(None, None), check_rep=False)
        return {name: run(acc) for name, acc in acc_bufs.items()}


@functools.lru_cache(maxsize=32)
def _sharded_flat_ops(task: Task, mesh, layout: str, interpret: bool,
                      filter_spec: Optional[str] = None) -> ShardedFlatOps:
    p_specs = jax.eval_shape(task.init, jax.random.PRNGKey(0))
    view = rules.sharded_flat_view(p_specs, mesh, layout,
                                   filter_spec=filter_spec)
    return ShardedFlatOps(view=view, interpret=interpret, mesh=mesh)


# ---------------------------------------------------------------------------
# the pod backend (engine hooks shared by both strategies)
# ---------------------------------------------------------------------------

class PodBackendMixin:
    """Engine backend hooks for a sharded mesh.  Subclasses are frozen
    strategy dataclasses providing ``mesh``, ``layout`` and
    ``clients_per_round`` fields."""

    def flat_ops(self, task: Task):
        if self.spec.update_impl == "tree":
            return None
        return _sharded_flat_ops(task, self.mesh, self.layout,
                                 ops.fused_interpret(self.spec.update_impl),
                                 effective_trainable_filter(self.spec))

    def n_selected(self, n_clients: int) -> int:
        if self.clients_per_round:
            return max(1, min(self.clients_per_round, n_clients))
        return super().n_selected(n_clients)

    def _param_shardings(self, task: Task) -> Pytree:
        p_specs = jax.eval_shape(task.init, jax.random.PRNGKey(0))
        return rules.param_shardings(p_specs, self.mesh, self.layout)

    def _axis1_sharding(self, arr):
        # batch-like axis 1 over (pod, data); replicate when it does not
        # divide — same degradation policy as the rules
        mesh = self.mesh
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        n_shards = 1
        for a in ("pod", "data"):
            n_shards *= sizes.get(a, 1)
        if arr.ndim >= 2 and n_shards > 1 and \
                arr.shape[1] % n_shards == 0 and arr.shape[1] >= n_shards:
            return jax.sharding.NamedSharding(
                mesh, rules.fl_batch_pspec(mesh, arr.ndim, batch_axis=1))
        return rules.replicated(mesh)

    def prepare_data(self, data: FederatedDataset):
        # sample pool (n_clients, n_per_client, ...): pool axis over the
        # mesh batch axes
        return data.device_arrays((self._axis1_sharding(data.x),
                                   self._axis1_sharding(data.y),
                                   rules.replicated(self.mesh)))

    def prepare_eval_data(self, batched):
        # eval stream (n_batches, B, ...): per-batch sample axis over the
        # mesh batch axes, exactly like the training pool
        return tuple(jax.device_put(a, self._axis1_sharding(a))
                     for a in batched)

    def _put_unaliased(self, tree: Pytree, shardings) -> Pytree:
        # device_put is a NO-OP (returns the caller's array) when the
        # placement already matches — e.g. phase 2 of a pod schedule
        # receiving phase 1's already-sharded result — and the engine
        # donates its carries, which would delete the caller's buffer.
        # Copy any aliased leaf so donation never eats external state.
        placed = jax.device_put(tree, shardings)
        return jax.tree_util.tree_map(
            lambda orig, out: jnp.copy(out) if out is orig else out,
            tree, placed)

    def place_params(self, params: Pytree) -> Pytree:
        return self._put_unaliased(
            params, rules.param_shardings(params, self.mesh, self.layout))

    def place_server_state(self, state: Pytree, task: Task) -> Pytree:
        if not jax.tree_util.tree_leaves(state):
            return state
        return self._put_unaliased(state, self.server_state_shardings(task))

    def state_shardings(self, task: Task, p_specs: Pytree,
                        n_clients: int) -> Dict:
        return {}

    def server_state_shardings(self, task: Task) -> Any:
        """Placement for the server-optimizer ``OptState``.

        Tree path: the moment trees mirror the param tree
        leaf-for-leaf, so the param path-pattern rules apply verbatim
        (the OptState/AdamWState wrappers only prefix the paths).
        Fused path: the moments are flat buffer dicts keyed by bucket
        name, so each moment buffer takes its bucket's
        ``flat_buffer_pspec``.  The scalar step count replicates either
        way."""
        server = self.make_server_update(task)
        if server is None:
            return ()
        p_specs = jax.eval_shape(task.init, jax.random.PRNGKey(0))
        fops = self.flat_ops(task)
        if fops is None:
            state = jax.eval_shape(server[0], p_specs)
            return rules.param_shardings(state, self.mesh, self.layout)
        buf_specs = jax.eval_shape(fops.flatten, p_specs)
        state = jax.eval_shape(server[0], buf_specs)
        buf_sh = fops.shardings()
        rep = rules.replicated(self.mesh)

        def leaf_sh(path, leaf):
            key = next((p.key for p in reversed(path)
                        if hasattr(p, "key")), None)
            return buf_sh.get(key, rep)

        return jax.tree_util.tree_map_with_path(leaf_sh, state)

    def jit_chunk(self, chunk: Callable, task: Task,
                  n_clients: int) -> Callable:
        p_specs = jax.eval_shape(task.init, jax.random.PRNGKey(0))
        fops = self.flat_ops(task)
        # flat-first: the params carry is the sharded buffer dict, so
        # its in/out shardings are the per-bucket flat shardings
        p_sh = fops.shardings() if fops is not None else \
            rules.param_shardings(p_specs, self.mesh, self.layout)
        rep = rules.replicated(self.mesh)
        st_sh = self.state_shardings(task, p_specs, n_clients)
        srv_sh = self.server_state_shardings(task)
        # chunk args: (key, params, algo_state, server_state, x_all,
        #              y_all, n_real, ids, lr_scales, eval_mask, ev_x,
        #              ev_y, ev_w); x/y and the eval stream keep the
        #              committed placement from prepare_data /
        #              prepare_eval_data (None = inherit), ids is None
        #              under on-device sampling, eval args are None in
        #              no-eval programs (a sharding entry broadcasts
        #              over the empty pytree); the trailing frozen
        #              bucket dict gets its replicated-or-FSDP layout
        #              ({} when nothing is frozen — any entry broadcasts)
        fz_sh = fops.frozen_shardings() if fops is not None else rep
        in_sh = (rep, p_sh, st_sh, srv_sh, None, None, rep, None, rep,
                 rep, None, None, None, fz_sh)
        out_sh = (rep, p_sh, st_sh, srv_sh, rep, rep)
        return jax.jit(chunk, in_shardings=in_sh, out_shardings=out_sh,
                       donate_argnums=(0, 1, 2, 3))


@dataclasses.dataclass(frozen=True)
class PodRelayStrategy(PodBackendMixin, RelayStrategy):
    """P1 relay on the mesh: the host relay body (sequential client scan,
    no aggregation) with params pinned to the FSDP×TP layout on round
    entry/exit."""
    mesh: Any = None
    layout: str = "fsdp_tp"
    clients_per_round: Optional[int] = None

    def __post_init__(self):
        super().__post_init__()         # relay rejects dp/secure_agg
        if self.mesh is None:
            raise ValueError("PodRelayStrategy requires a mesh")

    def build_round(self, task: Task) -> Callable:
        inner = RelayStrategy.build_round(self, task)
        fops = self.flat_ops(task)
        # fused: the carry is the sharded buffer dict — pin the buckets
        p_sh = fops.shardings() if fops is not None else \
            self._param_shardings(task)

        def body(key, params, x_all, y_all, ids, weights, lr_scale,
                 algo_state, frozen=None):
            params = jax.lax.with_sharding_constraint(params, p_sh)
            new_params, algo_state, loss = inner(
                key, params, x_all, y_all, ids, weights, lr_scale,
                algo_state, frozen)
            new_params = jax.lax.with_sharding_constraint(new_params, p_sh)
            return new_params, algo_state, loss

        return body


POD_AGGREGATIONS = ("sequential", "hierarchical")


@dataclasses.dataclass(frozen=True)
class PodAggregateStrategy(PodBackendMixin, AggregateStrategy):
    """P2 on the mesh: client scan + weighted f32 delta accumulation,
    algorithm state behind a data-axis-sharded ClientStateStore,
    server-side optimizers (``server_opt="momentum"|"adam"``) with
    param-sharded moments.  Numerically matches the host vmap backend
    round-for-round.

    Two aggregation topologies:

      sequential   : one ``lax.scan`` over all K clients accumulating
                     the delta — peak memory ~2×params independent of
                     K, aggregation critical path O(K).
      hierarchical : TWO-LEVEL — clients are grouped into ``n_pods``
                     (default: the mesh ``data``-axis size) pods; an
                     outer scan of K/G steps runs G clients at a time
                     (one vmap lane per pod), each lane accumulating
                     its own shard-local partial ``fused_delta_accum``,
                     and ONE cross-pod combine (a per-bucket sum over
                     the G lane partials, which lowers to a psum when
                     the lane axis is device-sharded) produces the
                     global weighted delta.  Critical path O(K/G) local
                     runs + one combine, at the cost of G× the f32
                     delta buffers and G× the lane activations — the
                     lanes are deliberately left unsharded so they
                     never conflict with the bucket axes.  Summation
                     order differs from sequential (per-pod partials,
                     then one sum), so results match up to float
                     reassociation.
    """
    mesh: Any = None
    layout: str = "fsdp_tp"
    clients_per_round: Optional[int] = None
    aggregation: str = "sequential"     # sequential | hierarchical
    n_pods: Optional[int] = None        # None: mesh data-axis size

    def __post_init__(self):
        if self.mesh is None:
            raise ValueError("PodAggregateStrategy requires a mesh")
        if self.algorithm not in POD_ALGORITHMS:
            raise ValueError(f"unknown pod algorithm {self.algorithm!r}")
        if self.aggregation not in POD_AGGREGATIONS:
            raise ValueError(f"unknown aggregation {self.aggregation!r} "
                             f"(choose from {POD_AGGREGATIONS})")
        if compression.compression_on(self.spec.compression) and \
                self.spec.update_impl == "tree":
            raise ValueError(
                "pod lossy compression needs the fused flat path "
                "(update_impl='fused'|'fused_interpret') — the tree "
                "backend has no shard-local compress kernel")
        if self.state_store is DENSE_STORE:
            object.__setattr__(self, "state_store",
                               ShardedClientStateStore(self.mesh))

    def _n_pods(self) -> int:
        if self.n_pods:
            return int(self.n_pods)
        sizes = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
        return max(1, sizes.get(rules.DATA, 1))

    def state_shardings(self, task: Task, p_specs: Pytree,
                        n_clients: int) -> Dict:
        store = self.state_store
        if not hasattr(store, "shardings"):
            return {}
        fops = self.flat_ops(task)
        # the store rows mirror the engine's carried representation:
        # flat bucket dicts on the fused path, param trees otherwise
        template = jax.eval_shape(fops.zeros) if fops is not None else p_specs
        stacked = store.shardings(template, n_clients, self.mesh)
        out: Dict = {}
        if stacked is not None:
            if self.algorithm == "scaffold":
                c_sh = fops.shardings() if fops is not None else \
                    rules.param_shardings(p_specs, self.mesh, self.layout)
                out = {"c_global": c_sh, "c_clients": stacked}
            elif self.algorithm == "moon":
                out = {"w_prev": stacked}
        comp = self.spec.compression
        if compression.compression_on(comp) and comp.error_feedback:
            # error-feedback residual rows: f32 buffers in the carried
            # flat layout, client axis sharded like every other stack
            # (lossy compression on the pod implies the fused path)
            ef_tmpl = jax.eval_shape(functools.partial(fops.zeros,
                                                       jnp.float32))
            ef_sh = self._ef_store.shardings(ef_tmpl, n_clients, self.mesh)
            if ef_sh is not None:
                out = dict(out, ef_residuals=ef_sh)
        return out

    def build_round(self, task: Task) -> Callable:
        spec = self.spec
        fops = self.flat_ops(task)
        local = make_local_fn(task, spec, fops)
        algo = self.algorithm
        store = self.state_store
        fused = fops is not None
        p_sh = fops.shardings() if fused else self._param_shardings(task)
        unpack = fops.unflatten if fused else (lambda t, fz=None: t)
        G = self._n_pods() if self.aggregation == "hierarchical" else 1
        dp = spec.dp
        dp_clips = dp is not None and dp.clips
        comp = spec.compression
        compressed = compression.compression_on(comp)   # implies fused
        ef = compressed and comp.error_feedback
        ef_store = self._ef_store if ef else None

        def pin(t):
            return jax.lax.with_sharding_constraint(t, p_sh)

        def body(key, params, x_all, y_all, ids, weights, lr_scale,
                 algo_state, frozen=None):
            params = pin(params)
            K = ids.shape[0]
            keys = jax.random.split(key, K)
            cx = x_all[ids]
            cy = y_all[ids]
            w32 = weights.astype(jnp.float32)
            wsum = jnp.sum(w32)
            ef_rows = (ef_store.gather(algo_state["ef_residuals"], ids)
                       if ef else ())

            if fused:
                # flat-first: params and the f32 delta accumulator are
                # sharded buffer dicts; each client's contribution and
                # the final apply run shard-locally, one blocked kernel
                # per bucket (ShardedFlatOps)
                def zeros_delta():
                    return fops.zeros(jnp.float32)

                def add_delta(delta, w_end, w_i):
                    return fops.delta_accum(delta, w_end, params,
                                            w_i / wsum)

                def apply_delta(params_, delta):
                    return fops.apply_delta(params_, delta)

                # compressed uploads ARE deltas: each client compresses
                # its own f32 (w_end − p [+ residual]) shard-locally —
                # one lax.top_k + one blocked kernel pass per bucket
                # under shard_map — and the accumulator sums coeff·c
                # with the accum-only kernel (no −(Σc)·p term to apply;
                # the upload already subtracted p)
                def compress_client(w_end, r_row):
                    d = {name: w_end[name].astype(jnp.float32) -
                               params[name].astype(jnp.float32)
                         for name in w_end}
                    if ef:
                        d = {name: d[name] + r_row[name] for name in d}
                    return fops.compress_delta(d, comp)
            else:
                def zeros_delta():
                    return jax.tree_util.tree_map(
                        lambda p: jnp.zeros(p.shape, jnp.float32), params)

                def add_delta(delta, w_end, w_i):
                    # the running weighted delta sum IS the FedAvg all-reduce
                    return jax.tree_util.tree_map(
                        lambda d, we, p: d + (w_i / wsum) * (
                            we.astype(jnp.float32) - p.astype(jnp.float32)),
                        delta, w_end, params)

                def apply_delta(params_, delta):
                    return jax.tree_util.tree_map(
                        lambda p, d: (p.astype(jnp.float32) + d).astype(p.dtype),
                        params_, delta)

            if dp_clips:
                # DP clipping folds into the accumulation COEFFICIENT:
                # coeff_i = (w_i/wsum)·min(1, C/‖w_end − p‖) — the
                # p-present accumulators self-normalize, so clipping
                # costs a squared-norm reduction, not an extra pass
                sqnorm = privacy.flat_delta_sqnorm if fused else \
                    privacy.tree_delta_sqnorm
                base_add = add_delta

                def add_delta(delta, w_end, w_i):
                    scale = privacy.clip_scale(dp, sqnorm(w_end, params))
                    return base_add(delta, w_end, w_i * scale)

            # -- per-algorithm client step -------------------------------
            # client(k, cxi, cyi, row) -> (w_end, out, loss): ``row`` is
            # this client's state-store row (() when stateless), ``out``
            # the row to scatter back (() when none).  The aggregation
            # topologies below are generic over it.
            if algo in ("fedavg", "fedprox"):
                anchor = unpack(params, frozen) if algo == "fedprox" else None
                rows = ()

                def client(k, cxi, cyi, row):
                    extras = {"w_global": anchor} if algo == "fedprox" else {}
                    w_end, aux = local(k, params, extras, cxi, cyi, lr_scale,
                                       frozen)
                    return w_end, (), aux["loss"]

            elif algo == "scaffold":
                c, c_all = algo_state["c_global"], algo_state["c_clients"]
                rows = store.gather(c_all, ids)
                denom = spec.n_steps * spec.lr * lr_scale
                if fused:
                    # FLAT per-client state: the correction and the
                    # option-II control-variate update run directly on
                    # the row buffers — no per-client unflatten at all
                    def client(k, cxi, cyi, c_i_row):
                        c_diff = jax.tree_util.tree_map(
                            lambda g, l: g - l, c, c_i_row)
                        w_end, aux = local(k, params, {"c_diff_flat": c_diff},
                                           cxi, cyi, lr_scale, frozen)
                        c_i_new = jax.tree_util.tree_map(
                            lambda ci, cg, p, we: ci - cg + (p - we) / denom,
                            c_i_row, c, params, w_end)
                        return w_end, c_i_new, aux["loss"]
                else:
                    def client(k, cxi, cyi, c_i_row):
                        extras = {"c_diff": tm.sub(c, c_i_row)}
                        w_end, aux = local(k, params, extras, cxi, cyi,
                                           lr_scale, frozen)
                        # option II: c_i⁺ = c_i − c + (w − w_i)/(S·lr)
                        c_i_new = jax.tree_util.tree_map(
                            lambda ci, cg, p, we: ci - cg + (p - we) / denom,
                            c_i_row, c, params, w_end)
                        return w_end, c_i_new, aux["loss"]

            elif algo == "moon":
                w_prev_all = algo_state["w_prev"]
                rows = store.gather(w_prev_all, ids)
                anchor = unpack(params, frozen)  # loop-invariant: hoist
                if fused:
                    # rows are flat buffers; the tree materializes once
                    # per client at the loss boundary, and the local
                    # output scatters back as raw buffers
                    def client(k, cxi, cyi, w_prev_row):
                        extras = {"w_global": anchor,
                                  "w_prev": fops.unflatten(w_prev_row,
                                                           frozen)}
                        w_end, aux = local(k, params, extras, cxi, cyi,
                                           lr_scale, frozen)
                        return w_end, w_end, aux["loss"]
                else:
                    def client(k, cxi, cyi, w_prev_row):
                        extras = {"w_global": anchor, "w_prev": w_prev_row}
                        w_end, aux = local(k, params, extras, cxi, cyi,
                                           lr_scale, frozen)
                        return w_end, w_end, aux["loss"]

            else:
                raise ValueError(f"unknown algorithm {algo!r}")

            # -- aggregation topology ------------------------------------
            if G > 1:
                if K % G:
                    raise ValueError(
                        f"hierarchical aggregation needs clients_per_round "
                        f"divisible by n_pods (K={K}, n_pods={G})")
                S = K // G

                def resh(t):
                    return jax.tree_util.tree_map(
                        lambda a: a.reshape((S, G) + a.shape[1:]), t)

                vclient = jax.vmap(client, in_axes=(0, 0, 0, 0))
                # the lane axis shards over the mesh `data` axis when the
                # pod count matches it AND the carries are flat — each
                # data shard then owns one pod's p-free partial
                # (accum-only kernel) and the cross-pod combine lowers to
                # ONE psum over `data` per bucket; otherwise (1-device
                # test meshes, tree impl, mismatched n_pods) lanes stay
                # unsharded and the combine is a local tree-sum
                lane_psum = fused and G == fops.lane_count()
                if compressed:
                    # per-lane compressed uploads: every lane compresses
                    # its own client's delta before accumulating, so the
                    # lane partials are sums of coeff·c (accum-only, no
                    # −p rewrite needed — uploads already subtracted p)
                    # and the cross-pod combine is untouched
                    vcompress = jax.vmap(compress_client)
                    if lane_psum:
                        def one_step(delta_g, inp):
                            k_g, cx_g, cy_g, w_g, row_g, r_g = inp
                            w_end_g, out_g, loss_g = vclient(k_g, cx_g,
                                                             cy_g, row_g)
                            c_g, r_new_g = vcompress(w_end_g, r_g)
                            return (fops.lane_accum(delta_g, c_g,
                                                    w_g / wsum),
                                    (out_g, loss_g, r_new_g))

                        delta_g, (outs, losses, r_outs) = jax.lax.scan(
                            one_step, fops.lane_zeros(G),
                            resh((keys, cx, cy, w32, rows, ef_rows)))
                        delta = fops.lane_combine(delta_g)
                        delta = jax.lax.with_sharding_constraint(delta,
                                                                 p_sh)
                    else:
                        vadd = jax.vmap(
                            lambda a, c, w: fops.delta_accum(a, c, None, w))
                        delta0 = jax.tree_util.tree_map(
                            lambda d: jnp.zeros((G,) + d.shape, d.dtype),
                            zeros_delta())

                        def one_step(delta_g, inp):
                            k_g, cx_g, cy_g, w_g, row_g, r_g = inp
                            w_end_g, out_g, loss_g = vclient(k_g, cx_g,
                                                             cy_g, row_g)
                            c_g, r_new_g = vcompress(w_end_g, r_g)
                            return (vadd(delta_g, c_g, w_g / wsum),
                                    (out_g, loss_g, r_new_g))

                        delta_g, (outs, losses, r_outs) = jax.lax.scan(
                            one_step, delta0,
                            resh((keys, cx, cy, w32, rows, ef_rows)))
                        delta = jax.tree_util.tree_map(
                            lambda d: jnp.sum(d, axis=0), delta_g)
                elif lane_psum and dp_clips:
                    # clipped coefficients no longer sum to 1, so the
                    # −(Σc)·p term cannot factor out as −p: carry the
                    # running coefficient sum next to the p-free lane
                    # partials and apply −csum·p once after the combine
                    dp_scales = jax.vmap(
                        lambda we: privacy.clip_scale(
                            dp, privacy.flat_delta_sqnorm(we, params)))

                    def one_step(carry, inp):
                        delta_g, csum = carry
                        k_g, cx_g, cy_g, w_g, row_g = inp
                        w_end_g, out_g, loss_g = vclient(k_g, cx_g, cy_g,
                                                         row_g)
                        coeffs = (w_g / wsum) * dp_scales(w_end_g)
                        return ((fops.lane_accum(delta_g, w_end_g, coeffs),
                                 csum + jnp.sum(coeffs)),
                                (out_g, loss_g))

                    (delta_g, csum), (outs, losses) = jax.lax.scan(
                        one_step, (fops.lane_zeros(G), jnp.float32(0.0)),
                        resh((keys, cx, cy, w32, rows)))
                    acc = fops.lane_combine(delta_g)
                    acc = jax.lax.with_sharding_constraint(acc, p_sh)
                    delta = {name: acc[name] -
                             csum * params[name].astype(jnp.float32)
                             for name in acc}
                elif lane_psum:
                    def one_step(delta_g, inp):
                        k_g, cx_g, cy_g, w_g, row_g = inp
                        w_end_g, out_g, loss_g = vclient(k_g, cx_g, cy_g,
                                                         row_g)
                        return (fops.lane_accum(delta_g, w_end_g,
                                                w_g / wsum),
                                (out_g, loss_g))

                    delta_g, (outs, losses) = jax.lax.scan(
                        one_step, fops.lane_zeros(G),
                        resh((keys, cx, cy, w32, rows)))
                    acc = fops.lane_combine(delta_g)
                    acc = jax.lax.with_sharding_constraint(acc, p_sh)
                    # A = Σᵢ cᵢ·wᵢ came back combined; the −(Σc)·p term
                    # factors out exactly (Σᵢ wᵢ/wsum = 1), applied once
                    delta = {name: acc[name] -
                             params[name].astype(jnp.float32)
                             for name in acc}
                else:
                    vadd = jax.vmap(add_delta, in_axes=(0, 0, 0))
                    delta0 = jax.tree_util.tree_map(
                        lambda d: jnp.zeros((G,) + d.shape, d.dtype),
                        zeros_delta())

                    def one_step(delta_g, inp):
                        k_g, cx_g, cy_g, w_g, row_g = inp
                        w_end_g, out_g, loss_g = vclient(k_g, cx_g, cy_g,
                                                         row_g)
                        return vadd(delta_g, w_end_g, w_g), (out_g, loss_g)

                    delta_g, (outs, losses) = jax.lax.scan(
                        one_step, delta0, resh((keys, cx, cy, w32, rows)))
                    # the single cross-pod combine: one reduction per
                    # bucket over the G pod partials
                    delta = jax.tree_util.tree_map(
                        lambda d: jnp.sum(d, axis=0), delta_g)
                # (S, G, ...) lane outputs fold back to client order —
                # client j ran as step j//G, lane j%G
                outs = jax.tree_util.tree_map(
                    lambda a: a.reshape((K,) + a.shape[2:]), outs)
                losses = losses.reshape(K)
                if ef:
                    r_outs = jax.tree_util.tree_map(
                        lambda a: a.reshape((K,) + a.shape[2:]), r_outs)
            elif compressed:
                def one_client(delta, inp):
                    k, cxi, cyi, w_i, row, r_row = inp
                    w_end, out, loss = client(k, cxi, cyi, row)
                    c, r_new = compress_client(w_end, r_row)
                    return (fops.delta_accum(delta, c, None, w_i / wsum),
                            (out, loss, r_new))

                delta, (outs, losses, r_outs) = jax.lax.scan(
                    one_client, zeros_delta(),
                    (keys, cx, cy, w32, rows, ef_rows))
            else:
                def one_client(delta, inp):
                    k, cxi, cyi, w_i, row = inp
                    w_end, out, loss = client(k, cxi, cyi, row)
                    return add_delta(delta, w_end, w_i), (out, loss)

                delta, (outs, losses) = jax.lax.scan(
                    one_client, zeros_delta(), (keys, cx, cy, w32, rows))

            # aggregated DP noise + secure-agg masks: independent of the
            # client outputs, so computed once per round and added to the
            # f32 delta in every topology (None statically when off)
            extra = privacy.round_extra(
                dp, spec.secure_agg, key, ids, w32 / wsum,
                zeros_fn=zeros_delta,
                normal_fn=fops.normal if fused else
                (lambda k: privacy.tree_normal(k, params)))
            if extra is not None:
                delta = jax.tree_util.tree_map(jnp.add, delta, extra)

            new_params = pin(apply_delta(params, delta))

            if algo == "scaffold":
                # c ← c + (K/N)·mean_i(c_i⁺ − c_i); N is the population
                frac = K / store.population(c_all)
                c_new = jax.tree_util.tree_map(
                    lambda cg, new, old: cg + frac * jnp.mean(new - old,
                                                              axis=0),
                    c, outs, rows)
                state = dict(algo_state, c_global=c_new,
                             c_clients=store.scatter(c_all, ids, outs))
            elif algo == "moon":
                state = dict(algo_state,
                             w_prev=store.scatter(w_prev_all, ids, outs))
            else:
                state = algo_state
            if ef:
                state = dict(state, ef_residuals=ef_store.scatter(
                    algo_state["ef_residuals"], ids, r_outs))
            return new_params, state, jnp.mean(losses)

        return body


# ---------------------------------------------------------------------------
# declarative phase configs (core.pipeline entries)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PodCyclicConfig:
    """P1 relay phase on the pod backend."""
    mesh: Any
    rounds: int = 4
    clients_per_round: int = 4
    spec: PodFLSpec = PodFLSpec()
    layout: str = "fsdp_tp"
    lr_decay: float = 1.0           # the pod driver historically had no decay
    eval_every: int = 0
    eval_batch: int = 64
    seed: int = 0
    chunk_size: int = 4
    sampling: str = "device"        # device | host (seed-compatible)

    def strategy(self) -> PodRelayStrategy:
        return PodRelayStrategy(
            spec=self.spec.local_spec("plain"), mesh=self.mesh,
            layout=self.layout, clients_per_round=self.clients_per_round)

    def schedule(self) -> RoundSchedule:
        return RoundSchedule(
            rounds=self.rounds, lr_decay=self.lr_decay,
            eval_every=self.eval_every, eval_batch=self.eval_batch,
            seed=self.seed, chunk_size=self.chunk_size,
            sampling=self.sampling, host_rng_offset=HOST_RNG_OFFSET_P1)


@dataclasses.dataclass(frozen=True)
class PodFLConfig:
    """P2 aggregation phase on the pod backend (algorithm from spec)."""
    mesh: Any
    rounds: int = 4
    clients_per_round: int = 4
    spec: PodFLSpec = PodFLSpec()
    layout: str = "fsdp_tp"
    lr_decay: float = 1.0
    eval_every: int = 0
    eval_batch: int = 64
    seed: int = 0
    chunk_size: int = 4
    sampling: str = "device"
    aggregation: str = "sequential"     # sequential | hierarchical
    n_pods: Optional[int] = None
    store: str = "dense"                # dense | sparse
    store_capacity: int = 1024          # sparse active-set rows
    overlap: bool = True                # pipeline residency behind compute

    def strategy(self) -> PodAggregateStrategy:
        kwargs = {}
        if self.store == "sparse":
            kwargs["state_store"] = ShardedSparseClientStateStore(
                capacity=self.store_capacity, mesh=self.mesh)
        elif self.store != "dense":
            raise ValueError(f"unknown store {self.store!r} "
                             f"(choose from ('dense', 'sparse'))")
        return PodAggregateStrategy(
            spec=self.spec.local_spec(), algorithm=self.spec.algorithm,
            server_opt=self.spec.server_opt, server_lr=self.spec.server_lr,
            server_momentum=self.spec.server_momentum,
            mesh=self.mesh, layout=self.layout,
            clients_per_round=self.clients_per_round,
            aggregation=self.aggregation, n_pods=self.n_pods, **kwargs)

    def schedule(self) -> RoundSchedule:
        return RoundSchedule(
            rounds=self.rounds, lr_decay=self.lr_decay,
            eval_every=self.eval_every, eval_batch=self.eval_batch,
            seed=self.seed, chunk_size=self.chunk_size,
            sampling=self.sampling, host_rng_offset=HOST_RNG_OFFSET_P2,
            overlap=self.overlap)


def run_pod_rounds(task: Task, data: FederatedDataset, cfg,
                   init_params: Optional[Pytree] = None,
                   ledger=None, verbose: bool = False,
                   eval_fn: Optional[Callable] = None,
                   switch_policy=None, phase: str = "P2"):
    """Phase runner for the pod configs — the engine loop does the work."""
    strategy = cfg.strategy()
    return run_rounds(task, data, strategy, cfg.schedule(),
                      init_params=init_params, ledger=ledger, verbose=verbose,
                      eval_fn=eval_fn, switch_policy=switch_policy,
                      phase=phase, label=f"pod-{strategy.name}")


# register with the declarative schedule so Phase(cfg=Pod*Config) works
from repro.core.pipeline import register_phase_runner  # noqa: E402

register_phase_runner(PodCyclicConfig, "relay", run_pod_rounds)
register_phase_runner(PodFLConfig, "aggregate", run_pod_rounds)
