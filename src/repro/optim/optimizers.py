"""Minimal optax-style optimizers in pure JAX.

The paper's experiments use SGD with momentum + weight decay + per-round
exponential lr decay; AdamW is provided for the LLM-class assigned
architectures.  An Optimizer is an (init, update) pair over pytrees; state
is itself a pytree so it shards/checkpoints like parameters.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.utils import tree_math as tm

Pytree = Any
Schedule = Callable[[jnp.ndarray], jnp.ndarray]


class OptState(NamedTuple):
    step: jnp.ndarray
    inner: Any


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Pytree], OptState]
    update: Callable[[Pytree, OptState, Pytree], tuple]  # (grads, state, params) -> (updates, state)

    def apply(self, grads: Pytree, state: OptState, params: Pytree):
        updates, new_state = self.update(grads, state, params)
        return apply_updates(params, updates), new_state


def apply_updates(params: Pytree, updates: Pytree) -> Pytree:
    return jax.tree_util.tree_map(lambda p, u: (p + u).astype(p.dtype), params, updates)


def _as_schedule(lr) -> Schedule:
    if callable(lr):
        return lr
    return lambda step: jnp.asarray(lr, dtype=jnp.float32)


def sgd(lr, momentum: float = 0.0, weight_decay: float = 0.0, nesterov: bool = False) -> Optimizer:
    """SGD + heavyball momentum + decoupled weight decay (paper default)."""
    sched = _as_schedule(lr)
    use_momentum = momentum != 0.0

    def init(params):
        inner = tm.zeros_like(params) if use_momentum else ()
        return OptState(step=jnp.zeros((), jnp.int32), inner=inner)

    def update(grads, state, params):
        step_lr = sched(state.step)
        if weight_decay:
            grads = jax.tree_util.tree_map(
                lambda g, p: g + weight_decay * p.astype(g.dtype), grads, params)
        if use_momentum:
            buf = jax.tree_util.tree_map(
                lambda m, g: momentum * m + g.astype(m.dtype), state.inner, grads)
            if nesterov:
                eff = jax.tree_util.tree_map(lambda g, m: g + momentum * m, grads, buf)
            else:
                eff = buf
            inner = buf
        else:
            eff = grads
            inner = ()
        updates = jax.tree_util.tree_map(lambda g: -step_lr * g, eff)
        return updates, OptState(step=state.step + 1, inner=inner)

    return Optimizer(init=init, update=update)


class AdamWState(NamedTuple):
    mu: Pytree
    nu: Pytree


def adamw(lr, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0) -> Optimizer:
    sched = _as_schedule(lr)

    def init(params):
        return OptState(
            step=jnp.zeros((), jnp.int32),
            inner=AdamWState(mu=tm.zeros_like(params), nu=tm.zeros_like(params)),
        )

    def update(grads, state, params):
        step = state.step + 1
        step_lr = sched(state.step)
        mu = jax.tree_util.tree_map(
            lambda m, g: b1 * m + (1 - b1) * g.astype(m.dtype), state.inner.mu, grads)
        nu = jax.tree_util.tree_map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(v.dtype)), state.inner.nu, grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(m, v, p):
            mhat = m / bc1
            vhat = v / bc2
            u = mhat / (jnp.sqrt(vhat) + eps)
            if weight_decay:
                u = u + weight_decay * p.astype(u.dtype)
            return -step_lr * u

        updates = jax.tree_util.tree_map(upd, mu, nu, params)
        return updates, OptState(step=step, inner=AdamWState(mu=mu, nu=nu))

    return Optimizer(init=init, update=update)
