"""Learning-rate schedules.  The paper decays lr to 99.8% per round."""
from __future__ import annotations

import jax.numpy as jnp


def constant_schedule(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def exponential_decay(lr: float, decay_rate: float = 0.998, steps_per_round: int = 1):
    """Paper schedule: lr *= decay_rate once per FL round."""

    def sched(step):
        rounds = jnp.floor_divide(step, steps_per_round).astype(jnp.float32)
        return jnp.asarray(lr, jnp.float32) * decay_rate ** rounds

    return sched


def cosine_decay(lr: float, total_steps: int, final_frac: float = 0.0):
    def sched(step):
        t = jnp.clip(step.astype(jnp.float32) / max(total_steps, 1), 0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
        return lr * (final_frac + (1 - final_frac) * cos)

    return sched


def warmup_cosine(lr: float, warmup_steps: int, total_steps: int, final_frac: float = 0.1):
    cos = cosine_decay(lr, max(total_steps - warmup_steps, 1), final_frac)

    def sched(step):
        step_f = step.astype(jnp.float32)
        warm = lr * step_f / max(warmup_steps, 1)
        return jnp.where(step < warmup_steps, warm, cos(step - warmup_steps))

    return sched
