from repro.optim.optimizers import (
    Optimizer,
    OptState,
    sgd,
    adamw,
    apply_updates,
)
from repro.optim.schedules import (
    constant_schedule,
    exponential_decay,
    cosine_decay,
    warmup_cosine,
)
