"""Flat-npz pytree checkpointing with structure manifest.

Good enough for single-host simulation and CPU validation; the on-disk
format is a ``.npz`` of flattened leaves keyed by path plus a JSON
manifest describing the treedef, so restore round-trips arbitrary nested
dict/list/tuple/NamedTuple-free pytrees (FL server state is plain dicts
by convention in this codebase).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import tempfile
from typing import Any, Dict, List, Optional

import jax
import numpy as np

Pytree = Any
_SEP = "/"


def _flatten_with_paths(tree: Pytree):
    flat = jax.tree_util.tree_flatten_with_path(tree)
    leaves = []
    for path, leaf in flat[0]:
        key = _SEP.join(_path_part(p) for p in path)
        leaves.append((key, np.asarray(leaf)))
    return leaves, flat[1]


def _path_part(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def save_pytree(path: str, tree: Pytree, metadata: Optional[Dict] = None) -> None:
    leaves, _ = _flatten_with_paths(tree)
    arrays = {f"leaf{_SEP}{k}": v for k, v in leaves}
    struct = jax.tree_util.tree_map(lambda _: 0, tree)
    manifest = {
        "structure": _encode_structure(struct),
        "keys": [k for k, _ in leaves],
        "metadata": metadata or {},
    }
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(os.path.abspath(path)), suffix=".tmp")
    os.close(fd)
    try:
        np.savez(tmp, __manifest__=np.frombuffer(
            json.dumps(manifest).encode(), dtype=np.uint8), **arrays)
        shutil.move(tmp + ".npz" if os.path.exists(tmp + ".npz") else tmp, path)
    finally:
        for cand in (tmp, tmp + ".npz"):
            if os.path.exists(cand):
                os.remove(cand)


def _encode_structure(struct: Pytree):
    if isinstance(struct, dict):
        return {"__kind__": "dict", "items": {k: _encode_structure(v) for k, v in struct.items()}}
    if isinstance(struct, (list, tuple)):
        kind = "list" if isinstance(struct, list) else "tuple"
        return {"__kind__": kind, "items": [_encode_structure(v) for v in struct]}
    return {"__kind__": "leaf"}


def _decode_structure(enc, leaves_iter):
    kind = enc["__kind__"]
    if kind == "dict":
        return {k: _decode_structure(v, leaves_iter) for k, v in enc["items"].items()}
    if kind in ("list", "tuple"):
        seq = [_decode_structure(v, leaves_iter) for v in enc["items"]]
        return seq if kind == "list" else tuple(seq)
    return next(leaves_iter)


def load_pytree(path: str) -> Pytree:
    with np.load(path, allow_pickle=False) as z:
        manifest = json.loads(bytes(z["__manifest__"]).decode())
        leaves = [z[f"leaf{_SEP}{k}"] for k in manifest["keys"]]
    return _decode_structure(manifest["structure"], iter(leaves))


def load_metadata(path: str) -> Dict:
    with np.load(path, allow_pickle=False) as z:
        return json.loads(bytes(z["__manifest__"]).decode())["metadata"]


class CheckpointManager:
    """Rolling round-numbered checkpoints: ``<dir>/ckpt_<round>.npz``."""

    PATTERN = re.compile(r"ckpt_(\d+)\.npz$")

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    def save(self, round_: int, tree: Pytree, metadata: Optional[Dict] = None) -> str:
        path = os.path.join(self.directory, f"ckpt_{round_}.npz")
        meta = dict(metadata or {})
        meta["round"] = round_
        save_pytree(path, tree, meta)
        self._gc()
        return path

    def latest(self) -> Optional[str]:
        rounds = self._rounds()
        if not rounds:
            return None
        return os.path.join(self.directory, f"ckpt_{rounds[-1]}.npz")

    def restore(self) -> Optional[Pytree]:
        path = self.latest()
        return None if path is None else load_pytree(path)

    def _rounds(self) -> List[int]:
        out = []
        for f in os.listdir(self.directory):
            m = self.PATTERN.match(f)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def _gc(self) -> None:
        rounds = self._rounds()
        for r in rounds[:-self.keep]:
            os.remove(os.path.join(self.directory, f"ckpt_{r}.npz"))
