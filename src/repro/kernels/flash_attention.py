"""Flash attention for TPU — Pallas kernel (causal, GQA, sliding window).

Online-softmax attention with the canonical TPU tiling: the grid is
(batch, q_head, q_block, kv_block) with the kv axis innermost, so each
(b, h, i) q tile stays resident in VMEM while K/V stream through in
``bk``-sized chunks; running max ``m``, normalizer ``l`` and the f32
output accumulator live in VMEM scratch across kv steps.  Both matmuls
(Q·Kᵀ and P·V) hit the MXU; block sizes default to 128 to match the
MXU's 128×128 systolic tile.

GQA is handled in the index map: q head ``h`` reads kv head ``h // G``
directly — the KV tensor is never materialized per-q-head.

Dynamic quantities ride in a scalar-prefetch operand (SMEM):
  [0] q_offset  — position of q[0] relative to k[0] (decode: cache_len)
  [1] window    — sliding-window size (2^30 = full causal); traced
                  per-layer in hybrid models (Hymba SWA/global mix)
  [2] kv_len    — true #keys before padding to a bk multiple

This container is CPU-only: the kernel is validated in interpret mode
against ``ref.attention_ref``; on real TPU the same code lowers to
Mosaic (pallas_call is the TARGET artifact).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(scal_ref, q_ref, k_ref, v_ref, o_ref,
                  acc_ref, m_ref, l_ref, *, bq: int, bk: int, scale: float):
    i = pl.program_id(2)
    j = pl.program_id(3)
    nk = pl.num_programs(3)
    q_offset = scal_ref[0]
    window = scal_ref[1]
    kv_len = scal_ref[2]

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, :, 0, :].astype(jnp.float32) * scale        # (bq, hd)
    k = k_ref[0, :, 0, :].astype(jnp.float32)                # (bk, hd)
    v = v_ref[0, :, 0, :].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (bq, bk)

    qpos = q_offset + i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = (kpos <= qpos) & (kpos > qpos - window) & (kpos < kv_len)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
    # explicit zeroing of masked entries — when a whole row is masked the
    # shifted exponent would otherwise be exp(0)=1 and pollute l/acc.
    p = jnp.where(mask, jnp.exp(s - m_cur[:, None]), 0.0)
    alpha = jnp.exp(m_prev - m_cur)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_cur

    @pl.when(j == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, :, 0, :] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True,
                    window: Optional[jnp.ndarray] = None,
                    q_offset=0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False) -> jnp.ndarray:
    """q: (B, S, H, hd); k/v: (B, T, KH, hd).  Returns (B, S, H, hd).

    ``window``/``q_offset`` may be traced scalars (decode / per-layer SWA).
    Non-causal is not needed by any assigned arch; ``causal`` is asserted.
    """
    assert causal, "only causal attention is implemented (decoder-only archs)"
    B, S, H, hd = q.shape
    T, KH = k.shape[1], k.shape[2]
    assert H % KH == 0, (H, KH)
    G = H // KH

    bq = min(block_q, S)
    bk = min(block_k, T)
    s_pad = (-S) % bq
    t_pad = (-T) % bk
    if s_pad:
        q = jnp.pad(q, ((0, 0), (0, s_pad), (0, 0), (0, 0)))
    if t_pad:
        k = jnp.pad(k, ((0, 0), (0, t_pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, t_pad), (0, 0), (0, 0)))
    Sp, Tp = S + s_pad, T + t_pad

    win = jnp.int32(2 ** 30) if window is None else jnp.asarray(window, jnp.int32)
    scalars = jnp.stack([jnp.asarray(q_offset, jnp.int32), win,
                         jnp.asarray(T, jnp.int32)])

    grid = (B, H, Sp // bq, Tp // bk)
    kernel = functools.partial(_flash_kernel, bq=bq, bk=bk, scale=hd ** -0.5)

    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, bq, 1, hd), lambda b, h, i, j, s: (b, i, h, 0)),
                pl.BlockSpec((1, bk, 1, hd), lambda b, h, i, j, s: (b, j, h // G, 0)),
                pl.BlockSpec((1, bk, 1, hd), lambda b, h, i, j, s: (b, j, h // G, 0)),
            ],
            out_specs=pl.BlockSpec((1, bq, 1, hd), lambda b, h, i, j, s: (b, i, h, 0)),
            scratch_shapes=[
                pltpu.VMEM((bq, hd), jnp.float32),   # acc
                pltpu.VMEM((bq,), jnp.float32),      # running max m
                pltpu.VMEM((bq,), jnp.float32),      # normalizer l
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, Sp, H, hd), q.dtype),
        interpret=interpret,
    )(scalars, q, k, v)
    return out[:, :S]
