"""Pallas TPU kernels for the assigned architectures' compute hot spots.

flash_attention — causal GQA attention w/ online softmax + sliding window
ssd_scan        — Mamba2 SSD chunked scan with carried VMEM state
fused_update    — FL update hot loop over FlatView flat buffers (client
                  step tail, weighted-delta aggregation, server moments)

``ops`` holds the jit'd wrappers; ``ref`` the pure-jnp oracles the tests
sweep against (interpret mode — this container has no TPU; the fused
update kernels' oracle is the tree_math path itself).
"""
from repro.kernels import fused_update, ops, ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ssd_scan import ssd_scan
