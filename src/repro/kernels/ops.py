"""Jit'd public wrappers around the Pallas kernels.

``repro.models`` routes through these when a config selects
``attn_impl='pallas'`` / ``ssd_impl='pallas'`` (or the ``*_interpret``
variants used for CPU validation).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import fused_update as _fu
from repro.kernels import ssd_scan as _ssd


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True,
                    window: Optional[jnp.ndarray] = None,
                    q_offset=0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False) -> jnp.ndarray:
    if window is None:
        window = jnp.int32(2 ** 30)
    if q_offset is None:
        q_offset = 0
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               q_offset=q_offset, block_q=block_q,
                               block_k=block_k, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
        B: jnp.ndarray, C: jnp.ndarray, *, chunk: int = 128,
        interpret: bool = False) -> jnp.ndarray:
    y, _ = _ssd.ssd_scan(x, dt, A, B, C, chunk=chunk, interpret=interpret)
    return y


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_with_state(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
                   B: jnp.ndarray, C: jnp.ndarray, *, chunk: int = 128,
                   interpret: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    return _ssd.ssd_scan(x, dt, A, B, C, chunk=chunk, interpret=interpret)


# ---------------------------------------------------------------------------
# fused FL-update kernels (repro.kernels.fused_update over flat buffers)
# ---------------------------------------------------------------------------
#
# The FL layers call these through a ``repro.fl.local.FlatParamOps``
# (flat-first: one call per dtype/mesh-axis bucket) with
# ``interpret=fused_interpret(spec)``, so ``update_impl="fused"`` lowers
# to Mosaic on TPU and transparently runs the interpreter on the CPU
# container (where there is no Mosaic backend);
# ``update_impl="fused_interpret"`` forces the interpreter everywhere
# (parity tests, benchmarks).  All wrappers take 1-D buffers: on the
# pod, ``repro.fl.pod.ShardedFlatOps`` invokes them inside a
# ``shard_map`` on each device's contiguous local shard, so the same
# kernels serve single-host FlatView buffers and mesh-sharded
# ShardedFlatView buckets unchanged.

def fused_interpret(update_impl: str) -> bool:
    """interpret= flag for an ``update_impl`` value: explicit interpret
    mode, or a CPU/GPU backend where Mosaic cannot lower."""
    return update_impl == "fused_interpret" or jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("weight_decay", "momentum",
                                             "block_rows", "interpret"))
def fused_local_step(p: jnp.ndarray, g: jnp.ndarray,
                     m: Optional[jnp.ndarray], c: Optional[jnp.ndarray],
                     clip_scale, step_size, *, weight_decay: float = 0.0,
                     momentum: float = 0.0, block_rows: int = 0,
                     interpret: bool = False):
    """Fused client step tail over one flat buffer — clip-scaled gradient
    + scaffold correction + decoupled weight decay + heavy-ball momentum
    + axpy in one blocked pass.  Returns (p_new, m_new-or-None)."""
    return _fu.local_step(p, g, m, c, clip_scale, step_size,
                          weight_decay=weight_decay, momentum=momentum,
                          block_rows=block_rows or _fu.DEFAULT_BLOCK_ROWS,
                          interpret=interpret)


@functools.partial(jax.jit, static_argnames=("deltas", "block_rows",
                                             "interpret"))
def fused_weighted_delta(stacked: jnp.ndarray, p: jnp.ndarray,
                         weights: jnp.ndarray,
                         extra: Optional[jnp.ndarray] = None, *,
                         deltas: bool = False, block_rows: int = 0,
                         interpret: bool = False) -> jnp.ndarray:
    """FedAvg aggregation over a stacked (K, N) flat buffer:
    ``cast(p32 + sum_k w_k * (stacked[k] - p) (+ extra))``.  ``extra``
    is an optional f32 (N,) buffer (aggregated DP noise + secure-agg
    masks) folded into the same blocked pass.  ``deltas=True`` (static)
    reads ``stacked`` as already-formed client deltas and drops the
    per-term ``- p`` (the compressed-communication aggregate)."""
    return _fu.weighted_delta(stacked, p, weights, extra=extra,
                              deltas=deltas,
                              block_rows=block_rows or _fu.DEFAULT_BLOCK_ROWS,
                              interpret=interpret)


@functools.partial(jax.jit, static_argnames=("bits", "topk", "with_residual",
                                             "block_rows", "interpret"))
def fused_compress_delta(d: jnp.ndarray, thresh, *, bits: int = 32,
                         topk: bool = False, with_residual: bool = False,
                         block_rows: int = 0, interpret: bool = False):
    """Compressed-communication form of one client's f32 flat delta:
    magnitude top-k masking at the traced threshold ``thresh`` (static
    ``topk`` gate) followed by blockwise symmetric int8/int16 fake
    quantization (per 128-lane-block bf16 scales; ``bits=32`` skips it
    statically).  Returns ``c``, or ``(c, r)`` with the error-feedback
    residual ``r = d - c`` when ``with_residual``."""
    return _fu.compress_delta(d, thresh, bits=bits, topk=topk,
                              with_residual=with_residual,
                              block_rows=block_rows or _fu.DEFAULT_BLOCK_ROWS,
                              interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def fused_dp_clip_noise(d: jnp.ndarray, z: Optional[jnp.ndarray],
                        clip_scale, noise_scale, *, block_rows: int = 0,
                        interpret: bool = False) -> jnp.ndarray:
    """One client's DP upload over one flat buffer:
    ``clip_scale * d32 (+ noise_scale * z)`` in a single blocked pass
    (``z=None`` statically drops the Gaussian term)."""
    return _fu.dp_clip_noise(d, z, clip_scale, noise_scale,
                             block_rows=block_rows or _fu.DEFAULT_BLOCK_ROWS,
                             interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def fused_delta_accum(delta: jnp.ndarray, w_end: jnp.ndarray,
                      p, coeff, *, block_rows: int = 0,
                      interpret: bool = False) -> jnp.ndarray:
    """One client's contribution to the pod backend's running f32
    weighted-delta sum: ``delta + coeff * (w_end32 - p32)``, or the
    p-free accum-only form ``delta + coeff * w_end32`` when ``p=None``
    (hierarchical per-lane partials)."""
    return _fu.delta_accum(delta, w_end, p, coeff,
                           block_rows=block_rows or _fu.DEFAULT_BLOCK_ROWS,
                           interpret=interpret)


@functools.partial(jax.jit, static_argnames=("opt", "beta", "b1", "b2",
                                             "eps", "block_rows",
                                             "interpret"))
def fused_server_update(p: jnp.ndarray, delta: jnp.ndarray, moments, scalars,
                        *, opt: str = "none", beta: float = 0.9,
                        b1: float = 0.9, b2: float = 0.99, eps: float = 1e-8,
                        block_rows: int = 0, interpret: bool = False):
    """Apply an aggregated f32 delta under a server optimizer
    (none / FedAvgM momentum / FedAdam).  Returns (p_new, new_moments)."""
    return _fu.server_update(p, delta, tuple(moments), tuple(scalars),
                             opt=opt, beta=beta, b1=b1, b2=b2, eps=eps,
                             block_rows=block_rows or _fu.DEFAULT_BLOCK_ROWS,
                             interpret=interpret)
