"""Jit'd public wrappers around the Pallas kernels.

``repro.models`` routes through these when a config selects
``attn_impl='pallas'`` / ``ssd_impl='pallas'`` (or the ``*_interpret``
variants used for CPU validation).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import ssd_scan as _ssd


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True,
                    window: Optional[jnp.ndarray] = None,
                    q_offset=0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False) -> jnp.ndarray:
    if window is None:
        window = jnp.int32(2 ** 30)
    if q_offset is None:
        q_offset = 0
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               q_offset=q_offset, block_q=block_q,
                               block_k=block_k, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
        B: jnp.ndarray, C: jnp.ndarray, *, chunk: int = 128,
        interpret: bool = False) -> jnp.ndarray:
    y, _ = _ssd.ssd_scan(x, dt, A, B, C, chunk=chunk, interpret=interpret)
    return y


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_with_state(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
                   B: jnp.ndarray, C: jnp.ndarray, *, chunk: int = 128,
                   interpret: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    return _ssd.ssd_scan(x, dt, A, B, C, chunk=chunk, interpret=interpret)
