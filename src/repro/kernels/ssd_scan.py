"""Mamba2 SSD (state-space duality) chunked scan — Pallas TPU kernel.

TPU adaptation (DESIGN.md §5): the GPU reference implements the
inter-chunk recurrence with warp-level primitives; TPUs have no warp
shuffles, so the chunked form IS the TPU-native algorithm — every
chunk-local term is a (chunk × chunk) or (chunk × d_state) matmul that
lands on the MXU, and the only sequential dependency is the tiny
(head_dim × d_state) state tile carried in VMEM scratch across the
innermost grid axis.

Grid: (batch, head, chunk) — chunk innermost, so for a fixed (b, h) the
chunks execute in order and the scratch state is the running recurrence.
Per step the kernel computes, entirely in VMEM:

  intra  :  y_j += Σ_{i≤j}  (C_j·B_i) · exp(cum_j − cum_i) · dt_i · x_i
  inter  :  y_j += exp(cum_j) · C_j · state_inᵀ
  state' :  exp(cum_L) · state_in  +  Σ_i dt_i exp(cum_L − cum_i) x_i B_iᵀ

which matches the exact recurrence state_t = state_{t−1}·exp(dt_t A_h)
+ dt_t·x_t B_tᵀ; y_t = C_t·state_t (see ref.ssd_ref).

The per-head decay A rides in scalar-prefetch SMEM; grouped B/C (g < h)
are mapped per-head in the index map (h // heads_per_group) so the
group tensors are never materialized per head.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(a_ref, x_ref, dt_ref, b_ref, c_ref, y_ref, fs_ref,
                state_ref, *, chunk: int):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)
    h = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, :, 0, :].astype(jnp.float32)          # (L, p)
    dt = dt_ref[0, :, 0].astype(jnp.float32)           # (L,)
    A = a_ref[h]                                       # scalar (negative)
    Bm = b_ref[0, :, 0, :].astype(jnp.float32)         # (L, n)
    Cm = c_ref[0, :, 0, :].astype(jnp.float32)         # (L, n)

    dA = dt * A
    cum = jnp.cumsum(dA)                               # inclusive
    # ---- intra-chunk quadratic term (MXU matmuls) ----
    seg = cum[:, None] - cum[None, :]                  # (L, L): cum_j - cum_i
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.where(ii >= jj, jnp.exp(seg), 0.0)
    CB = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (L, L)
    M = CB * decay * dt[None, :]
    y = jax.lax.dot_general(M, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (L, p)
    # ---- inter-chunk: contribution of the entering state ----
    state_in = state_ref[...]                          # (p, n)
    y += jnp.exp(cum)[:, None] * jax.lax.dot_general(
        Cm, state_in, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)            # (L, p)
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)
    # ---- state update ----
    total = cum[-1]
    w = (dt * jnp.exp(total - cum))[:, None] * x       # (L, p)
    state_ref[...] = state_in * jnp.exp(total) + jax.lax.dot_general(
        w, Bm, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ci == nc - 1)
    def _emit_final():
        fs_ref[0, 0, :, :] = state_ref[...]


def ssd_scan(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
             B: jnp.ndarray, C: jnp.ndarray, *, chunk: int = 128,
             interpret: bool = False):
    """x: (b, s, h, p); dt: (b, s, h) positive; A: (h,); B/C: (b, s, g, n).

    Returns (y (b, s, h, p), final_state (b, h, p, n) f32).
    s is padded to a chunk multiple with dt=0 (exp(0)=1, contribution 0),
    so padding does not perturb the state.
    """
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    hpg = h // g

    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    sp = s + pad
    grid = (b, h, sp // chunk)

    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    y, final = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, chunk, 1, p), lambda bi, hi, ci, a: (bi, ci, hi, 0)),
                pl.BlockSpec((1, chunk, 1), lambda bi, hi, ci, a: (bi, ci, hi)),
                pl.BlockSpec((1, chunk, 1, n),
                             lambda bi, hi, ci, a: (bi, ci, hi // hpg, 0)),
                pl.BlockSpec((1, chunk, 1, n),
                             lambda bi, hi, ci, a: (bi, ci, hi // hpg, 0)),
            ],
            out_specs=[
                pl.BlockSpec((1, chunk, 1, p), lambda bi, hi, ci, a: (bi, ci, hi, 0)),
                pl.BlockSpec((1, 1, p, n), lambda bi, hi, ci, a: (bi, hi, 0, 0)),
            ],
            scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((b, sp, h, p), x.dtype),
            jax.ShapeDtypeStruct((b, h, p, n), jnp.float32),
        ],
        interpret=interpret,
    )(A.astype(jnp.float32), x, dt, B, C)
    return y[:, :s], final
