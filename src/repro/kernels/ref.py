"""Pure-jnp oracles for the Pallas kernels.

These are the correctness references the kernel tests sweep against
(``assert_allclose`` over shapes/dtypes, kernels in interpret mode).
They are deliberately naive-but-exact; repro.models uses its own fused
XLA paths in production mode.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                  causal: bool = True, window: Optional[int] = None,
                  q_offset: int = 0) -> jnp.ndarray:
    """Dense attention oracle.

    q: (B, S, H, hd); k/v: (B, T, KH, hd) with H % KH == 0 (GQA).
    window w keeps keys with qpos - w < kpos <= qpos.
    """
    B, S, H, hd = q.shape
    T, KH = k.shape[1], k.shape[2]
    G = H // KH
    qg = q.reshape(B, S, KH, G, hd).astype(jnp.float32)
    logits = jnp.einsum("bskgd,btkd->bkgst", qg, k.astype(jnp.float32))
    logits *= hd ** -0.5
    qpos = q_offset + jnp.arange(S)
    kpos = jnp.arange(T)
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] > qpos[:, None] - window
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v.astype(jnp.float32))
    return out.reshape(B, S, H, hd).astype(q.dtype)


def ssd_ref(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
            B: jnp.ndarray, C: jnp.ndarray,
            initial_state: Optional[jnp.ndarray] = None):
    """Naive sequential SSD recurrence (exact oracle).

    x: (b, s, h, p); dt: (b, s, h) positive; A: (h,) negative;
    B/C: (b, s, g, n).  Returns (y (b,s,h,p), final_state (b,h,p,n)).

    state_t = state_{t-1} * exp(dt_t A_h) + dt_t * x_t B_t^T
    y_t     = C_t · state_t
    """
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    hpg = h // g
    Bh = jnp.repeat(B, hpg, axis=2) if g != h else B
    Ch = jnp.repeat(C, hpg, axis=2) if g != h else C

    def step(state, inp):
        xt, dtt, Bt, Ct = inp
        decay = jnp.exp(dtt * A[None, :])
        state = state * decay[:, :, None, None] + \
            dtt[:, :, None, None] * xt[:, :, :, None] * Bt[:, :, None, :]
        y = jnp.einsum("bhpn,bhn->bhp", state, Ct)
        return state, y

    init = (jnp.zeros((b, h, p, n), jnp.float32) if initial_state is None
            else initial_state.astype(jnp.float32))
    final, ys = jax.lax.scan(
        step, init,
        (jnp.moveaxis(x, 1, 0).astype(jnp.float32),
         jnp.moveaxis(dt, 1, 0).astype(jnp.float32),
         jnp.moveaxis(Bh, 1, 0).astype(jnp.float32),
         jnp.moveaxis(Ch, 1, 0).astype(jnp.float32)))
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), final
