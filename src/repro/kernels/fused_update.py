"""Fused FL-update Pallas kernels over FlatView buffers.

The federated hot loop spends its non-matmul time in parameter-space
algebra: every local SGD step runs clip → (scaffold) correction →
decoupled weight decay → momentum → axpy over the whole model, and every
round runs a weighted delta aggregation plus an optional server-moment
update (FedAvgM / FedAdam).  Leaf-wise ``tree_map`` makes each of those
O(n_leaves) ops; these kernels run them as ONE blocked pass over the
contiguous per-dtype buffers produced by
``repro.utils.flatten.FlatView``.

Kernels (all elementwise / VPU-bound, blocked (rows, 128) over the flat
buffer, f32 compute, cast on store):

  local_step      — the whole client step tail:
                      g ← g·clip_scale (+ c) (+ wd·p)
                      m ← g + β·m            (momentum, optional)
                      p ← p − step·(m or g)
  weighted_delta  — FedAvg aggregation over a stacked (K, N) buffer:
                      p ← cast(p₃₂ + Σₖ w̄ₖ·(wₖ − p) (+ e))
                    ``e`` is an optional f32 extra operand folded into
                    the same pass — the round's DP noise / secure-agg
                    mask total rides the aggregation kernel for free.
  compress_delta  — the compressed-communication form of the client
                    upload, one pass:
                      c ← quantize(topk(d));  r ← d − c
                    magnitude top-k masking at a prefetched threshold τ
                    plus blockwise symmetric int8/int16 fake
                    quantization (per 128-lane-block bf16 scales); the
                    optional residual r is the error-feedback carry.
  dp_clip_noise   — the privacy form of the client upload, one pass:
                      u ← clip_scale·d₃₂ (+ noise_scale·z)
                    clip_scale = min(1, C/‖d‖) clips the client delta to
                    the DP bound C; z is a standard-normal buffer and
                    noise_scale = σ·C calibrates the Gaussian mechanism.
  delta_accum     — the pod backend's sequential form, one client:
                      d ← d + coeff·(w₃₂ − p₃₂)
  server_update   — server optimizer on the pseudo-gradient g = −delta:
                      none     : p ← cast(p₃₂ + d)
                      momentum : m ← β·m + g;  p ← p − lr·m      (FedAvgM)
                      adam     : μ,ν moments + bias-corrected step (FedAdam)

Traced scalars (clip scale, step size, lr, bias corrections) ride a
scalar-prefetch operand in SMEM — same pattern as
``repro.kernels.flash_attention``.  Static algorithm constants (weight
decay, momentum, Adam betas) are compile-time kernel parameters, so
disabled terms cost nothing.

Buffers are 1-D; the wrappers pad to a (rows, 128) grid of
``block_rows``-row tiles and strip the pad on return — pad lanes stay
zero through every op above, so chaining kernels over padded buffers is
safe.  Callers that carry buffers across many kernel calls (the
engine's FlatParamOps chunk carries) pre-pad them to ``GRID_ALIGN``
(one 8-sublane × 128-lane tile) once at placement time: ``_pad_rows``
then degenerates to a reshape on every call, so the interpret/CPU path
pays zero pad copies per operand per step, and the trailing ``[:n]``
strip is a no-op slice XLA folds.  This container is CPU-only: the
kernels are validated in interpret mode against the tree_math oracles
(tests/test_fused_update); on TPU the same code lowers to Mosaic.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANES = 128
DEFAULT_BLOCK_ROWS = 512        # 512×128 f32 = 256 KB per operand tile
# one (8, 128) sublane×lane tile — buffers whose length is a multiple of
# this hit the pad==0 fast path in _pad_rows on the one-block interpret
# grid (FlatParamOps pre-pads its carried buffers to this alignment)
GRID_ALIGN = 8 * LANES


def _grid_rows(n: int, block_rows: int, interpret: bool) -> Tuple[int, int]:
    """(padded_rows, n_blocks) for an n-element 1-D buffer: rows pad to
    a sublane multiple (8), then to a whole number of row-blocks, with
    the block clamped for small buffers so tiny models don't pay a full
    512-row tile.  The block size bounds VMEM residency on TPU; the
    interpreter has no VMEM, and per-block iteration is its dominant
    cost, so interpret mode always runs ONE whole-buffer block."""
    rows = -(-n // LANES)
    rows8 = -(-rows // 8) * 8
    br = rows8 if interpret else min(block_rows, rows8)
    rows_p = -(-rows8 // br) * br
    return rows_p, rows_p // br


def _pad_rows(buf: jnp.ndarray, rows_p: int) -> jnp.ndarray:
    pad = rows_p * LANES - buf.shape[-1]
    if pad:
        widths = [(0, 0)] * (buf.ndim - 1) + [(0, pad)]
        buf = jnp.pad(buf, widths)
    return buf.reshape(buf.shape[:-1] + (rows_p, LANES))


# ---------------------------------------------------------------------------
# local step tail
# ---------------------------------------------------------------------------

def _local_step_kernel(sc_ref, *refs, wd: float, beta: float,
                       has_m: bool, has_c: bool):
    clip_scale = sc_ref[0]
    step_size = sc_ref[1]
    it = iter(refs)
    p_ref, g_ref = next(it), next(it)
    m_ref = next(it) if has_m else None
    c_ref = next(it) if has_c else None
    p_out = next(it)
    m_out = next(it) if has_m else None

    p = p_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32) * clip_scale
    if has_c:
        g = g + c_ref[...].astype(jnp.float32)
    if wd:
        g = g + wd * p
    if has_m:
        m = g + beta * m_ref[...].astype(jnp.float32)
        m_out[...] = m.astype(m_out.dtype)
        eff = m
    else:
        eff = g
    p_out[...] = (p - step_size * eff).astype(p_out.dtype)


def local_step(p: jnp.ndarray, g: jnp.ndarray,
               m: Optional[jnp.ndarray], c: Optional[jnp.ndarray],
               clip_scale, step_size, *, weight_decay: float = 0.0,
               momentum: float = 0.0, block_rows: int = DEFAULT_BLOCK_ROWS,
               interpret: bool = False):
    """One fused client SGD step over a 1-D flat buffer.

    Returns ``(p_new, m_new)`` (``m_new`` is None when ``m`` is).  The
    op order matches repro.fl.local's tree path exactly: the RAW
    gradient is pre-scaled by ``clip_scale``, then the scaffold
    correction ``c`` is added, then decoupled weight decay, then the
    heavy-ball momentum update, then the axpy with ``step_size`` =
    lr · lr_scale.
    """
    n = p.shape[-1]
    has_m, has_c = m is not None, c is not None
    if n == 0:                       # zero-size dtype bucket: nothing to do
        return p, m
    rows_p, n_blocks = _grid_rows(n, block_rows, interpret)
    br = rows_p // n_blocks
    operands = [_pad_rows(x, rows_p)
                for x in (p, g) + ((m,) if has_m else ()) +
                ((c,) if has_c else ())]
    scalars = jnp.stack([jnp.asarray(clip_scale, jnp.float32),
                         jnp.asarray(step_size, jnp.float32)])
    out_shape = [jax.ShapeDtypeStruct((rows_p, LANES), p.dtype)]
    if has_m:
        out_shape.append(jax.ShapeDtypeStruct((rows_p, LANES), m.dtype))
    kernel = functools.partial(_local_step_kernel, wd=float(weight_decay),
                               beta=float(momentum), has_m=has_m,
                               has_c=has_c)
    blk = pl.BlockSpec((br, LANES), lambda i, sc: (i, 0))
    outs = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(n_blocks,),
            in_specs=[blk] * len(operands),
            out_specs=[blk] * len(out_shape),
        ),
        out_shape=out_shape,
        interpret=interpret,
    )(scalars, *operands)
    p_new = outs[0].reshape(-1)[:n]
    m_new = outs[1].reshape(-1)[:n] if has_m else None
    return p_new, m_new


# ---------------------------------------------------------------------------
# weighted delta aggregation (host engine, all clients at once)
# ---------------------------------------------------------------------------

def _weighted_delta_kernel(w_ref, *refs, K: int, has_extra: bool,
                           deltas: bool):
    it = iter(refs)
    s_ref, p_ref = next(it), next(it)
    e_ref = next(it) if has_extra else None
    o_ref = next(it)
    p = p_ref[...].astype(jnp.float32)
    acc = e_ref[...] if has_extra else jnp.zeros_like(p)
    for k in range(K):                      # K is static and small
        if deltas:
            acc = acc + w_ref[k] * s_ref[k].astype(jnp.float32)
        else:
            acc = acc + w_ref[k] * (s_ref[k].astype(jnp.float32) - p)
    o_ref[...] = (p + acc).astype(o_ref.dtype)


def weighted_delta(stacked: jnp.ndarray, p: jnp.ndarray,
                   weights: jnp.ndarray, *,
                   extra: Optional[jnp.ndarray] = None,
                   deltas: bool = False,
                   block_rows: int = DEFAULT_BLOCK_ROWS,
                   interpret: bool = False) -> jnp.ndarray:
    """FedAvg aggregation: ``p₃₂ + Σₖ w̄ₖ·(stacked[k] − p) (+ extra)``
    cast back to ``p.dtype``.  ``stacked`` is (K, N), ``weights`` the
    (K,) normalized client weights (must sum to 1 for the
    convex-combination reading; per-client DP clip scales fold into
    them).  ``extra`` is an optional f32 (N,) buffer added inside the
    same pass — the round's aggregated DP noise + secure-agg mask term —
    so privacy costs zero additional traversals here.  ``deltas=True``
    (static) reads ``stacked`` as already-formed client DELTAS
    ``cₖ = compress(wₖ − p)`` and drops the per-term ``− p``:
    ``p₃₂ + Σₖ w̄ₖ·cₖ`` — the compressed-communication aggregate."""
    K, n = stacked.shape
    if n == 0:
        return p
    has_extra = extra is not None
    rows_p, n_blocks = _grid_rows(n, block_rows, interpret)
    br = rows_p // n_blocks
    blk = pl.BlockSpec((br, LANES), lambda i, sc: (i, 0))
    operands = [_pad_rows(stacked, rows_p), _pad_rows(p, rows_p)]
    if has_extra:
        operands.append(_pad_rows(extra, rows_p))
    outs = pl.pallas_call(
        functools.partial(_weighted_delta_kernel, K=K, has_extra=has_extra,
                          deltas=deltas),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(n_blocks,),
            in_specs=[pl.BlockSpec((K, br, LANES), lambda i, sc: (0, i, 0))] +
                     [blk] * (len(operands) - 1),
            out_specs=blk,
        ),
        out_shape=jax.ShapeDtypeStruct((rows_p, LANES), p.dtype),
        interpret=interpret,
    )(weights.astype(jnp.float32), *operands)
    return outs.reshape(-1)[:n]


# ---------------------------------------------------------------------------
# compressed-communication client upload: top-k mask + blockwise quantize
# ---------------------------------------------------------------------------

# blockwise-symmetric quantization constants.  Wire scales are bf16 (the
# 2-byte-per-128-lane-block format the payload accounting assumes); the
# f32 scale is nudged UP by SCALE_PAD before the bf16 round-to-nearest
# so the stored scale is always ≥ amax/qmax — quantized magnitudes then
# never exceed qmax (no clipping distortion) and the per-element error
# stays ≤ scale/2 for the WIRE scale.  bf16's 8 mantissa-free relative
# step is 2⁻⁸; 1 + 2⁻⁶ dominates it with margin.
QMAX = {8: 127.0, 16: 32767.0}
SCALE_PAD = 1.0 + 2.0 ** -6


def _compress_delta_kernel(sc_ref, *refs, bits: int, topk: bool,
                           with_residual: bool):
    it = iter(refs)
    d_ref = next(it)
    o_ref = next(it)
    r_ref = next(it) if with_residual else None
    d0 = d_ref[...].astype(jnp.float32)
    d = d0
    if topk:
        tau = sc_ref[0]
        d = jnp.where(jnp.abs(d) >= tau, d, 0.0)
    if bits != 32:
        qmax = QMAX[bits]
        amax = jnp.max(jnp.abs(d), axis=-1, keepdims=True)
        scale = (amax / qmax) * SCALE_PAD
        scale = scale.astype(jnp.bfloat16).astype(jnp.float32)
        q = jnp.where(scale > 0.0, d / jnp.where(scale > 0.0, scale, 1.0),
                      0.0)
        q = jnp.clip(jnp.round(q), -qmax, qmax)
        c = q * scale
    else:
        c = d
    o_ref[...] = c.astype(o_ref.dtype)
    if with_residual:
        r_ref[...] = (d0 - c).astype(r_ref.dtype)


def compress_delta(d: jnp.ndarray, thresh, *, bits: int = 32,
                   topk: bool = False, with_residual: bool = False,
                   block_rows: int = DEFAULT_BLOCK_ROWS,
                   interpret: bool = False):
    """Fake-quantized compressed form of one client's f32 flat delta —
    exactly the values a decompressed wire payload would carry, in ONE
    blocked pass:

      1. ``topk`` (static): magnitude sparsification ``d ← d·[|d| ≥ τ]``
         with the traced threshold ``τ = thresh`` (the caller's k-th
         largest |d|; ties at τ are kept, matching the threshold
         semantics of the NumPy oracle).
      2. ``bits ∈ {8, 16}`` (static): blockwise symmetric quantization —
         per 128-lane row, ``scale = bf16((amax/qmax)·(1+2⁻⁶))`` and
         ``c = round(d/scale)·scale`` (round half-even, clip ±qmax);
         all-zero rows keep scale 0 and emit zeros.  ``bits=32`` skips
         quantization statically.

    Returns ``c`` (f32, same length), plus the error-feedback residual
    ``r = d − c`` (f32) when ``with_residual`` — computed against the
    ORIGINAL delta, so sparsified-away mass lands in the residual.  Pad
    lanes are zero in, zero out: zero rows quantize to zero and zero
    elements always survive the ≥-threshold mask as zeros."""
    n = d.shape[-1]
    if bits not in (8, 16, 32):
        raise ValueError(f"compress_delta bits must be 8|16|32, got {bits}")
    if n == 0:
        out = d.astype(jnp.float32)
        return (out, jnp.zeros_like(out)) if with_residual else out
    rows_p, n_blocks = _grid_rows(n, block_rows, interpret)
    br = rows_p // n_blocks
    blk = pl.BlockSpec((br, LANES), lambda i, sc: (i, 0))
    out_shape = [jax.ShapeDtypeStruct((rows_p, LANES), jnp.float32)]
    if with_residual:
        out_shape.append(jax.ShapeDtypeStruct((rows_p, LANES), jnp.float32))
    outs = pl.pallas_call(
        functools.partial(_compress_delta_kernel, bits=bits, topk=topk,
                          with_residual=with_residual),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(n_blocks,),
            in_specs=[blk],
            out_specs=[blk] * len(out_shape),
        ),
        out_shape=out_shape,
        interpret=interpret,
    )(jnp.asarray(thresh, jnp.float32).reshape(1), _pad_rows(d, rows_p))
    c = outs[0].reshape(-1)[:n]
    if with_residual:
        return c, outs[1].reshape(-1)[:n]
    return c


# ---------------------------------------------------------------------------
# DP clip + noise — the privacy form of one client's upload
# ---------------------------------------------------------------------------

def _dp_clip_noise_kernel(sc_ref, *refs, has_z: bool):
    it = iter(refs)
    d_ref = next(it)
    z_ref = next(it) if has_z else None
    o_ref = next(it)
    u = sc_ref[0] * d_ref[...].astype(jnp.float32)
    if has_z:
        u = u + sc_ref[1] * z_ref[...]
    o_ref[...] = u.astype(o_ref.dtype)


def dp_clip_noise(d: jnp.ndarray, z: Optional[jnp.ndarray],
                  clip_scale, noise_scale, *,
                  block_rows: int = DEFAULT_BLOCK_ROWS,
                  interpret: bool = False) -> jnp.ndarray:
    """One client's DP upload in ONE blocked pass:
    ``u = clip_scale·d₃₂ (+ noise_scale·z)`` returned as f32.

    ``clip_scale`` is the traced ``min(1, C/(‖d‖+ε))`` factor that clips
    the delta to the sensitivity bound C, and ``noise_scale`` the
    calibrated ``σ·C`` Gaussian multiplier for the standard-normal f32
    buffer ``z`` (``z=None`` statically drops the noise term — pure
    clipping costs the same single pass).  Pad lanes stay zero: both
    terms are multiplicative in zero-padded operands."""
    n = d.shape[-1]
    has_z = z is not None
    if n == 0:
        return d.astype(jnp.float32)
    rows_p, n_blocks = _grid_rows(n, block_rows, interpret)
    br = rows_p // n_blocks
    blk = pl.BlockSpec((br, LANES), lambda i, sc: (i, 0))
    operands = [_pad_rows(d, rows_p)]
    if has_z:
        operands.append(_pad_rows(z, rows_p))
    scalars = jnp.stack([jnp.asarray(clip_scale, jnp.float32),
                         jnp.asarray(noise_scale, jnp.float32)])
    out = pl.pallas_call(
        functools.partial(_dp_clip_noise_kernel, has_z=has_z),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(n_blocks,),
            in_specs=[blk] * len(operands),
            out_specs=blk,
        ),
        out_shape=jax.ShapeDtypeStruct((rows_p, LANES), jnp.float32),
        interpret=interpret,
    )(scalars, *operands)
    return out.reshape(-1)[:n]


# ---------------------------------------------------------------------------
# sequential delta accumulation (pod backend, one client per call)
# ---------------------------------------------------------------------------

def _delta_accum_kernel(sc_ref, d_ref, w_ref, p_ref, o_ref):
    coeff = sc_ref[0]
    o_ref[...] = d_ref[...] + coeff * (
        w_ref[...].astype(jnp.float32) - p_ref[...].astype(jnp.float32))


def _weighted_accum_kernel(sc_ref, d_ref, w_ref, o_ref):
    coeff = sc_ref[0]
    o_ref[...] = d_ref[...] + coeff * w_ref[...].astype(jnp.float32)


def delta_accum(delta: jnp.ndarray, w_end: jnp.ndarray,
                p: Optional[jnp.ndarray], coeff, *,
                block_rows: int = DEFAULT_BLOCK_ROWS,
                interpret: bool = False) -> jnp.ndarray:
    """``delta + coeff·(w_end₃₂ − p₃₂)`` — one client's contribution to
    the running f32 weighted-delta sum (the pod FedAvg all-reduce).

    ``p=None`` is the ACCUM-ONLY form ``delta + coeff·w_end₃₂``: the
    hierarchical psum path keeps its per-lane partials p-free (the
    ``−(Σcoeff)·p`` term factors out of the lane sums and is applied
    once after the cross-pod combine), so the lane accumulator never
    needs the params resident per lane."""
    n = delta.shape[-1]
    if n == 0:
        return delta
    rows_p, n_blocks = _grid_rows(n, block_rows, interpret)
    br = rows_p // n_blocks
    blk = pl.BlockSpec((br, LANES), lambda i, sc: (i, 0))
    kernel = _delta_accum_kernel if p is not None else _weighted_accum_kernel
    operands = [_pad_rows(delta, rows_p), _pad_rows(w_end, rows_p)]
    if p is not None:
        operands.append(_pad_rows(p, rows_p))
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(n_blocks,),
            in_specs=[blk] * len(operands),
            out_specs=blk,
        ),
        out_shape=jax.ShapeDtypeStruct((rows_p, LANES), jnp.float32),
        interpret=interpret,
    )(jnp.asarray(coeff, jnp.float32).reshape(1), *operands)
    return out.reshape(-1)[:n]


# ---------------------------------------------------------------------------
# server update (apply delta + FedAvgM / FedAdam moments)
# ---------------------------------------------------------------------------

def _server_update_kernel(sc_ref, *refs, opt: str, beta: float, b1: float,
                          b2: float, eps: float):
    lr = sc_ref[0]
    it = iter(refs)
    p_ref, d_ref = next(it), next(it)
    p = p_ref[...].astype(jnp.float32)
    d = d_ref[...]
    if opt == "none":
        next(it)[...] = (p + d).astype(p_ref.dtype)
        return
    g = -d                                   # pseudo-gradient w − w_avg
    if opt == "momentum":
        m_ref = next(it)
        p_out, m_out = next(it), next(it)
        m = beta * m_ref[...].astype(jnp.float32) + g
        m_out[...] = m.astype(m_out.dtype)
        p_out[...] = (p - lr * m).astype(p_out.dtype)
        return
    # adam — bias corrections arrive precomputed as scalars
    bc1, bc2 = sc_ref[1], sc_ref[2]
    mu_ref, nu_ref = next(it), next(it)
    p_out, mu_out, nu_out = next(it), next(it), next(it)
    mu = b1 * mu_ref[...].astype(jnp.float32) + (1.0 - b1) * g
    nu = b2 * nu_ref[...].astype(jnp.float32) + (1.0 - b2) * g * g
    mu_out[...] = mu.astype(mu_out.dtype)
    nu_out[...] = nu.astype(nu_out.dtype)
    u = (mu / bc1) / (jnp.sqrt(nu / bc2) + eps)
    p_out[...] = (p - lr * u).astype(p_out.dtype)


def server_update(p: jnp.ndarray, delta: jnp.ndarray,
                  moments: Tuple[jnp.ndarray, ...], scalars, *,
                  opt: str = "none", beta: float = 0.9, b1: float = 0.9,
                  b2: float = 0.99, eps: float = 1e-8,
                  block_rows: int = DEFAULT_BLOCK_ROWS,
                  interpret: bool = False):
    """Apply the aggregated f32 ``delta`` to ``p`` under a server
    optimizer.  ``moments`` is () for "none", (m,) for "momentum",
    (mu, nu) for "adam"; ``scalars`` is (lr,) or (lr, bc1, bc2) for adam
    (bias corrections 1−b1^t, 1−b2^t computed by the caller, where the
    step count lives).  Returns ``(p_new, new_moments)``.
    """
    if opt not in ("none", "momentum", "adam"):
        raise ValueError(f"unknown server opt {opt!r}")
    n = p.shape[-1]
    if n == 0:
        return p, tuple(moments)
    rows_p, n_blocks = _grid_rows(n, block_rows, interpret)
    br = rows_p // n_blocks
    blk = pl.BlockSpec((br, LANES), lambda i, sc: (i, 0))
    operands = [_pad_rows(p, rows_p), _pad_rows(delta, rows_p)] + \
        [_pad_rows(m, rows_p) for m in moments]
    out_shape = [jax.ShapeDtypeStruct((rows_p, LANES), p.dtype)] + \
        [jax.ShapeDtypeStruct((rows_p, LANES), m.dtype) for m in moments]
    sc = jnp.stack([jnp.asarray(s, jnp.float32) for s in scalars])
    outs = pl.pallas_call(
        functools.partial(_server_update_kernel, opt=opt, beta=float(beta),
                          b1=float(b1), b2=float(b2), eps=float(eps)),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(n_blocks,),
            in_specs=[blk] * len(operands),
            out_specs=[blk] * len(out_shape),
        ),
        out_shape=out_shape,
        interpret=interpret,
    )(sc, *operands)
    p_new = outs[0].reshape(-1)[:n]
    new_moments = tuple(o.reshape(-1)[:n] for o in outs[1:])
    return p_new, new_moments
