"""P1 → P2 switch policies (RQ3).

The paper shows (Fig 5/6) that final accuracy vs P1 duration is a
rise-then-slow-descent curve: too little cyclic training forfeits the
flat-basin benefit, too much wastes rounds that plain FL would use
better.  Policies below encode the practical answers:

  FixedRounds     — the paper's protocol (T_cyc = 100).
  AccuracyPlateau — switch when the P1 eval accuracy stops improving by
                    ``min_delta`` over a ``patience`` window; adaptive
                    version of the Fig-6 knee.
  BudgetFraction  — spend a fixed fraction of the total round budget in
                    P1 (the efficiency-first operating point).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Protocol


class SwitchPolicy(Protocol):
    def should_switch(self, rnd: int, history: List[Dict[str, float]]) -> bool:
        ...


@dataclasses.dataclass(frozen=True)
class FixedRounds:
    t_cyc: int = 100

    def should_switch(self, rnd: int, history) -> bool:
        return rnd + 1 >= self.t_cyc


@dataclasses.dataclass(frozen=True)
class AccuracyPlateau:
    """Switch once eval accuracy improves < ``min_delta`` for ``patience``
    consecutive evaluations (only rows containing 'acc' are counted)."""
    patience: int = 3
    min_delta: float = 0.002
    min_rounds: int = 10

    def should_switch(self, rnd: int, history) -> bool:
        if rnd + 1 < self.min_rounds:
            return False
        accs = [h["acc"] for h in history if "acc" in h]
        if len(accs) < self.patience + 1:
            return False
        recent = accs[-(self.patience + 1):]
        best_before = max(accs[:-self.patience])
        return all(a - best_before < self.min_delta for a in recent[1:])


@dataclasses.dataclass(frozen=True)
class BudgetFraction:
    total_rounds: int
    fraction: float = 0.1

    def should_switch(self, rnd: int, history) -> bool:
        return rnd + 1 >= max(1, int(self.total_rounds * self.fraction))
