"""Communication accounting — Table IV, as executable closed forms plus a
runtime ledger the simulator feeds; tests assert ledger == closed form.

Notation (paper §IV): X = model capacity (bytes), T_cyc / T_res = rounds
in P1 / P2, K_P1 / K_P2 = clients per round in P1 / P2.

Closed forms (Table IV):
    FedAvg/FedProx/Moon  w/o cyclic : 2·K_P2·T_tot·X
    SCAFFOLD             w/o cyclic : 4·K_P2·T_tot·X
    FedAvg/FedProx/Moon  w/ cyclic  : 2·[K_P1·T_cyc + K_P2·T_res]·X
    SCAFFOLD             w/ cyclic  : 2·[K_P1·T_cyc + 2·K_P2·T_res]·X

P1 is a relay: each participating client downloads the model and uploads
it once ⇒ 2·K_P1·X per round, same per-round cost shape as FedAvg but
with K_P1 clients.  SCAFFOLD doubles P2 payload (control variates ride
along both directions).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

from repro.utils import tree_math as tm

Pytree = Any

_PER_ROUND_FACTOR = {"fedavg": 2, "fedprox": 2, "moon": 2, "scaffold": 4}

# secure-aggregation key-agreement payload: one shared seed per ordered
# client pair per round (Bonawitz-style pairwise masking; the masks
# themselves are derived locally and add zero wire bytes)
SEED_BYTES = 32


def model_bytes(params: Pytree) -> int:
    """X — the model capacity in bytes."""
    return tm.size_bytes(params)


def secure_agg_mask_bytes(k: int) -> int:
    """Per-round secure-agg overhead: each of the K clients exchanges a
    SEED_BYTES seed with each of the other K−1 — the model payload is
    unchanged (masks are the same shape as the upload they hide in)."""
    return k * (k - 1) * SEED_BYTES


def overhead_without_cyclic(algorithm: str, k_p2: int, t_tot: int, x_bytes: int) -> int:
    return _PER_ROUND_FACTOR[algorithm] * k_p2 * t_tot * x_bytes


def overhead_with_cyclic(algorithm: str, k_p1: int, t_cyc: int,
                         k_p2: int, t_res: int, x_bytes: int) -> int:
    p2_factor = _PER_ROUND_FACTOR[algorithm]
    return 2 * k_p1 * t_cyc * x_bytes + p2_factor * k_p2 * t_res * x_bytes


def compressed_round_bytes(algorithm: str, k_p2: int, x_bytes: int,
                           payload_bytes: int) -> int:
    """One compressed P2 round: each of the K clients downloads the full
    model (X) and uploads the compressed payload, once per leg pair —
    the closed form ``table4_comm.py``'s compression column checks the
    ledger against."""
    legs = _PER_ROUND_FACTOR[algorithm] // 2
    return k_p2 * legs * (x_bytes + payload_bytes)


def rounds_budget_equivalent(algorithm: str, k_p1: int, t_cyc: int,
                             k_p2: int, x_bytes: int) -> float:
    """How many P2 rounds the P1 phase costs — converts the paper's
    convergence-speedup (rounds-to-accuracy) into a comm-fair comparison."""
    p1 = 2 * k_p1 * t_cyc * x_bytes
    per_p2_round = _PER_ROUND_FACTOR[algorithm] * k_p2 * x_bytes
    return p1 / per_p2_round


@dataclasses.dataclass
class CommLedger:
    """Runtime byte counter incremented by the P1/P2 drivers.

    Capacity is recomputed PER RECORD (or taken from the explicit
    ``x_bytes`` override the engine passes) — P1 relay and compressed P2
    payloads legitimately differ, so nothing may latch the first call's
    bytes forever.  ``model_bytes`` in :meth:`summary` reports the
    first-seen capacity separately, as the X the closed forms use.

    Compressed communication (repro.fl.compression) threads
    ``payload_bytes`` — the wire bytes of ONE client's compressed
    upload — into :meth:`record_round`: the download legs still ship the
    full model (clients need exact params to train on), so a round costs
    ``K · legs · (X + payload)`` with ``legs = factor/2`` up/down leg
    pairs per client (SCAFFOLD's control variates double both
    directions).  ``payload_ratio`` in the summary is the UPLOAD-side
    reduction — full upload bytes over actual — which is the axis
    compression acts on (1.0 when nothing was compressed).
    """
    p1_bytes: int = 0
    p2_bytes: int = 0
    p1_rounds: int = 0
    p2_rounds: int = 0
    mask_bytes: int = 0         # secure-agg pairwise seed exchanges
    p2_upload_bytes: int = 0        # actual up-leg bytes
    p2_upload_full_bytes: int = 0   # up-leg bytes had nothing compressed
    _x_bytes: Optional[int] = None  # first-seen capacity (reporting only)

    @property
    def total_bytes(self) -> int:
        return self.p1_bytes + self.p2_bytes + self.mask_bytes

    @property
    def payload_ratio(self) -> float:
        """Upload-side compression factor: full / actual up-leg bytes."""
        if not self.p2_upload_bytes:
            return 1.0
        return self.p2_upload_full_bytes / self.p2_upload_bytes

    def record_cyclic_round(self, k_p1: int, params: Pytree, *,
                            x_bytes: Optional[int] = None) -> None:
        x = self._capacity(params, x_bytes)
        self.p1_bytes += 2 * k_p1 * x       # download + upload per client
        self.p1_rounds += 1

    def record_round(self, algorithm: str, k_p2: int, params: Pytree, *,
                     secure_agg: bool = False,
                     x_bytes: Optional[int] = None,
                     payload_bytes: Optional[int] = None) -> None:
        x = self._capacity(params, x_bytes)
        legs = _PER_ROUND_FACTOR[algorithm] // 2    # down/up pairs
        up = x if payload_bytes is None else int(payload_bytes)
        self.p2_bytes += k_p2 * legs * (x + up)
        self.p2_upload_bytes += k_p2 * legs * up
        self.p2_upload_full_bytes += k_p2 * legs * x
        self.p2_rounds += 1
        if secure_agg:
            self.mask_bytes += secure_agg_mask_bytes(k_p2)

    def _capacity(self, params: Pytree,
                  x_bytes: Optional[int] = None) -> int:
        x = int(x_bytes) if x_bytes is not None else model_bytes(params)
        if self._x_bytes is None:
            self._x_bytes = x           # first-seen, for reporting only
        return x

    def summary(self) -> Dict[str, float]:
        return {
            "p1_rounds": self.p1_rounds, "p2_rounds": self.p2_rounds,
            "p1_bytes": self.p1_bytes, "p2_bytes": self.p2_bytes,
            "mask_bytes": self.mask_bytes,
            "total_bytes": self.total_bytes,
            "model_bytes": self._x_bytes or 0,
            "p2_upload_bytes": self.p2_upload_bytes,
            "payload_ratio": self.payload_ratio,
        }
