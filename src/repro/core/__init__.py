"""CyclicFL — the paper's primary contribution.

P1 cyclic pre-training (Algorithm 1), P1→P2 switch policies, Table-IV
communication accounting, loss-landscape diagnostics, and the Cyclic+Y
pipeline that composes with every FL algorithm in repro.fl.
"""
from repro.core.cyclic import CyclicConfig, CyclicResult, cyclic_pretrain
from repro.core.switch import FixedRounds, AccuracyPlateau, BudgetFraction
from repro.core.comm_accounting import (
    CommLedger,
    model_bytes,
    overhead_with_cyclic,
    overhead_without_cyclic,
    rounds_budget_equivalent,
)
from repro.core.diagnostics import (
    sharpness_probe,
    hessian_top_eig,
    landscape_slice,
    client_similarity,
    make_batch_loss,
)
from repro.core.pipeline import (
    Phase,
    PhaseResult,
    PipelineResult,
    ScheduleResult,
    run_cyclic_then_federated,
    run_phase_schedule,
)
