"""CyclicFL — Algorithm 1: cyclic model pre-training (phase P1).

The server relays ONE model through a randomly-sampled group of clients
*sequentially* each round:

    w ← random init
    for t in 1..T_cyc:
        S_t ← RandomSample(clients, K_P1)
        for i in S_t (in order):          # strict sequential relay
            w ← LocalSGD(w, D_i, t_i steps)
    return w                              # well-initialized global model

Unlike FedAvg there is NO aggregation — the sequential pass approximates
centralized SGD over the union of client data (Corollary 1: SGD over a
task sequence approaches OGD — hence centralized training — as client
data distributions overlap), landing the model in a flat loss basin
(Lemma 2) that stabilizes the downstream FL phase.

Implementation: one round = one XLA program.  The selected clients'
shards are stacked (K, n, ...) and the relay is a ``lax.scan`` over the
client axis carrying the model; each scan step runs the client's
``t_i``-step local SGD (itself a nested scan).  On a pod this scan is the
sequential schedule whose per-step body is fully model-parallel — see
repro/launch/train.py.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.federated import FederatedDataset
from repro.fl.local import LocalSpec, make_local_fn
from repro.fl.simulation import make_eval_fn
from repro.fl.task import Task

Pytree = Any


@dataclasses.dataclass(frozen=True)
class CyclicConfig:
    rounds: int = 100               # T_cyc
    participation: float = 0.25     # K_P1 / |S|  (paper default: 25%)
    local_steps: int = 20           # t_i — max local update steps (paper: 20)
    batch_size: int = 32
    lr: float = 0.01
    momentum: float = 0.0
    weight_decay: float = 0.0
    lr_decay: float = 0.998
    grad_clip: Optional[float] = None
    eval_every: int = 10
    eval_batch: int = 256
    seed: int = 0

    def n_selected(self, n_clients: int) -> int:
        return max(1, int(round(self.participation * n_clients)))

    def local_spec(self) -> LocalSpec:
        return LocalSpec(
            n_steps=self.local_steps, batch_size=self.batch_size, lr=self.lr,
            momentum=self.momentum, weight_decay=self.weight_decay,
            variant="plain", grad_clip=self.grad_clip)


def make_cyclic_round_fn(task: Task, cfg: CyclicConfig) -> Callable:
    """One P1 round: sequential relay over the K selected clients."""
    local = make_local_fn(task, cfg.local_spec())

    @jax.jit
    def round_fn(key, params, x_all, y_all, ids, lr_scale):
        cx = x_all[ids]                       # (K, n, ...)
        cy = y_all[ids]
        keys = jax.random.split(key, ids.shape[0])

        def relay(w, inp):
            k, cxi, cyi = inp
            w_next, aux = local(k, w, {}, cxi, cyi, lr_scale)
            return w_next, aux["loss"]

        params, losses = jax.lax.scan(relay, params, (keys, cx, cy))
        return params, {"local_loss": jnp.mean(losses)}

    return round_fn


@dataclasses.dataclass
class CyclicResult:
    params: Pytree
    history: List[Dict[str, float]]


def cyclic_pretrain(task: Task, data: FederatedDataset, cfg: CyclicConfig,
                    init_params: Optional[Pytree] = None,
                    ledger=None, verbose: bool = False,
                    eval_fn: Optional[Callable] = None,
                    switch_policy=None) -> CyclicResult:
    """Run P1 and return the well-initialized global model w_wg.

    ``switch_policy`` (core.switch) may terminate P1 early based on the
    evaluation history — the RQ3 trade-off knob.
    """
    rng = np.random.default_rng(cfg.seed + 31)
    key = jax.random.PRNGKey(cfg.seed)
    params = init_params if init_params is not None else task.init(key)

    round_fn = make_cyclic_round_fn(task, cfg)
    evaluate = eval_fn or make_eval_fn(task, cfg.eval_batch)
    x_all, y_all, _ = data.device_arrays()
    K = cfg.n_selected(data.n_clients)

    history: List[Dict[str, float]] = []
    for rnd in range(cfg.rounds):
        ids = jnp.asarray(rng.choice(data.n_clients, size=K, replace=False))
        lr_scale = jnp.asarray(cfg.lr_decay ** rnd, jnp.float32)
        key, rk = jax.random.split(key)
        params, metrics = round_fn(rk, params, x_all, y_all, ids, lr_scale)
        if ledger is not None:
            ledger.record_cyclic_round(K, params)
        row = {"round": rnd, "local_loss": float(metrics["local_loss"]),
               "phase": "P1"}
        if (rnd + 1) % cfg.eval_every == 0 or rnd == cfg.rounds - 1:
            row["acc"] = evaluate(params, data.test_x, data.test_y)
            if verbose:
                print(f"[cyclic] round {rnd + 1}/{cfg.rounds} "
                      f"loss={row['local_loss']:.4f} acc={row['acc']:.4f}",
                      flush=True)
        history.append(row)
        if switch_policy is not None and switch_policy.should_switch(rnd, history):
            break
    return CyclicResult(params=params, history=history)
