"""CyclicFL — Algorithm 1: cyclic model pre-training (phase P1).

The server relays ONE model through a randomly-sampled group of clients
*sequentially* each round:

    w ← random init
    for t in 1..T_cyc:
        S_t ← RandomSample(clients, K_P1)
        for i in S_t (in order):          # strict sequential relay
            w ← LocalSGD(w, D_i, t_i steps)
    return w                              # well-initialized global model

Unlike FedAvg there is NO aggregation — the sequential pass approximates
centralized SGD over the union of client data (Corollary 1: SGD over a
task sequence approaches OGD — hence centralized training — as client
data distributions overlap), landing the model in a flat loss basin
(Lemma 2) that stabilizes the downstream FL phase.

Implementation: this module is a thin configuration shim over the shared
round engine (repro.fl.engine).  One P1 round = one ``lax.scan`` step
over the selected-client axis carrying the model (RelayStrategy); the
engine dispatches ``chunk_size`` rounds per XLA program and samples
clients on device by default (``sampling="host"`` reproduces the
original host-RNG stream).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional

import jax

from repro.data.federated import FederatedDataset
from repro.fl.engine import RelayStrategy, RoundSchedule, run_rounds
from repro.fl.local import LocalSpec
from repro.fl.task import Task

Pytree = Any

# the seed driver drew P1 client ids from np.random.default_rng(seed + 31);
# sampling="host" keeps that stream for backward-compatible runs
HOST_RNG_OFFSET_P1 = 31


@dataclasses.dataclass(frozen=True)
class CyclicConfig:
    rounds: int = 100               # T_cyc
    participation: float = 0.25     # K_P1 / |S|  (paper default: 25%)
    local_steps: int = 20           # t_i — max local update steps (paper: 20)
    batch_size: int = 32
    lr: float = 0.01
    momentum: float = 0.0
    weight_decay: float = 0.0
    lr_decay: float = 0.998
    grad_clip: Optional[float] = None
    eval_every: int = 10
    eval_batch: int = 256
    seed: int = 0
    chunk_size: int = 8             # rounds per XLA dispatch (engine)
    sampling: str = "device"        # device | host (seed-compatible)
    update_impl: str = "tree"       # tree | fused | fused_interpret

    def __post_init__(self):
        from repro.fl.local import validate_update_impl
        validate_update_impl(self.update_impl)

    def n_selected(self, n_clients: int) -> int:
        return max(1, int(round(self.participation * n_clients)))

    def local_spec(self) -> LocalSpec:
        return LocalSpec(
            n_steps=self.local_steps, batch_size=self.batch_size, lr=self.lr,
            momentum=self.momentum, weight_decay=self.weight_decay,
            variant="plain", grad_clip=self.grad_clip,
            update_impl=self.update_impl)

    def strategy(self) -> RelayStrategy:
        return RelayStrategy(spec=self.local_spec(),
                             participation=self.participation)

    def schedule(self) -> RoundSchedule:
        return RoundSchedule(
            rounds=self.rounds, lr_decay=self.lr_decay,
            eval_every=self.eval_every, eval_batch=self.eval_batch,
            seed=self.seed, chunk_size=self.chunk_size,
            sampling=self.sampling, host_rng_offset=HOST_RNG_OFFSET_P1)


def make_cyclic_round_fn(task: Task, cfg: CyclicConfig) -> Callable:
    """One P1 round: sequential relay over the K selected clients.

    Kept for diagnostics/tests that drive a single round directly; the
    training loop itself lives in repro.fl.engine.  The params contract
    is TREES regardless of ``update_impl`` — on the fused path this
    shim packs/unpacks at the boundary (the engine proper carries flat
    buffers end to end instead).
    """
    strategy = cfg.strategy()
    body = strategy.build_round(task)
    fops = strategy.flat_ops(task)

    @jax.jit
    def round_fn(key, params, x_all, y_all, ids, lr_scale):
        if fops is not None:
            params = fops.flatten(params)
        params, _, loss = body(key, params, x_all, y_all, ids,
                               None, lr_scale, {})
        if fops is not None:
            params = fops.unflatten(params)
        return params, {"local_loss": loss}

    return round_fn


@dataclasses.dataclass
class CyclicResult:
    params: Pytree
    history: List[Dict[str, float]]
    dispatches: int = 0             # chunk-program invocations (engine)


def cyclic_pretrain(task: Task, data: FederatedDataset, cfg: CyclicConfig,
                    init_params: Optional[Pytree] = None,
                    ledger=None, verbose: bool = False,
                    eval_fn: Optional[Callable] = None,
                    switch_policy=None, phase: str = "P1") -> CyclicResult:
    """Run P1 and return the well-initialized global model w_wg.

    ``switch_policy`` (core.switch) may terminate P1 early based on the
    evaluation history — the RQ3 trade-off knob.
    """
    res = run_rounds(task, data, cfg.strategy(), cfg.schedule(),
                     init_params=init_params, ledger=ledger, verbose=verbose,
                     eval_fn=eval_fn, switch_policy=switch_policy,
                     phase=phase, label="cyclic")
    return CyclicResult(params=res.params, history=res.history,
                        dispatches=res.dispatches)
