"""Cyclic+Y — the end-to-end CyclicFL pipeline (P1 then P2).

This is the paper's headline configuration: run cyclic pre-training for
T_cyc rounds, hand the well-initialized model to any FL algorithm Y ∈
{FedAvg, FedProx, SCAFFOLD, Moon}, and keep a communication ledger so
the Table-IV accounting is measured, not asserted.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.core.comm_accounting import CommLedger
from repro.core.cyclic import CyclicConfig, CyclicResult, cyclic_pretrain
from repro.data.federated import FederatedDataset
from repro.fl.simulation import FLConfig, FLResult, run_federated
from repro.fl.task import Task


@dataclasses.dataclass
class PipelineResult:
    cyclic: Optional[CyclicResult]
    federated: FLResult
    ledger: CommLedger

    @property
    def history(self) -> List[Dict[str, float]]:
        hist = list(self.cyclic.history) if self.cyclic else []
        offset = len(hist)
        for h in self.federated.history:
            row = dict(h)
            row["round"] = offset + h["round"]
            hist.append(row)
        return hist

    def best_acc(self) -> Dict[str, float]:
        rows = [h for h in self.history if "acc" in h]
        return max(rows, key=lambda h: h["acc"]) if rows else {}

    def rounds_to_acc(self, target: float) -> Optional[int]:
        """First (global) round reaching ``target`` accuracy — the paper's
        convergence metric (Table III)."""
        for h in self.history:
            if h.get("acc", -1.0) >= target:
                return h["round"]
        return None


def run_cyclic_then_federated(
    task: Task,
    data: FederatedDataset,
    cyclic_cfg: Optional[CyclicConfig],
    fl_cfg: FLConfig,
    verbose: bool = False,
    switch_policy=None,
) -> PipelineResult:
    """cyclic_cfg=None runs the w/o-Cyclic baseline under the same ledger."""
    ledger = CommLedger()
    cyc = None
    init_params = None
    if cyclic_cfg is not None:
        cyc = cyclic_pretrain(task, data, cyclic_cfg, ledger=ledger,
                              verbose=verbose, switch_policy=switch_policy)
        init_params = cyc.params
    fed = run_federated(task, data, fl_cfg, init_params=init_params,
                        ledger=ledger, verbose=verbose)
    return PipelineResult(cyclic=cyc, federated=fed, ledger=ledger)
