"""Cyclic+Y — the end-to-end CyclicFL pipeline as a declarative phase
schedule.

The paper's headline configuration is two phases — P1 cyclic
pre-training, then any FL algorithm Y ∈ {FedAvg, FedProx, SCAFFOLD,
Moon} — but with the shared round engine (repro.fl.engine) a phase is
just (strategy config, optional switch policy), so arbitrary schedules
compose: multi-cycle P1↔P2 alternation, relay warm restarts between
algorithms, adaptive-initialization sweeps.  ``run_phase_schedule``
threads the model and one CommLedger through every phase so the
Table-IV accounting is measured, not asserted; switch policies
(core.switch) apply at ANY phase boundary, not just P1→P2.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Union

from repro.core.comm_accounting import CommLedger
from repro.core.cyclic import CyclicConfig, CyclicResult, cyclic_pretrain
from repro.data.federated import FederatedDataset
from repro.fl.simulation import FLConfig, FLResult, run_federated
from repro.fl.task import Task


@dataclasses.dataclass(frozen=True)
class Phase:
    """One schedule entry.  ``cfg`` decides the strategy: a CyclicConfig
    runs the P1 relay, an FLConfig runs aggregation rounds.  The phase
    ``name`` tags the history rows; ``switch_policy`` may end the phase
    early (the engine then advances to the next phase)."""
    name: str
    cfg: Union[CyclicConfig, FLConfig]
    switch_policy: Optional[object] = None

    @property
    def kind(self) -> str:
        return "relay" if isinstance(self.cfg, CyclicConfig) else "aggregate"


@dataclasses.dataclass
class PhaseResult:
    phase: Phase
    result: Union[CyclicResult, FLResult]

    @property
    def history(self) -> List[Dict[str, float]]:
        return self.result.history


@dataclasses.dataclass
class ScheduleResult:
    phases: List[PhaseResult]
    ledger: CommLedger

    @property
    def params(self):
        return self.phases[-1].result.params

    @property
    def history(self) -> List[Dict[str, float]]:
        """All phases' rows with a schedule-global round index."""
        hist: List[Dict[str, float]] = []
        for pr in self.phases:
            offset = len(hist)
            for h in pr.history:
                row = dict(h)
                row["round"] = offset + h["round"]
                hist.append(row)
        return hist

    def best_acc(self) -> Dict[str, float]:
        rows = [h for h in self.history if "acc" in h]
        return max(rows, key=lambda h: h["acc"]) if rows else {}

    def rounds_to_acc(self, target: float) -> Optional[int]:
        """First (global) round reaching ``target`` accuracy — the paper's
        convergence metric (Table III)."""
        for h in self.history:
            if h.get("acc", -1.0) >= target:
                return h["round"]
        return None


def run_phase_schedule(task: Task, data: FederatedDataset,
                       phases: Sequence[Phase],
                       verbose: bool = False,
                       ledger: Optional[CommLedger] = None) -> ScheduleResult:
    """Run ``phases`` in order, each starting from the previous phase's
    final params, under one communication ledger."""
    ledger = ledger if ledger is not None else CommLedger()
    params = None
    results: List[PhaseResult] = []
    for ph in phases:
        if ph.kind == "relay":
            res = cyclic_pretrain(task, data, ph.cfg, init_params=params,
                                  ledger=ledger, verbose=verbose,
                                  switch_policy=ph.switch_policy,
                                  phase=ph.name)
        else:
            res = run_federated(task, data, ph.cfg, init_params=params,
                                ledger=ledger, verbose=verbose,
                                switch_policy=ph.switch_policy,
                                phase=ph.name)
        params = res.params
        results.append(PhaseResult(phase=ph, result=res))
    return ScheduleResult(phases=results, ledger=ledger)


# ---------------------------------------------------------------------------
# the paper's two-phase pipeline, expressed as a schedule
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PipelineResult:
    cyclic: Optional[CyclicResult]
    federated: FLResult
    ledger: CommLedger

    @property
    def history(self) -> List[Dict[str, float]]:
        hist = list(self.cyclic.history) if self.cyclic else []
        offset = len(hist)
        for h in self.federated.history:
            row = dict(h)
            row["round"] = offset + h["round"]
            hist.append(row)
        return hist

    def best_acc(self) -> Dict[str, float]:
        rows = [h for h in self.history if "acc" in h]
        return max(rows, key=lambda h: h["acc"]) if rows else {}

    def rounds_to_acc(self, target: float) -> Optional[int]:
        """First (global) round reaching ``target`` accuracy — the paper's
        convergence metric (Table III)."""
        for h in self.history:
            if h.get("acc", -1.0) >= target:
                return h["round"]
        return None


def run_cyclic_then_federated(
    task: Task,
    data: FederatedDataset,
    cyclic_cfg: Optional[CyclicConfig],
    fl_cfg: FLConfig,
    verbose: bool = False,
    switch_policy=None,
) -> PipelineResult:
    """cyclic_cfg=None runs the w/o-Cyclic baseline under the same ledger."""
    phases: List[Phase] = []
    if cyclic_cfg is not None:
        phases.append(Phase("P1", cyclic_cfg, switch_policy=switch_policy))
    phases.append(Phase("P2", fl_cfg))
    sched = run_phase_schedule(task, data, phases, verbose=verbose)
    cyc = sched.phases[0].result if cyclic_cfg is not None else None
    return PipelineResult(cyclic=cyc, federated=sched.phases[-1].result,
                          ledger=sched.ledger)
