"""Cyclic+Y — the end-to-end CyclicFL pipeline as a declarative phase
schedule.

The paper's headline configuration is two phases — P1 cyclic
pre-training, then any FL algorithm Y ∈ {FedAvg, FedProx, SCAFFOLD,
Moon} — but with the shared round engine (repro.fl.engine) a phase is
just (strategy config, optional switch policy), so arbitrary schedules
compose: multi-cycle P1↔P2 alternation, relay warm restarts between
algorithms, adaptive-initialization sweeps.  ``run_phase_schedule``
threads the model and one CommLedger through every phase so the
Table-IV accounting is measured, not asserted; switch policies
(core.switch) apply at ANY phase boundary, not just P1→P2.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.comm_accounting import CommLedger
from repro.core.cyclic import CyclicConfig, CyclicResult, cyclic_pretrain
from repro.data.federated import FederatedDataset
from repro.fl.simulation import FLConfig, FLResult, run_federated
from repro.fl.task import Task

# ---------------------------------------------------------------------------
# phase-runner registry: config type -> (kind, runner).  A runner has the
# shared driver signature runner(task, data, cfg, *, init_params, ledger,
# verbose, eval_fn, switch_policy, phase) and returns an object with
# ``.params`` and ``.history``.  Backends register their configs here
# (repro.fl.pod adds the sharded pod configs) so the SAME declarative
# schedule drives host simulation and mesh training.
# ---------------------------------------------------------------------------

_PHASE_RUNNERS: Dict[type, Tuple[str, Callable]] = {}


def register_phase_runner(cfg_type: type, kind: str,
                          runner: Callable) -> None:
    """Make ``Phase(cfg=<cfg_type instance>)`` runnable.  ``kind`` is
    "relay" (P1-style, no aggregation) or "aggregate"."""
    _PHASE_RUNNERS[cfg_type] = (kind, runner)


def _lookup_runner(cfg) -> Tuple[str, Callable]:
    for t in type(cfg).__mro__:
        if t in _PHASE_RUNNERS:
            return _PHASE_RUNNERS[t]
    raise TypeError(f"no phase runner registered for {type(cfg).__name__}; "
                    "see core.pipeline.register_phase_runner")


@dataclasses.dataclass(frozen=True)
class Phase:
    """One schedule entry.  ``cfg`` decides strategy AND backend through
    the runner registry: CyclicConfig/FLConfig run on the host engine,
    the repro.fl.pod configs on the sharded mesh backend.  The phase
    ``name`` tags the history rows; ``switch_policy`` may end the phase
    early (the engine then advances to the next phase); ``eval_fn``
    overrides the engine's default eval metric for this phase — it is
    traced into the round program, so it must follow the engine's
    per-sample contract ``eval_fn(params, bx, by) -> (B,)``."""
    name: str
    cfg: Any
    switch_policy: Optional[object] = None
    eval_fn: Optional[Callable] = None

    @property
    def kind(self) -> str:
        return _lookup_runner(self.cfg)[0]


@dataclasses.dataclass
class PhaseResult:
    phase: Phase
    result: Any                      # CyclicResult | FLResult | EngineResult

    @property
    def history(self) -> List[Dict[str, float]]:
        return self.result.history


@dataclasses.dataclass
class ScheduleResult:
    phases: List[PhaseResult]
    ledger: CommLedger

    @property
    def params(self):
        return self.phases[-1].result.params

    @property
    def history(self) -> List[Dict[str, float]]:
        """All phases' rows with a schedule-global round index."""
        hist: List[Dict[str, float]] = []
        for pr in self.phases:
            offset = len(hist)
            for h in pr.history:
                row = dict(h)
                row["round"] = offset + h["round"]
                hist.append(row)
        return hist

    def best_acc(self) -> Dict[str, float]:
        rows = [h for h in self.history if "acc" in h]
        return max(rows, key=lambda h: h["acc"]) if rows else {}

    def rounds_to_acc(self, target: float) -> Optional[int]:
        """First (global) round reaching ``target`` accuracy — the paper's
        convergence metric (Table III)."""
        for h in self.history:
            if h.get("acc", -1.0) >= target:
                return h["round"]
        return None


def run_phase_schedule(task: Task, data: FederatedDataset,
                       phases: Sequence[Phase],
                       verbose: bool = False,
                       ledger: Optional[CommLedger] = None) -> ScheduleResult:
    """Run ``phases`` in order, each starting from the previous phase's
    final params, under one communication ledger."""
    ledger = ledger if ledger is not None else CommLedger()
    params = None
    results: List[PhaseResult] = []
    for ph in phases:
        _, runner = _lookup_runner(ph.cfg)
        res = runner(task, data, ph.cfg, init_params=params,
                     ledger=ledger, verbose=verbose, eval_fn=ph.eval_fn,
                     switch_policy=ph.switch_policy, phase=ph.name)
        params = res.params
        results.append(PhaseResult(phase=ph, result=res))
    return ScheduleResult(phases=results, ledger=ledger)


register_phase_runner(CyclicConfig, "relay", cyclic_pretrain)
register_phase_runner(FLConfig, "aggregate", run_federated)


# ---------------------------------------------------------------------------
# the paper's two-phase pipeline, expressed as a schedule
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PipelineResult:
    cyclic: Optional[CyclicResult]
    federated: FLResult
    ledger: CommLedger

    @property
    def history(self) -> List[Dict[str, float]]:
        hist = list(self.cyclic.history) if self.cyclic else []
        offset = len(hist)
        for h in self.federated.history:
            row = dict(h)
            row["round"] = offset + h["round"]
            hist.append(row)
        return hist

    def best_acc(self) -> Dict[str, float]:
        rows = [h for h in self.history if "acc" in h]
        return max(rows, key=lambda h: h["acc"]) if rows else {}

    def rounds_to_acc(self, target: float) -> Optional[int]:
        """First (global) round reaching ``target`` accuracy — the paper's
        convergence metric (Table III)."""
        for h in self.history:
            if h.get("acc", -1.0) >= target:
                return h["round"]
        return None


def run_cyclic_then_federated(
    task: Task,
    data: FederatedDataset,
    cyclic_cfg: Optional[CyclicConfig],
    fl_cfg: FLConfig,
    verbose: bool = False,
    switch_policy=None,
) -> PipelineResult:
    """cyclic_cfg=None runs the w/o-Cyclic baseline under the same ledger."""
    phases: List[Phase] = []
    if cyclic_cfg is not None:
        phases.append(Phase("P1", cyclic_cfg, switch_policy=switch_policy))
    phases.append(Phase("P2", fl_cfg))
    sched = run_phase_schedule(task, data, phases, verbose=verbose)
    cyc = sched.phases[0].result if cyclic_cfg is not None else None
    return PipelineResult(cyclic=cyc, federated=sched.phases[-1].result,
                          ledger=sched.ledger)
