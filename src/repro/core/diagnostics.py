"""Loss-landscape and data-consistency diagnostics.

RQ4 / Fig 7: the paper visualizes loss landscapes (Li et al. NeurIPS'18
filter-normalized directions) and argues cyclic pre-training lands in
flatter basins.  On this container we quantify flatness instead of
plotting:

  sharpness_probe      — E[ L(w + α·d) − L(w) ] over random
                         filter-normalized directions d (Fig-7 proxy:
                         smaller = flatter).
  hessian_top_eig      — top Hessian eigenvalue via HVP power iteration
                         (sharpness in the strict sense).

Corollary 1 diagnostics: the SGD↔OGD gap shrinks with task (client)
similarity, so we expose

  client_similarity    — mean pairwise cosine of client label
                         distributions and mean TV from global; the
                         knob β moves these, and the theory predicts
                         CyclicFL's advantage tracks them.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.utils import tree_math as tm

Pytree = Any


def sharpness_probe(loss_fn: Callable[[Pytree], jnp.ndarray], params: Pytree,
                    key: jax.Array, n_dirs: int = 8,
                    alphas: Tuple[float, ...] = (0.1, 0.5, 1.0)) -> Dict[str, float]:
    """Mean loss increase along random filter-normalized directions.

    loss_fn: params -> scalar (bind the eval batch before calling).
    Returns {'base_loss', 'sharpness@<alpha>' ...}; each entry is
    E_d[ L(w + α d) − L(w) ] with d filter-normalized to ||w_leaf||.
    """
    base = float(loss_fn(params))
    out = {"base_loss": base}
    keys = jax.random.split(key, n_dirs)
    deltas = {a: [] for a in alphas}
    for k in keys:
        d = tm.random_like(k, params)
        d = tm.filter_normalize(d, params)
        for a in alphas:
            perturbed = tm.add_scaled(params, d, a)
            deltas[a].append(float(loss_fn(perturbed)) - base)
    for a in alphas:
        out[f"sharpness@{a}"] = float(np.mean(deltas[a]))
    return out


def hessian_top_eig(loss_fn: Callable[[Pytree], jnp.ndarray], params: Pytree,
                    key: jax.Array, n_iter: int = 12) -> float:
    """Top Hessian eigenvalue by power iteration on the HVP."""
    grad_fn = jax.grad(loss_fn)

    @jax.jit
    def hvp(v):
        return jax.jvp(grad_fn, (params,), (v,))[1]

    v = tm.random_like(key, params)
    v = tm.scale(v, 1.0 / (tm.norm(v) + 1e-12))
    eig = 0.0
    for _ in range(n_iter):
        hv = hvp(v)
        eig = float(tm.dot(v, hv))
        n = tm.norm(hv)
        v = tm.scale(hv, 1.0 / (n + 1e-12))
    return eig


def landscape_slice(loss_fn: Callable[[Pytree], jnp.ndarray], params: Pytree,
                    key: jax.Array, n_points: int = 11,
                    radius: float = 1.0) -> Dict[str, np.ndarray]:
    """1-D filter-normalized loss slice (the numeric form of Fig 7's
    surface): L(w + α d) for α ∈ [−radius, radius]."""
    d = tm.filter_normalize(tm.random_like(key, params), params)
    alphas = np.linspace(-radius, radius, n_points)
    losses = np.array([float(loss_fn(tm.add_scaled(params, d, float(a))))
                       for a in alphas])
    return {"alpha": alphas, "loss": losses}


def client_similarity(labels_per_client: np.ndarray, n_classes: int) -> Dict[str, float]:
    """Label-distribution overlap diagnostics (Corollary 1's knob).

    labels_per_client: (n_clients, n_samples) int array.
    """
    dists = []
    for ly in labels_per_client:
        h = np.bincount(np.asarray(ly).ravel() % n_classes, minlength=n_classes)
        dists.append(h / max(h.sum(), 1))
    D = np.stack(dists)                            # (C, n_classes)
    g = D.mean(axis=0)
    # pairwise cosine
    norms = np.linalg.norm(D, axis=1, keepdims=True) + 1e-12
    cos = (D @ D.T) / (norms * norms.T)
    iu = np.triu_indices(len(D), k=1)
    tv = 0.5 * np.abs(D - g).sum(axis=1)
    return {
        "mean_pairwise_cos": float(cos[iu].mean()) if len(iu[0]) else 1.0,
        "mean_tv_from_global": float(tv.mean()),
        "min_pairwise_cos": float(cos[iu].min()) if len(iu[0]) else 1.0,
    }


def make_batch_loss(task, x: np.ndarray, y: np.ndarray) -> Callable[[Pytree], jnp.ndarray]:
    """Bind a fixed eval batch into a pure params->loss closure (jit'd)."""
    bx, by = jnp.asarray(x), jnp.asarray(y)

    @jax.jit
    def loss(params):
        return task.loss_fn(params, bx, by, None)

    return loss
