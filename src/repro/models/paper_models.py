"""The paper's evaluation models, in pure JAX.

Table I models:
  - LeNet-5        (CIFAR-10): 2 conv + 3 FC          [LeCun et al. 1998]
  - ResNet-8       (CIFAR-100): 3 basic residual blocks + BN-free GroupNorm*
  - CNN-FEMNIST    (FEMNIST): 2 conv + 1 FC
  - CNN-Fashion    (Fashion-MNIST): 2 conv + dropout + 2 FC
  - CharLSTM-256   (Shakespeare): embed + 2-layer LSTM(256) + FC

*BatchNorm is notoriously broken under non-IID FL (client statistics
diverge); the paper uses BN in ResNet-8 but aggregates running stats via
FedAvg.  We keep an exact-BN variant for fidelity (train-mode batch
stats, aggregated like weights) — GroupNorm can be selected with
``norm='group'`` for the robustness ablation.

Each model is an (init, apply) pair over dict params; apply signature is
``apply(params, x, train=False, rng=None) -> logits``.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import he_normal, normal_init
from repro.utils.registry import Registry

Pytree = Any
PAPER_MODELS: Registry = Registry("paper_model")


# ---------------------------------------------------------------------------
# conv/pool/norm primitives (NHWC)
# ---------------------------------------------------------------------------

def init_conv(key, k: int, c_in: int, c_out: int, dtype=jnp.float32) -> Pytree:
    w = he_normal(key, (k, k, c_in, c_out), fan_in=k * k * c_in, dtype=dtype)
    return {"w": w, "b": jnp.zeros((c_out,), dtype)}


def conv2d(p: Pytree, x: jnp.ndarray, stride: int = 1, padding: str = "SAME") -> jnp.ndarray:
    y = jax.lax.conv_general_dilated(
        x, p["w"].astype(x.dtype), (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + p["b"].astype(x.dtype)


def maxpool(x: jnp.ndarray, k: int = 2) -> jnp.ndarray:
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                 (1, k, k, 1), (1, k, k, 1), "VALID")


def avgpool_global(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.mean(x, axis=(1, 2))


def init_fc(key, d_in: int, d_out: int, dtype=jnp.float32) -> Pytree:
    return {"w": he_normal(key, (d_in, d_out), fan_in=d_in, dtype=dtype),
            "b": jnp.zeros((d_out,), dtype)}


def fc(p: Pytree, x: jnp.ndarray) -> jnp.ndarray:
    return x @ p["w"].astype(x.dtype) + p["b"].astype(x.dtype)


def init_bn(c: int, dtype=jnp.float32) -> Pytree:
    return {"scale": jnp.ones((c,), dtype), "bias": jnp.zeros((c,), dtype)}


def batchnorm(p: Pytree, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    """Train-mode BN (batch statistics).  FL simulation always trains;
    evaluation uses the same batch statistics, matching common FL-repo
    practice where running stats are unreliable under non-IID."""
    mu = jnp.mean(x, axis=(0, 1, 2), keepdims=True)
    var = jnp.var(x, axis=(0, 1, 2), keepdims=True)
    xhat = (x - mu) * jax.lax.rsqrt(var + eps)
    return xhat * p["scale"].astype(x.dtype) + p["bias"].astype(x.dtype)


def groupnorm(p: Pytree, x: jnp.ndarray, groups: int = 8, eps: float = 1e-5) -> jnp.ndarray:
    N, H, W, C = x.shape
    g = math.gcd(groups, C)
    xg = x.reshape(N, H, W, g, C // g)
    mu = jnp.mean(xg, axis=(1, 2, 4), keepdims=True)
    var = jnp.var(xg, axis=(1, 2, 4), keepdims=True)
    xg = (xg - mu) * jax.lax.rsqrt(var + eps)
    return xg.reshape(N, H, W, C) * p["scale"].astype(x.dtype) + p["bias"].astype(x.dtype)


# ---------------------------------------------------------------------------
# LeNet-5 (CIFAR-10)
# ---------------------------------------------------------------------------

def lenet5_init(key, n_classes: int = 10, in_ch: int = 3) -> Pytree:
    ks = jax.random.split(key, 5)
    return {
        "c1": init_conv(ks[0], 5, in_ch, 6),
        "c2": init_conv(ks[1], 5, 6, 16),
        "f1": init_fc(ks[2], 16 * 8 * 8, 120),
        "f2": init_fc(ks[3], 120, 84),
        "f3": init_fc(ks[4], 84, n_classes),
    }


def lenet5_apply(p: Pytree, x: jnp.ndarray, train: bool = False, rng=None) -> jnp.ndarray:
    x = maxpool(jax.nn.relu(conv2d(p["c1"], x)))
    x = maxpool(jax.nn.relu(conv2d(p["c2"], x)))
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(fc(p["f1"], x))
    x = jax.nn.relu(fc(p["f2"], x))
    return fc(p["f3"], x)


# ---------------------------------------------------------------------------
# ResNet-8 (CIFAR-100): conv stem + 3 basic blocks + linear
# ---------------------------------------------------------------------------

def _init_basic_block(key, c_in: int, c_out: int, stride: int, norm: str) -> Pytree:
    ks = jax.random.split(key, 3)
    p = {
        "conv1": init_conv(ks[0], 3, c_in, c_out),
        "n1": init_bn(c_out),
        "conv2": init_conv(ks[1], 3, c_out, c_out),
        "n2": init_bn(c_out),
        "stride": stride,
    }
    if stride != 1 or c_in != c_out:
        p["proj"] = init_conv(ks[2], 1, c_in, c_out)
    return p


def _basic_block(p: Pytree, x: jnp.ndarray, norm_fn) -> jnp.ndarray:
    s = p["stride"]
    h = jax.nn.relu(norm_fn(p["n1"], conv2d(p["conv1"], x, stride=s)))
    h = norm_fn(p["n2"], conv2d(p["conv2"], h))
    sc = conv2d(p["proj"], x, stride=s) if "proj" in p else x
    return jax.nn.relu(h + sc)


def resnet8_init(key, n_classes: int = 100, in_ch: int = 3, norm: str = "batch") -> Pytree:
    ks = jax.random.split(key, 6)
    return {
        "stem": init_conv(ks[0], 3, in_ch, 16),
        "stem_n": init_bn(16),
        "b1": _init_basic_block(ks[1], 16, 16, 1, norm),
        "b2": _init_basic_block(ks[2], 16, 32, 2, norm),
        "b3": _init_basic_block(ks[3], 32, 64, 2, norm),
        "head": init_fc(ks[4], 64, n_classes),
        "norm_kind": norm,
    }


def resnet8_apply(p: Pytree, x: jnp.ndarray, train: bool = False, rng=None) -> jnp.ndarray:
    norm_fn = batchnorm if p.get("norm_kind", "batch") == "batch" else groupnorm
    x = jax.nn.relu(norm_fn(p["stem_n"], conv2d(p["stem"], x)))
    x = _basic_block(p["b1"], x, norm_fn)
    x = _basic_block(p["b2"], x, norm_fn)
    x = _basic_block(p["b3"], x, norm_fn)
    return fc(p["head"], avgpool_global(x))


# ---------------------------------------------------------------------------
# CNN-FEMNIST: 2 conv + 1 FC
# ---------------------------------------------------------------------------

def cnn_femnist_init(key, n_classes: int = 62, in_ch: int = 1) -> Pytree:
    ks = jax.random.split(key, 3)
    return {
        "c1": init_conv(ks[0], 5, in_ch, 32),
        "c2": init_conv(ks[1], 5, 32, 64),
        "f1": init_fc(ks[2], 64 * 7 * 7, n_classes),
    }


def cnn_femnist_apply(p: Pytree, x: jnp.ndarray, train: bool = False, rng=None) -> jnp.ndarray:
    x = maxpool(jax.nn.relu(conv2d(p["c1"], x)))
    x = maxpool(jax.nn.relu(conv2d(p["c2"], x)))
    return fc(p["f1"], x.reshape(x.shape[0], -1))


# ---------------------------------------------------------------------------
# CNN-Fashion: 2 conv + dropout + 2 FC
# ---------------------------------------------------------------------------

def mlp_init(key, n_classes: int = 10, in_ch: int = 1, d_hidden: int = 64,
             img: int = 28) -> Pytree:
    """Two-layer MLP — not a paper model; the matmul-only workload used by
    dispatch/throughput microbenchmarks where conv cost would mask the
    effect being measured."""
    ks = jax.random.split(key, 2)
    return {
        "f1": init_fc(ks[0], img * img * in_ch, d_hidden),
        "f2": init_fc(ks[1], d_hidden, n_classes),
    }


def mlp_apply(p: Pytree, x: jnp.ndarray, train: bool = False, rng=None) -> jnp.ndarray:
    x = x.reshape(x.shape[0], -1)
    return fc(p["f2"], jax.nn.relu(fc(p["f1"], x)))


def cnn_fashion_init(key, n_classes: int = 10, in_ch: int = 1) -> Pytree:
    ks = jax.random.split(key, 4)
    return {
        "c1": init_conv(ks[0], 5, in_ch, 16),
        "c2": init_conv(ks[1], 5, 16, 32),
        "f1": init_fc(ks[2], 32 * 7 * 7, 128),
        "f2": init_fc(ks[3], 128, n_classes),
    }


def cnn_fashion_apply(p: Pytree, x: jnp.ndarray, train: bool = False,
                      rng=None, drop: float = 0.5) -> jnp.ndarray:
    x = maxpool(jax.nn.relu(conv2d(p["c1"], x)))
    x = maxpool(jax.nn.relu(conv2d(p["c2"], x)))
    x = x.reshape(x.shape[0], -1)
    if train and rng is not None:
        keep = jax.random.bernoulli(rng, 1 - drop, x.shape).astype(x.dtype)
        x = x * keep / (1 - drop)
    x = jax.nn.relu(fc(p["f1"], x))
    return fc(p["f2"], x)


# ---------------------------------------------------------------------------
# CharLSTM-256 (Shakespeare): embed(8) + 2x LSTM(256) + FC
# ---------------------------------------------------------------------------

def _init_lstm_cell(key, d_in: int, d_hidden: int) -> Pytree:
    ks = jax.random.split(key, 2)
    scale = (d_in + d_hidden) ** -0.5
    return {
        "wx": normal_init(ks[0], (d_in, 4 * d_hidden), std=scale),
        "wh": normal_init(ks[1], (d_hidden, 4 * d_hidden), std=scale),
        "b": jnp.zeros((4 * d_hidden,)),
    }


def _lstm_cell(p: Pytree, carry, x):
    h, c = carry
    gates = x @ p["wx"] + h @ p["wh"] + p["b"]
    i, f, g, o = jnp.split(gates, 4, axis=-1)
    c = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    h = jax.nn.sigmoid(o) * jnp.tanh(c)
    return (h, c), h


def charlstm_init(key, vocab: int = 64, d_embed: int = 8, d_hidden: int = 256) -> Pytree:
    ks = jax.random.split(key, 4)
    return {
        "embed": normal_init(ks[0], (vocab, d_embed), std=0.1),
        "lstm1": _init_lstm_cell(ks[1], d_embed, d_hidden),
        "lstm2": _init_lstm_cell(ks[2], d_hidden, d_hidden),
        "head": init_fc(ks[3], d_hidden, vocab),
    }


def charlstm_apply(p: Pytree, tokens: jnp.ndarray, train: bool = False,
                   rng=None) -> jnp.ndarray:
    """tokens: (B, S) int -> logits (B, S, vocab) for next-char prediction."""
    B, S = tokens.shape
    x = p["embed"][tokens]                                  # (B, S, e)
    d_hidden = p["lstm1"]["wh"].shape[0]

    def run_layer(cell, seq):
        init = (jnp.zeros((B, d_hidden), seq.dtype), jnp.zeros((B, d_hidden), seq.dtype))
        _, hs = jax.lax.scan(lambda c, xt: _lstm_cell(cell, c, xt),
                             init, jnp.moveaxis(seq, 1, 0))
        return jnp.moveaxis(hs, 0, 1)

    h = run_layer(p["lstm1"], x)
    h = run_layer(p["lstm2"], h)
    return fc(p["head"], h)


# ---------------------------------------------------------------------------
# registry: name -> (init_fn(key, n_classes), apply_fn, kind)
# ---------------------------------------------------------------------------

PAPER_MODELS.register("lenet5")((lenet5_init, lenet5_apply, "vision"))
PAPER_MODELS.register("resnet8")((resnet8_init, resnet8_apply, "vision"))
PAPER_MODELS.register("cnn_femnist")((cnn_femnist_init, cnn_femnist_apply, "vision"))
PAPER_MODELS.register("cnn_fashion")((cnn_fashion_init, cnn_fashion_apply, "vision"))
PAPER_MODELS.register("mlp")((mlp_init, mlp_apply, "vision"))
PAPER_MODELS.register("charlstm")((charlstm_init, charlstm_apply, "charlm"))
