"""Unified decoder-LM assembly covering all assigned architecture families.

One ``TransformerConfig`` describes dense (GQA), MoE (incl. MLA), SSM
(Mamba2), hybrid (parallel attn+SSM heads), VLM-backbone and
audio-backbone models.  Layers with identical structure are stacked and
driven by ``lax.scan`` (small HLO, fast SPMD partitioning); heterogeneous
prefixes (dense layers before MoE) are unrolled.

Entry points:
    init_lm(key, cfg)                        -> params
    lm_forward(params, cfg, batch)           -> logits (full sequence)
    lm_loss(params, cfg, batch)              -> (loss, metrics)
    prefill(params, cfg, batch, max_len)     -> (logits_last, cache)
    init_decode_cache(cfg, batch, max_len)   -> cache
    decode_step(params, cfg, token, cache, cache_len) -> (logits, cache)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.layers import (
    AttnConfig, MLAConfig, MoEConfig,
    init_attention, attention, init_mla, mla_attention,
    init_mlp, mlp, init_moe, moe,
    init_rmsnorm, rmsnorm, init_linear, linear, normal_init,
)
from repro.models.ssm import (
    SSMConfig, init_ssm, ssm_forward, ssm_decode_step, init_ssm_state,
)

Pytree = Any


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    arch_type: str                       # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    vocab_size: int
    n_heads: int = 0
    n_kv_heads: int = 0
    d_ff: int = 0
    head_dim: Optional[int] = None
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    # attention variant ------------------------------------------------
    attention: str = "gqa"               # gqa | mla | none
    window: Optional[int] = None         # sliding-window size (SWA layers)
    global_attn_layers: Tuple[int, ...] = ()  # full-attn layer ids when window set
    # MLA ----------------------------------------------------------------
    kv_lora_rank: int = 0
    q_lora_rank: Optional[int] = None
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    # MoE ----------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_ff_expert: int = 0
    d_ff_shared: int = 0
    n_dense_layers: int = 0              # leading dense-FFN layers (deepseek)
    router_scoring: str = "softmax"
    capacity_factor: float = 1.25
    aux_loss_coef: float = 0.001
    # SSM ----------------------------------------------------------------
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_n_groups: int = 1
    ssm_chunk: int = 128
    # multi-token prediction (deepseek-v3) --------------------------------
    mtp: bool = False
    mtp_loss_weight: float = 0.3
    # PEFT -----------------------------------------------------------------
    lora_rank: int = 0        # > 0: LoRA-adapt every block linear (attn
                              # q/k/v/o + MLP gate/up/down); embeddings,
                              # lm_head and norms stay plain.  Distinct from
                              # the MLA kv_lora_rank/q_lora_rank above,
                              # which are architectural low-rank factors,
                              # not adapters.
    # input handling -------------------------------------------------------
    input_mode: str = "tokens"           # tokens | vlm | embeddings
    n_prefix_tokens: int = 0             # vlm patch count
    n_codebooks: int = 1                 # musicgen output heads
    # numerics / impl ------------------------------------------------------
    dtype: Any = jnp.float32
    param_dtype: Any = jnp.float32
    attn_impl: str = "xla"
    ssd_impl: str = "xla"
    scan_unroll: Any = 1      # lax.scan unroll for the layer stack; True =
                              # fully unrolled (dry-run cost correction uses
                              # this — XLA cost_analysis counts a while body
                              # ONCE, so scanned stacks undercount by ~L)
    shard_activations: bool = False   # insert with_sharding_constraint on
                                      # the residual stream (batch over
                                      # ``batch_axes``) — §Perf fix for
                                      # SPMD dropping batch sharding
                                      # through attention (requires a mesh
                                      # context with these axis names)
    batch_axes: Tuple[str, ...] = ("data",)
    remat: bool = False
    norm_eps: float = 1e-6
    logit_dtype: Any = jnp.float32

    # ---- derived -----------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    def attn_cfg(self) -> AttnConfig:
        return AttnConfig(
            d_model=self.d_model, n_heads=self.n_heads, n_kv_heads=self.n_kv_heads,
            head_dim=self.resolved_head_dim, qkv_bias=self.qkv_bias,
            qk_norm=self.qk_norm, rope_theta=self.rope_theta,
            window=self.window, attn_impl=self.attn_impl,
            lora_rank=self.lora_rank)

    def mla_cfg(self) -> MLAConfig:
        return MLAConfig(
            d_model=self.d_model, n_heads=self.n_heads,
            kv_lora_rank=self.kv_lora_rank, q_lora_rank=self.q_lora_rank,
            qk_nope_dim=self.qk_nope_dim, qk_rope_dim=self.qk_rope_dim,
            v_head_dim=self.v_head_dim, rope_theta=self.rope_theta)

    def moe_cfg(self) -> MoEConfig:
        return MoEConfig(
            d_model=self.d_model, n_experts=self.n_experts, top_k=self.top_k,
            d_ff_expert=self.d_ff_expert, n_shared=self.n_shared_experts,
            d_ff_shared=self.d_ff_shared, capacity_factor=self.capacity_factor,
            router_scoring=self.router_scoring, aux_loss_coef=self.aux_loss_coef)

    def ssm_cfg(self) -> SSMConfig:
        return SSMConfig(
            d_model=self.d_model, d_state=self.ssm_state,
            head_dim=self.ssm_head_dim, expand=self.ssm_expand,
            n_groups=self.ssm_n_groups, chunk=self.ssm_chunk,
            ssd_impl=self.ssd_impl)

    @property
    def has_attn(self) -> bool:
        return self.arch_type != "ssm"

    @property
    def has_ssm(self) -> bool:
        return self.arch_type in ("ssm", "hybrid")

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def has_ffn(self) -> bool:
        return self.d_ff > 0 or self.is_moe

    def validate(self) -> None:
        assert self.arch_type in ("dense", "moe", "ssm", "hybrid", "vlm", "audio"), self.arch_type
        if self.has_attn and self.attention == "gqa":
            assert self.n_heads % max(self.n_kv_heads, 1) == 0, "GQA head mismatch"
        if self.is_moe:
            assert 0 < self.top_k <= self.n_experts
        if self.arch_type == "ssm":
            assert self.ssm_state > 0


# ---------------------------------------------------------------------------
# block init / apply
# ---------------------------------------------------------------------------

def _init_block(key, cfg: TransformerConfig, moe_layer: bool) -> Pytree:
    ks = jax.random.split(key, 8)
    pd = cfg.param_dtype
    p: Dict[str, Pytree] = {}
    if cfg.has_attn:
        p["attn_norm"] = init_rmsnorm(cfg.d_model, pd)
        if cfg.attention == "mla":
            p["attn"] = init_mla(ks[0], cfg.mla_cfg(), pd)
        else:
            p["attn"] = init_attention(ks[0], cfg.attn_cfg(), pd)
    if cfg.has_ssm:
        if cfg.arch_type == "ssm":
            p["ssm_norm"] = init_rmsnorm(cfg.d_model, pd)
        p["ssm"] = init_ssm(ks[1], cfg.ssm_cfg(), pd)
    if cfg.has_ffn:
        p["ffn_norm"] = init_rmsnorm(cfg.d_model, pd)
        if moe_layer:
            p["moe"] = init_moe(ks[2], cfg.moe_cfg(), pd)
        elif cfg.d_ff > 0:
            p["mlp"] = init_mlp(ks[2], cfg.d_model, cfg.d_ff, pd,
                                lora_rank=cfg.lora_rank)
    return p


def _block_apply(p: Pytree, x: jnp.ndarray, cfg: TransformerConfig,
                 positions: jnp.ndarray, moe_layer: bool,
                 is_global: Optional[jnp.ndarray] = None,
                 cache: Optional[Pytree] = None, cache_len=None):
    """One decoder block.  Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    new_cache: Dict[str, Pytree] = {}

    if cfg.arch_type == "hybrid":
        # Hymba: attention heads and mamba heads consume the same normed
        # input in parallel; outputs are averaged (arXiv:2411.13676 eq. 3).
        xn = rmsnorm(p["attn_norm"], x, cfg.norm_eps)
        attn_out, kv = _run_attention(p, xn, cfg, positions, is_global,
                                      cache.get("kv") if cache else None, cache_len)
        if cache is not None:
            ssm_out, ssm_state = _run_ssm_cached(p["ssm"], xn, cache["ssm"], cfg)
            new_cache["ssm"] = ssm_state
        else:
            ssm_out = ssm_forward(p["ssm"], xn, cfg.ssm_cfg())
        new_cache["kv"] = kv
        x = x + 0.5 * (attn_out + ssm_out)
    elif cfg.arch_type == "ssm":
        xn = rmsnorm(p["ssm_norm"], x, cfg.norm_eps)
        if cache is not None:
            out, ssm_state = _run_ssm_cached(p["ssm"], xn, cache["ssm"], cfg)
            new_cache["ssm"] = ssm_state
        else:
            out = ssm_forward(p["ssm"], xn, cfg.ssm_cfg())
        x = x + out
    else:
        xn = rmsnorm(p["attn_norm"], x, cfg.norm_eps)
        attn_out, kv = _run_attention(p, xn, cfg, positions, is_global,
                                      cache.get("kv") if cache else None, cache_len)
        new_cache["kv"] = kv
        x = x + attn_out

    if cfg.has_ffn:
        xn = rmsnorm(p["ffn_norm"], x, cfg.norm_eps)
        if moe_layer:
            out, aux = moe(p["moe"], xn, cfg.moe_cfg())
        else:
            out = mlp(p["mlp"], xn)
        x = x + out
    return x, new_cache, aux


def _run_ssm_cached(ssm_params, xn, ssm_state, cfg: TransformerConfig):
    """Cached SSM: single-token decode updates the recurrent state; a
    multi-token call (prefill) runs the chunked scan and emits the final
    state for subsequent decode steps."""
    if xn.shape[1] == 1:
        return ssm_decode_step(ssm_params, xn, ssm_state, cfg.ssm_cfg())
    out, (final, conv_tail) = ssm_forward(ssm_params, xn, cfg.ssm_cfg(),
                                          return_final_state=True)
    return out, (final, conv_tail.astype(ssm_state[1].dtype))


def _run_attention(p, xn, cfg: TransformerConfig, positions, is_global,
                   kv_cache, cache_len):
    if cfg.attention == "mla":
        return mla_attention(p["attn"], xn, cfg.mla_cfg(), positions,
                             kv_cache=kv_cache, cache_len=cache_len)
    acfg = cfg.attn_cfg()
    if cfg.window is not None and is_global is not None:
        # per-layer SWA/global choice carried as a traced flag: a "window"
        # larger than any sequence is equivalent to full attention.
        eff_window = jnp.where(is_global, jnp.int32(2**30), jnp.int32(cfg.window))
        acfg = dataclasses.replace(acfg, window=eff_window)
    return attention(p["attn"], xn, acfg, positions,
                     kv_cache=kv_cache, cache_len=cache_len)


# ---------------------------------------------------------------------------
# model init
# ---------------------------------------------------------------------------

def init_lm(key, cfg: TransformerConfig) -> Pytree:
    cfg.validate()
    ks = jax.random.split(key, 8)
    pd = cfg.param_dtype
    params: Dict[str, Pytree] = {}
    if cfg.input_mode in ("tokens", "vlm"):
        params["embed"] = normal_init(ks[0], (cfg.vocab_size, cfg.d_model),
                                      std=0.02, dtype=pd)
    # scanned identical blocks
    n_scan = cfg.n_layers - cfg.n_dense_layers
    block_keys = jax.random.split(ks[1], n_scan)
    params["blocks"] = jax.vmap(
        lambda k: _init_block(k, cfg, moe_layer=cfg.is_moe))(block_keys)
    # unrolled dense prefix (deepseek v2/v3 first layers are dense-FFN)
    if cfg.n_dense_layers:
        dk = jax.random.split(ks[2], cfg.n_dense_layers)
        params["dense_blocks"] = [
            _init_block(dk[i], cfg, moe_layer=False) for i in range(cfg.n_dense_layers)]
    params["final_norm"] = init_rmsnorm(cfg.d_model, pd)
    if not cfg.tie_embeddings:
        params["lm_head"] = normal_init(
            ks[3], (cfg.d_model, cfg.vocab_size * cfg.n_codebooks),
            std=cfg.d_model ** -0.5, dtype=pd)
    if cfg.mtp:
        params["mtp"] = {
            "block": _init_block(ks[4], cfg, moe_layer=cfg.is_moe),
            "proj": init_linear(ks[5], 2 * cfg.d_model, cfg.d_model, dtype=pd),
            "norm_prev": init_rmsnorm(cfg.d_model, pd),
            "norm_emb": init_rmsnorm(cfg.d_model, pd),
        }
    return params


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------

def _maybe_shard(x: jnp.ndarray, cfg: TransformerConfig) -> jnp.ndarray:
    """Pin the residual stream's batch dim to ``cfg.batch_axes``.

    SPMD sharding propagation can DROP batch sharding through the
    attention einsums (observed: deepseek-v3 train_4k ran attention with
    the full global batch replicated per chip — 16× wasted compute).
    Anchors at block boundaries AND inside attention (layers.anchor_batch
    on the score tensors, installed by ``_install_act_sharding``)."""
    if not cfg.shard_activations:
        return x
    return L.anchor_batch(x)


def _install_act_sharding(cfg: TransformerConfig) -> None:
    """Trace-time switch for the in-attention batch anchors."""
    L.set_activation_batch_axes(cfg.batch_axes if cfg.shard_activations
                                else None)


def _embed_inputs(params, cfg: TransformerConfig, batch: Dict[str, jnp.ndarray]):
    """Returns (x, positions, text_offset)."""
    if cfg.input_mode == "tokens":
        x = params["embed"][batch["tokens"]].astype(cfg.dtype)
        positions = jnp.arange(x.shape[1])
        return x, positions, 0
    if cfg.input_mode == "vlm":
        # stub frontend: precomputed patch embeddings + token embeddings
        pe = batch["patch_embeds"].astype(cfg.dtype)       # (B, P, d)
        te = params["embed"][batch["tokens"]].astype(cfg.dtype)
        x = jnp.concatenate([pe, te], axis=1)
        positions = jnp.arange(x.shape[1])
        return x, positions, pe.shape[1]
    if cfg.input_mode == "embeddings":
        # audio stub: precomputed EnCodec frame embeddings
        x = batch["frame_embeds"].astype(cfg.dtype)
        positions = jnp.arange(x.shape[1])
        return x, positions, 0
    raise ValueError(cfg.input_mode)


def _global_flags(cfg: TransformerConfig) -> Optional[jnp.ndarray]:
    if cfg.window is None:
        return None
    n_scan = cfg.n_layers - cfg.n_dense_layers
    flags = jnp.zeros((n_scan,), bool)
    for idx in cfg.global_attn_layers:
        if 0 <= idx - cfg.n_dense_layers < n_scan:
            flags = flags.at[idx - cfg.n_dense_layers].set(True)
    return flags


def _run_blocks(params, cfg: TransformerConfig, x, positions,
                caches=None, cache_len=None):
    """Dense-prefix blocks (unrolled) then scanned stack.

    caches: None for full-sequence, else dict with 'dense' (list) and
    'scan' (stacked, leading L axis) entries.
    Returns (x, new_caches, total_aux).
    """
    aux_total = jnp.zeros((), jnp.float32)
    new_dense = []
    for i in range(cfg.n_dense_layers):
        c = caches["dense"][i] if caches is not None else None
        x, nc, aux = _block_apply(params["dense_blocks"][i], x, cfg, positions,
                                  moe_layer=False, is_global=None,
                                  cache=c, cache_len=cache_len)
        new_dense.append(nc)
        aux_total += aux

    flags = _global_flags(cfg)

    def body(carry, xs):
        h, aux_acc = carry
        if caches is not None:
            bp, flag, cache_l = xs
        else:
            bp, flag = xs
            cache_l = None
        h, nc, aux = _block_apply(bp, h, cfg, positions,
                                  moe_layer=cfg.is_moe, is_global=flag,
                                  cache=cache_l, cache_len=cache_len)
        h = _maybe_shard(h, cfg)
        return (h, aux_acc + aux), nc

    body_fn = jax.checkpoint(body) if (cfg.remat and caches is None) else body
    n_scan = cfg.n_layers - cfg.n_dense_layers
    flag_xs = flags if flags is not None else jnp.zeros((n_scan,), bool)
    if caches is not None:
        xs = (params["blocks"], flag_xs, caches["scan"])
    else:
        xs = (params["blocks"], flag_xs)
    (x, aux_total2), scan_caches = jax.lax.scan(body_fn, (x, aux_total), xs,
                                                unroll=cfg.scan_unroll)
    new_caches = {"dense": new_dense, "scan": scan_caches} if caches is not None else None
    return x, new_caches, aux_total2


def _logits(params, cfg: TransformerConfig, x: jnp.ndarray) -> jnp.ndarray:
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(cfg.dtype))
    if cfg.n_codebooks > 1:
        B, S, _ = logits.shape
        logits = logits.reshape(B, S, cfg.n_codebooks, cfg.vocab_size)
    return logits.astype(cfg.logit_dtype)


def lm_forward(params, cfg: TransformerConfig, batch: Dict[str, jnp.ndarray]):
    """Full-sequence forward -> (logits, aux_loss, hidden)."""
    _install_act_sharding(cfg)
    x, positions, _ = _embed_inputs(params, cfg, batch)
    x = _maybe_shard(x, cfg)
    x, _, aux = _run_blocks(params, cfg, x, positions)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return _logits(params, cfg, x), aux, x


def _xent(logits: jnp.ndarray, labels: jnp.ndarray, ignore: int = -1):
    """Cross-entropy with ignore-label masking; logits (..., V)."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, jnp.maximum(labels, 0)[..., None],
                               axis=-1)[..., 0]
    nll = logz - gold
    mask = (labels != ignore).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0), mask


def lm_loss(params, cfg: TransformerConfig, batch: Dict[str, jnp.ndarray]):
    """Next-token loss.  batch['labels']:
       tokens/embeddings mode: (B, S) — or (B, S, n_codebooks) for audio;
       vlm mode: (B, S_text) — prefix positions are excluded automatically.
    """
    logits, aux, hidden = lm_forward(params, cfg, batch)
    labels = batch["labels"]
    if cfg.input_mode == "vlm":
        # drop image-prefix positions; predict text tokens only
        P = batch["patch_embeds"].shape[1]
        logits = logits[:, P:]
    loss, mask = _xent(logits, labels)
    metrics = {"xent": loss, "aux": aux}
    total = loss + aux
    if cfg.mtp:
        mtp_loss = _mtp_loss(params, cfg, batch, hidden)
        metrics["mtp"] = mtp_loss
        total = total + cfg.mtp_loss_weight * mtp_loss
    metrics["loss"] = total
    return total, metrics


def _mtp_loss(params, cfg: TransformerConfig, batch, hidden):
    """DeepSeek-V3 multi-token prediction (depth 1): combine the main
    trunk's hidden state at position i with the embedding of token i+1 to
    predict token i+2 through one extra block."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    mp = params["mtp"]
    h_prev = rmsnorm(mp["norm_prev"], hidden[:, : S - 1])
    emb_next = rmsnorm(mp["norm_emb"],
                       params["embed"][tokens[:, 1:]].astype(cfg.dtype))
    h = linear(mp["proj"], jnp.concatenate([h_prev, emb_next], axis=-1))
    positions = jnp.arange(S - 1)
    h, _, _ = _block_apply(mp["block"], h, cfg, positions, moe_layer=cfg.is_moe)
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = _logits(params, cfg, h)
    labels = batch["labels"][:, 1:]  # labels[i] = token i+1 => shift one more
    loss, _ = _xent(logits, labels)
    return loss


# ---------------------------------------------------------------------------
# decode path
# ---------------------------------------------------------------------------

def _layer_cache_struct(cfg: TransformerConfig, batch: int, max_len: int):
    """Cache pytree for ONE block (used stacked for the scan stack)."""
    c: Dict[str, Any] = {}
    if cfg.has_attn:
        if cfg.attention == "mla":
            c["kv"] = (
                jnp.zeros((batch, max_len, cfg.kv_lora_rank), cfg.dtype),
                jnp.zeros((batch, max_len, cfg.qk_rope_dim), cfg.dtype),
            )
        else:
            hd, KH = cfg.resolved_head_dim, cfg.n_kv_heads
            c["kv"] = (
                jnp.zeros((batch, max_len, KH, hd), cfg.dtype),
                jnp.zeros((batch, max_len, KH, hd), cfg.dtype),
            )
    if cfg.has_ssm:
        c["ssm"] = init_ssm_state(cfg.ssm_cfg(), batch, cfg.dtype)
    return c


def init_decode_cache(cfg: TransformerConfig, batch: int, max_len: int):
    n_scan = cfg.n_layers - cfg.n_dense_layers
    one = _layer_cache_struct(cfg, batch, max_len)
    scan_cache = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (n_scan,) + x.shape), one)
    dense = [
        _layer_cache_struct(cfg, batch, max_len) for _ in range(cfg.n_dense_layers)]
    return {"dense": dense, "scan": scan_cache}


def prefill(params, cfg: TransformerConfig, batch: Dict[str, jnp.ndarray],
            max_len: int):
    """Process the prompt, build the decode cache.  Returns
    (last-position logits, cache, prompt_len)."""
    _install_act_sharding(cfg)
    x, positions, _ = _embed_inputs(params, cfg, batch)
    x = _maybe_shard(x, cfg)
    S = x.shape[1]
    caches = init_decode_cache(cfg, x.shape[0], max_len)
    # full-sequence pass but inserting k/v into the preallocated cache
    x, new_caches, _ = _run_blocks(params, cfg, x, positions,
                                   caches=caches, cache_len=0)
    x = rmsnorm(params["final_norm"], x[:, -1:], cfg.norm_eps)
    return _logits(params, cfg, x), new_caches, S


def decode_step(params, cfg: TransformerConfig, token: jnp.ndarray,
                caches, cache_len):
    """One decode step.  token: (B, 1) int32 (or (B,1,d) embeddings for
    the audio stub); cache_len: scalar count of valid cache positions.
    Returns (logits (B,1,V[,C]), new_caches).
    """
    _install_act_sharding(cfg)
    if cfg.input_mode == "embeddings":
        x = token.astype(cfg.dtype)
    else:
        x = params["embed"][token].astype(cfg.dtype)
    positions = cache_len + jnp.arange(1)
    x, new_caches, _ = _run_blocks(params, cfg, x, positions,
                                   caches=caches, cache_len=cache_len)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return _logits(params, cfg, x), new_caches
