from repro.models.transformer import (
    TransformerConfig,
    init_lm,
    lm_loss,
    lm_forward,
    prefill,
    decode_step,
    init_decode_cache,
)
from repro.models.paper_models import (
    lenet5_init, lenet5_apply,
    resnet8_init, resnet8_apply,
    cnn_femnist_init, cnn_femnist_apply,
    cnn_fashion_init, cnn_fashion_apply,
    charlstm_init, charlstm_apply,
    PAPER_MODELS,
)
