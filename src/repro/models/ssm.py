"""Mamba2 (SSD — state-space duality) layer, TPU-adapted.

Follows Dao & Gu (arXiv:2405.21060): scalar-identity A per head, chunked
computation so the sequence dim becomes matmuls (MXU-friendly) with a
short sequential recurrence over chunk states.  The GPU formulation's
warp-level scan does not transfer to TPU; the chunked form is the
TPU-native equivalent (see DESIGN.md §3/§5).

Layer I/O follows mamba_ssm.Mamba2: fused input projection producing
(z, x, B, C, dt), short depthwise conv on (x, B, C), SSD core, gated
RMSNorm, output projection.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import init_linear, linear, init_rmsnorm, rmsnorm, normal_init

Pytree = Any


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_model: int
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 128
    ssd_impl: str = "xla"  # xla | pallas | pallas_interpret
    dt_min: float = 0.001
    dt_max: float = 0.1

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.head_dim

    @property
    def conv_dim(self) -> int:
        return self.d_inner + 2 * self.n_groups * self.d_state


def init_ssm(key, cfg: SSMConfig, dtype=jnp.float32) -> Pytree:
    ks = jax.random.split(key, 6)
    d_in_proj = 2 * cfg.d_inner + 2 * cfg.n_groups * cfg.d_state + cfg.n_heads
    p = {
        "in_proj": init_linear(ks[0], cfg.d_model, d_in_proj, dtype=dtype),
        "conv_w": normal_init(ks[1], (cfg.d_conv, cfg.conv_dim), std=cfg.d_conv ** -0.5,
                              dtype=dtype),
        "conv_b": jnp.zeros((cfg.conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, cfg.n_heads)).astype(jnp.float32),
        "D": jnp.ones((cfg.n_heads,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[2], (cfg.n_heads,),
                                       minval=math.log(cfg.dt_min),
                                       maxval=math.log(cfg.dt_max))))).astype(jnp.float32),
        "out_norm": init_rmsnorm(cfg.d_inner, dtype),
        "out_proj": init_linear(ks[3], cfg.d_inner, cfg.d_model, dtype=dtype),
    }
    return p


# ---------------------------------------------------------------------------
# SSD core (chunked, jnp reference path — the Pallas kernel mirrors this)
# ---------------------------------------------------------------------------

def ssd_chunked(x, dt, A, B, C, chunk: int, initial_state=None,
                return_final_state: bool = False):
    """SSD over full sequence.

    x:  (b, s, h, p)   per-head inputs
    dt: (b, s, h)      positive step sizes (already softplus'd + biased)
    A:  (h,)           negative per-head decay
    B:  (b, s, g, n)   input projections (n = d_state), g groups
    C:  (b, s, g, n)
    returns y: (b, s, h, p) and optionally final state (b, h, p, n)
    """
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    if s % chunk != 0:
        pad = chunk - s % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    s_pad = x.shape[1]
    nc = s_pad // chunk
    # reshape into chunks
    xc = x.reshape(b, nc, chunk, h, p)
    dtc = dt.reshape(b, nc, chunk, h)
    Bc = B.reshape(b, nc, chunk, g, n)
    Cc = C.reshape(b, nc, chunk, g, n)
    hpg = h // g  # heads per group

    dA = dtc * A[None, None, None, :]            # (b, nc, l, h) negative
    dA_cum = jnp.cumsum(dA, axis=2)              # within-chunk cumulative log-decay

    # ---- intra-chunk (quadratic attention-like) term ----
    # L[i,j] = exp(dA_cum[i] - dA_cum[j]) for i >= j
    seg = dA_cum[:, :, :, None, :] - dA_cum[:, :, None, :, :]   # (b,nc,l,l,h)
    li = jnp.arange(chunk)
    causal = (li[:, None] >= li[None, :])[None, None, :, :, None]
    L = jnp.where(causal, jnp.exp(seg), 0.0)
    # scores: C_i . B_j  (group-shared across heads in group)
    CB = jnp.einsum("bclgn,bcmgn->bclmg", Cc.astype(jnp.float32), Bc.astype(jnp.float32))
    CB = jnp.repeat(CB, hpg, axis=-1)            # (b,nc,l,l,h)
    M = CB * L * dtc[:, :, None, :, :]           # weight by dt_j
    y_intra = jnp.einsum("bclmh,bcmhp->bclhp", M, xc.astype(jnp.float32))

    # ---- chunk states ----
    # state contribution of chunk c: sum_j exp(dA_cum[last] - dA_cum[j]) dt_j B_j x_j
    decay_to_end = jnp.exp(dA_cum[:, :, -1:, :] - dA_cum)       # (b,nc,l,h)
    # (grouped B broadcast over heads-in-group)
    Bh = jnp.repeat(Bc, hpg, axis=3) if g != h else Bc           # (b,nc,l,h,n)
    weighted_x = xc.astype(jnp.float32) * (dtc * decay_to_end)[..., None]
    states = jnp.einsum("bclhn,bclhp->bchpn", Bh.astype(jnp.float32), weighted_x)

    # ---- inter-chunk recurrence over nc chunk states ----
    chunk_decay = jnp.exp(jnp.sum(dA, axis=2))                   # (b, nc, h)

    def scan_fn(carry, inp):
        st, dec = inp                                            # (b,h,p,n), (b,h)
        new = carry * dec[:, :, None, None] + st
        return new, carry  # emit state *entering* this chunk

    init = (jnp.zeros((b, h, p, n), jnp.float32) if initial_state is None
            else initial_state.astype(jnp.float32))
    final_state, entering = jax.lax.scan(
        scan_fn, init,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    entering = jnp.moveaxis(entering, 0, 1)                      # (b,nc,h,p,n)

    # ---- inter-chunk output: y_j += C_j . (decay_from_start * state_in) ----
    decay_from_start = jnp.exp(dA_cum)                           # (b,nc,l,h)
    Ch = jnp.repeat(Cc, hpg, axis=3) if g != h else Cc           # (b,nc,l,h,n)
    y_inter = jnp.einsum("bclhn,bchpn->bclhp", Ch.astype(jnp.float32), entering)
    y_inter = y_inter * decay_from_start[..., None]

    y = (y_intra + y_inter).reshape(b, s_pad, h, p)[:, :s]
    if return_final_state:
        return y.astype(x.dtype), final_state
    return y.astype(x.dtype)


def ssd_reference(x, dt, A, B, C, initial_state=None):
    """Naive O(s·n) recurrence — oracle for tests (slow, exact)."""
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    hpg = h // g
    Bh = jnp.repeat(B, hpg, axis=2) if g != h else B
    Ch = jnp.repeat(C, hpg, axis=2) if g != h else C

    def step(state, inp):
        xt, dtt, Bt, Ct = inp  # (b,h,p), (b,h), (b,h,n), (b,h,n)
        decay = jnp.exp(dtt * A[None, :])                        # (b,h)
        state = state * decay[:, :, None, None] + \
            dtt[:, :, None, None] * xt[:, :, :, None] * Bt[:, :, None, :]
        y = jnp.einsum("bhpn,bhn->bhp", state, Ct)
        return state, y

    init = (jnp.zeros((b, h, p, n), jnp.float32) if initial_state is None
            else initial_state.astype(jnp.float32))
    final, ys = jax.lax.scan(
        step, init,
        (jnp.moveaxis(x, 1, 0).astype(jnp.float32),
         jnp.moveaxis(dt, 1, 0).astype(jnp.float32),
         jnp.moveaxis(Bh, 1, 0).astype(jnp.float32),
         jnp.moveaxis(Ch, 1, 0).astype(jnp.float32)))
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), final


# ---------------------------------------------------------------------------
# layer apply: full-sequence and single-step decode
# ---------------------------------------------------------------------------

def _split_proj(cfg: SSMConfig, zxbcdt: jnp.ndarray):
    di, g, n, nh = cfg.d_inner, cfg.n_groups, cfg.d_state, cfg.n_heads
    z, xBC, dt = jnp.split(zxbcdt, [di, di + cfg.conv_dim], axis=-1)
    return z, xBC, dt


def ssm_forward(p: Pytree, x: jnp.ndarray, cfg: SSMConfig,
                return_final_state: bool = False):
    """Full-sequence forward.  x: (B, S, d_model)."""
    Bsz, S, _ = x.shape
    z, xBC, dt = _split_proj(cfg, linear(p["in_proj"], x))
    # depthwise causal conv over sequence
    w = p["conv_w"].astype(xBC.dtype)                            # (k, conv_dim)
    pad = jnp.pad(xBC, ((0, 0), (cfg.d_conv - 1, 0), (0, 0)))
    conv = sum(pad[:, i:i + S] * w[i] for i in range(cfg.d_conv))
    xBC = jax.nn.silu(conv + p["conv_b"].astype(xBC.dtype))
    xs, Bmat, Cmat = jnp.split(xBC, [cfg.d_inner, cfg.d_inner + cfg.n_groups * cfg.d_state],
                               axis=-1)
    xs = xs.reshape(Bsz, S, cfg.n_heads, cfg.head_dim)
    Bmat = Bmat.reshape(Bsz, S, cfg.n_groups, cfg.d_state)
    Cmat = Cmat.reshape(Bsz, S, cfg.n_groups, cfg.d_state)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,nh)
    A = -jnp.exp(p["A_log"])

    if cfg.ssd_impl in ("pallas", "pallas_interpret"):
        from repro.kernels import ops as kops
        interp = cfg.ssd_impl == "pallas_interpret"
        if return_final_state:
            y, final = kops.ssd_with_state(xs, dt, A, Bmat, Cmat,
                                           chunk=cfg.chunk, interpret=interp)
        else:
            y = kops.ssd(xs, dt, A, Bmat, Cmat, chunk=cfg.chunk,
                         interpret=interp)
            final = None
    else:
        out = ssd_chunked(xs, dt, A, Bmat, Cmat, cfg.chunk,
                          return_final_state=return_final_state)
        y, final = out if return_final_state else (out, None)

    y = y + xs * p["D"][None, None, :, None].astype(xs.dtype)
    y = y.reshape(Bsz, S, cfg.d_inner)
    y = rmsnorm(p["out_norm"], y * jax.nn.silu(z))
    out = linear(p["out_proj"], y)
    if return_final_state:
        # decode conv state = last (d_conv-1) *pre-activation* xBC inputs
        return out, (final, _tail_conv_inputs(p, x, cfg))
    return out


def _tail_conv_inputs(p: Pytree, x: jnp.ndarray, cfg: SSMConfig) -> jnp.ndarray:
    """Last (d_conv-1) raw xBC inputs — the decode conv state."""
    _, xBC, _ = _split_proj(cfg, linear(p["in_proj"], x[:, -(cfg.d_conv - 1):]))
    return xBC


def init_ssm_state(cfg: SSMConfig, batch: int, dtype=jnp.float32):
    """Decode-time carried state: (ssm_state, conv_state)."""
    return (
        jnp.zeros((batch, cfg.n_heads, cfg.head_dim, cfg.d_state), jnp.float32),
        jnp.zeros((batch, cfg.d_conv - 1, cfg.conv_dim), dtype),
    )


def ssm_decode_step(p: Pytree, x: jnp.ndarray, state, cfg: SSMConfig):
    """Single-token decode.  x: (B, 1, d_model); state from init_ssm_state."""
    ssm_state, conv_state = state
    Bsz = x.shape[0]
    z, xBC, dt = _split_proj(cfg, linear(p["in_proj"], x))
    xBC = xBC[:, 0]                                              # (B, conv_dim)
    # roll conv state
    hist = jnp.concatenate([conv_state, xBC[:, None]], axis=1)   # (B, k, conv_dim)
    w = p["conv_w"].astype(xBC.dtype)
    conv = jnp.einsum("bkc,kc->bc", hist, w) + p["conv_b"].astype(xBC.dtype)
    act = jax.nn.silu(conv)
    xs, Bmat, Cmat = jnp.split(act, [cfg.d_inner, cfg.d_inner + cfg.n_groups * cfg.d_state],
                               axis=-1)
    xs = xs.reshape(Bsz, cfg.n_heads, cfg.head_dim)
    Bmat = Bmat.reshape(Bsz, cfg.n_groups, cfg.d_state)
    Cmat = Cmat.reshape(Bsz, cfg.n_groups, cfg.d_state)
    hpg = cfg.n_heads // cfg.n_groups
    Bh = jnp.repeat(Bmat, hpg, axis=1)
    Ch = jnp.repeat(Cmat, hpg, axis=1)
    dtv = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B, nh)
    A = -jnp.exp(p["A_log"])
    decay = jnp.exp(dtv * A[None, :])
    new_state = ssm_state * decay[:, :, None, None] + \
        dtv[:, :, None, None] * xs.astype(jnp.float32)[:, :, :, None] * \
        Bh.astype(jnp.float32)[:, :, None, :]
    y = jnp.einsum("bhpn,bhn->bhp", new_state, Ch.astype(jnp.float32)).astype(x.dtype)
    y = y + xs * p["D"][None, :, None].astype(x.dtype)
    y = y.reshape(Bsz, 1, cfg.d_inner)
    y = rmsnorm(p["out_norm"], y * jax.nn.silu(z))
    out = linear(p["out_proj"], y)
    return out, (new_state, hist[:, 1:])
