"""Core neural-net layers in pure JAX (pytree params, init/apply pairs).

Conventions:
- params are nested dicts of jnp arrays;
- ``init_*`` takes a PRNG key + shape info and returns params;
- ``*_apply`` is pure; dtype policy = params stay in ``param_dtype``,
  activations/compute run in ``dtype`` (usually bf16 on TPU, f32 on CPU).
- all matmul dims that land on the MXU should be multiples of 128 for the
  full-size configs; reduced smoke configs may be smaller.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


# ---------------------------------------------------------------------------
# activation-sharding anchor (§Perf)
#
# Under FSDP weights the SPMD partitioner may choose to REPLICATE the
# activation batch dim rather than all-gather a weight (observed on MLA:
# attention scores materialized with the full global batch per chip —
# 16× redundant compute).  The transformer entry points install the
# model's batch axes here when cfg.shard_activations is set; attention
# score/output tensors are then anchored batch-first and propagation
# keeps the rest sharded.
# ---------------------------------------------------------------------------

_ACT_BATCH_AXES: Optional[Tuple[str, ...]] = None


def set_activation_batch_axes(axes: Optional[Tuple[str, ...]]) -> None:
    global _ACT_BATCH_AXES
    _ACT_BATCH_AXES = tuple(axes) if axes else None


def anchor_batch(x: jnp.ndarray) -> jnp.ndarray:
    """Pin dim 0 (batch) of ``x`` to the installed mesh axes (no-op when
    no axes are installed)."""
    if _ACT_BATCH_AXES is None:
        return x
    from jax.sharding import PartitionSpec as P
    ax = _ACT_BATCH_AXES if len(_ACT_BATCH_AXES) > 1 else _ACT_BATCH_AXES[0]
    return jax.lax.with_sharding_constraint(
        x, P(ax, *([None] * (x.ndim - 1))))


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def he_normal(key, shape, dtype=jnp.float32, fan_in: Optional[int] = None):
    fan = fan_in if fan_in is not None else shape[0]
    std = math.sqrt(2.0 / max(fan, 1))
    return (jax.random.normal(key, shape) * std).astype(dtype)


def xavier_uniform(key, shape, dtype=jnp.float32):
    fan_in, fan_out = shape[0], shape[-1]
    lim = math.sqrt(6.0 / (fan_in + fan_out))
    return jax.random.uniform(key, shape, minval=-lim, maxval=lim).astype(dtype)


def normal_init(key, shape, std=0.02, dtype=jnp.float32):
    return (jax.random.normal(key, shape) * std).astype(dtype)


# ---------------------------------------------------------------------------
# linear / norm
# ---------------------------------------------------------------------------

def init_linear(key, d_in: int, d_out: int, bias: bool = False,
                dtype=jnp.float32, std: Optional[float] = None,
                lora_rank: int = 0) -> Pytree:
    """Plain linear, or — with ``lora_rank > 0`` — a LoRA-adapted linear
    (see :func:`init_lora_linear`)."""
    if lora_rank:
        return init_lora_linear(key, d_in, d_out, lora_rank, bias=bias,
                                dtype=dtype, std=std)
    wkey, _ = jax.random.split(key)
    w = normal_init(wkey, (d_in, d_out), std=std if std is not None else d_in ** -0.5,
                    dtype=dtype)
    p = {"w": w}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


# LoRA (Hu et al. 2106.09685): y = x·W + (α/r)·x·A·B with A (d_in, r)
# normal-init and B (r, d_out) ZERO-init, so the adapted model starts
# exactly at the base model.  α is the fixed library-style constant
# below; only A/B train under the "lora" trainable filter
# (repro.sharding.rules.TRAINABLE_FILTERS) — the base W stays frozen.
LORA_ALPHA = 16.0


def lora_scale(rank: int) -> float:
    return LORA_ALPHA / rank


def init_lora_linear(key, d_in: int, d_out: int, rank: int,
                     bias: bool = False, dtype=jnp.float32,
                     std: Optional[float] = None) -> Pytree:
    """LoRA-adapted linear: the base ``w`` (and optional ``b``) draw
    EXACTLY like :func:`init_linear` for the same key, plus ``lora_a``
    (normal, the key's unused split half) and ``lora_b`` (zeros) — so a
    LoRA model's forward at init equals the base model's bitwise."""
    if rank <= 0:
        raise ValueError(f"lora rank must be a positive integer, got {rank}")
    p = init_linear(key, d_in, d_out, bias=bias, dtype=dtype, std=std)
    _, akey = jax.random.split(key)
    p["lora_a"] = normal_init(akey, (d_in, rank), std=d_in ** -0.5,
                              dtype=dtype)
    p["lora_b"] = jnp.zeros((rank, d_out), dtype)
    return p


def linear(p: Pytree, x: jnp.ndarray, dtype=None) -> jnp.ndarray:
    dtype = dtype or x.dtype
    y = jnp.einsum("...d,df->...f", x, p["w"].astype(dtype))
    if "lora_a" in p:
        a, b = p["lora_a"].astype(dtype), p["lora_b"].astype(dtype)
        z = jnp.einsum("...d,dr->...r", x, a)
        y = y + lora_scale(a.shape[-1]) * jnp.einsum("...r,rf->...f", z, b)
    if "b" in p:
        y = y + p["b"].astype(dtype)
    return y


def merge_lora(p: Pytree) -> Pytree:
    """Fold a LoRA adapter into its base weight — ``W + (α/r)·A·B`` in
    f32, cast back to W's dtype — returning a PLAIN linear param dict
    (the inference/merge form; parity-tested against the adapter
    forward).  Recurses through nested dicts, so it merges a whole
    model tree."""
    if not isinstance(p, dict):
        return p
    if "lora_a" in p:
        a = p["lora_a"].astype(jnp.float32)
        b = p["lora_b"].astype(jnp.float32)
        w = p["w"].astype(jnp.float32) + lora_scale(a.shape[-1]) * (a @ b)
        out = {k: v for k, v in p.items() if k not in ("lora_a", "lora_b")}
        out["w"] = w.astype(p["w"].dtype)
        return out
    return {k: merge_lora(v) for k, v in p.items()}


def init_rmsnorm(d: int, dtype=jnp.float32) -> Pytree:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: Pytree, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def init_layernorm(d: int, dtype=jnp.float32) -> Pytree:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p: Pytree, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float = 10000.0) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 10000.0) -> jnp.ndarray:
    """x: (B, S, H, hd) or (B, S, hd); positions: (S,)."""
    assert positions.ndim == 1, positions.shape
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    angles = positions[:, None].astype(jnp.float32) * freqs  # (S, hd/2)
    if x.ndim == 4:  # insert head axis
        angles = angles[:, None, :]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (GQA, optional qk-norm / qkv-bias / sliding window)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10000.0
    window: Optional[int] = None  # sliding window; None = full causal
    attn_impl: str = "xla"  # xla | pallas | pallas_interpret
    lora_rank: int = 0  # > 0: LoRA-adapt the q/k/v/o projections


def init_attention(key, cfg: AttnConfig, dtype=jnp.float32) -> Pytree:
    ks = jax.random.split(key, 5)
    d, H, KH, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    r = cfg.lora_rank
    p = {
        "wq": init_linear(ks[0], d, H * hd, bias=cfg.qkv_bias, dtype=dtype,
                          lora_rank=r),
        "wk": init_linear(ks[1], d, KH * hd, bias=cfg.qkv_bias, dtype=dtype,
                          lora_rank=r),
        "wv": init_linear(ks[2], d, KH * hd, bias=cfg.qkv_bias, dtype=dtype,
                          lora_rank=r),
        "wo": init_linear(ks[3], H * hd, d, bias=False, dtype=dtype,
                          lora_rank=r),
    }
    if cfg.qk_norm:
        p["q_norm"] = init_rmsnorm(hd, dtype)
        p["k_norm"] = init_rmsnorm(hd, dtype)
    return p


def _sdpa(q, k, v, *, causal: bool, window: Optional[int], q_offset,
          impl: str = "xla") -> jnp.ndarray:
    """q: (B, S, H, hd); k/v: (B, T, KH, hd); GQA broadcast inside.

    q_offset: scalar position offset of q[0] relative to k[0] (decode).
    """
    if impl in ("pallas", "pallas_interpret"):
        from repro.kernels import ops as kops
        return kops.flash_attention(q, k, v, causal=causal, window=window,
                                    q_offset=q_offset,
                                    interpret=(impl == "pallas_interpret"))
    B, S, H, hd = q.shape
    T, KH = k.shape[1], k.shape[2]
    G = H // KH
    qg = q.reshape(B, S, KH, G, hd)
    scale = hd ** -0.5
    logits = anchor_batch(
        jnp.einsum("bskgd,btkd->bkgst", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale)
    qpos = q_offset + jnp.arange(S)
    kpos = jnp.arange(T)
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] > qpos[:, None] - window
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v.astype(jnp.float32))
    return out.reshape(B, S, H, hd).astype(q.dtype)


def attention(p: Pytree, x: jnp.ndarray, cfg: AttnConfig, positions: jnp.ndarray,
              kv_cache: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
              cache_len=None) -> Tuple[jnp.ndarray, Optional[Tuple]]:
    """Full-sequence (train/prefill) or incremental (decode) attention.

    kv_cache: (k_cache, v_cache) of shape (B, T_max, KH, hd).  When given,
    new k/v are inserted at ``cache_len`` and attention runs over the cache.
    Returns (out, new_cache).
    """
    B, S, d = x.shape
    H, KH, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = linear(p["wq"], x).reshape(B, S, H, hd)
    k = linear(p["wk"], x).reshape(B, S, KH, hd)
    v = linear(p["wv"], x).reshape(B, S, KH, hd)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q)
        k = rmsnorm(p["k_norm"], k)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    if kv_cache is None:
        out = _sdpa(q, k, v, causal=True, window=cfg.window, q_offset=0,
                    impl=cfg.attn_impl)
        new_cache = (k, v)
    else:
        kc, vc = kv_cache
        kc = jax.lax.dynamic_update_slice(kc, k.astype(kc.dtype), (0, cache_len, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v.astype(vc.dtype), (0, cache_len, 0, 0))
        out = _sdpa(q, kc, vc, causal=True, window=cfg.window, q_offset=cache_len,
                    impl=cfg.attn_impl)
        new_cache = (kc, vc)
    out = out.reshape(B, S, H * hd)
    return linear(p["wo"], out), new_cache


# ---------------------------------------------------------------------------
# MLA — multi-head latent attention (DeepSeek V2/V3)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MLAConfig:
    d_model: int
    n_heads: int
    kv_lora_rank: int          # 512
    q_lora_rank: Optional[int]  # None (v2-lite) or 1536 (v3)
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    rope_theta: float = 10000.0


def init_mla(key, cfg: MLAConfig, dtype=jnp.float32) -> Pytree:
    ks = jax.random.split(key, 8)
    d, H = cfg.d_model, cfg.n_heads
    qk_dim = cfg.qk_nope_dim + cfg.qk_rope_dim
    p = {}
    if cfg.q_lora_rank:
        p["wq_a"] = init_linear(ks[0], d, cfg.q_lora_rank, dtype=dtype)
        p["q_a_norm"] = init_rmsnorm(cfg.q_lora_rank, dtype)
        p["wq_b"] = init_linear(ks[1], cfg.q_lora_rank, H * qk_dim, dtype=dtype)
    else:
        p["wq"] = init_linear(ks[0], d, H * qk_dim, dtype=dtype)
    # joint KV compression + decoupled rope key
    p["wkv_a"] = init_linear(ks[2], d, cfg.kv_lora_rank + cfg.qk_rope_dim, dtype=dtype)
    p["kv_a_norm"] = init_rmsnorm(cfg.kv_lora_rank, dtype)
    p["wkv_b"] = init_linear(ks[3], cfg.kv_lora_rank,
                             H * (cfg.qk_nope_dim + cfg.v_head_dim), dtype=dtype)
    p["wo"] = init_linear(ks[4], H * cfg.v_head_dim, d, dtype=dtype)
    return p


def mla_attention(p: Pytree, x: jnp.ndarray, cfg: MLAConfig, positions: jnp.ndarray,
                  kv_cache: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
                  cache_len=None) -> Tuple[jnp.ndarray, Optional[Tuple]]:
    """MLA with latent-space cache: cache stores (c_kv, k_rope) only —
    (B, T, kv_lora_rank) + (B, T, qk_rope_dim) — the paper's memory win.
    """
    B, S, d = x.shape
    H = cfg.n_heads
    qk_dim = cfg.qk_nope_dim + cfg.qk_rope_dim

    if cfg.q_lora_rank:
        q = linear(p["wq_b"], rmsnorm(p["q_a_norm"], linear(p["wq_a"], x)))
    else:
        q = linear(p["wq"], x)
    q = q.reshape(B, S, H, qk_dim)
    q_nope, q_rope = jnp.split(q, [cfg.qk_nope_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kv_a = linear(p["wkv_a"], x)
    c_kv, k_rope = jnp.split(kv_a, [cfg.kv_lora_rank], axis=-1)
    c_kv = rmsnorm(p["kv_a_norm"], c_kv)                 # (B, S, r)
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)  # (B, S, rope_dim), shared across heads

    if kv_cache is not None:
        cc, kr = kv_cache
        cc = jax.lax.dynamic_update_slice(cc, c_kv.astype(cc.dtype), (0, cache_len, 0))
        kr = jax.lax.dynamic_update_slice(kr, k_rope.astype(kr.dtype), (0, cache_len, 0))
        c_kv, k_rope = cc, kr
        q_offset = cache_len
        new_cache = (cc, kr)
    else:
        q_offset = 0
        new_cache = (c_kv, k_rope)

    T = c_kv.shape[1]
    # expand latent -> per-head K_nope, V
    kv = linear(p["wkv_b"], c_kv).reshape(B, T, H, cfg.qk_nope_dim + cfg.v_head_dim)
    k_nope, v = jnp.split(kv, [cfg.qk_nope_dim], axis=-1)

    scale = qk_dim ** -0.5
    logits = anchor_batch(
        (jnp.einsum("bshd,bthd->bhst", q_nope.astype(jnp.float32),
                    k_nope.astype(jnp.float32)) +
         jnp.einsum("bshd,btd->bhst", q_rope.astype(jnp.float32),
                    k_rope.astype(jnp.float32))) * scale)
    qpos = q_offset + jnp.arange(S)
    mask = jnp.arange(T)[None, :] <= qpos[:, None]
    logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhst,bthd->bshd", probs, v.astype(jnp.float32))
    out = out.reshape(B, S, H * cfg.v_head_dim).astype(x.dtype)
    return linear(p["wo"], out), new_cache


# ---------------------------------------------------------------------------
# MLPs and MoE
# ---------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, dtype=jnp.float32,
             lora_rank: int = 0) -> Pytree:
    ks = jax.random.split(key, 3)
    return {
        "w_gate": init_linear(ks[0], d_model, d_ff, dtype=dtype,
                              lora_rank=lora_rank),
        "w_up": init_linear(ks[1], d_model, d_ff, dtype=dtype,
                            lora_rank=lora_rank),
        "w_down": init_linear(ks[2], d_ff, d_model, dtype=dtype,
                              lora_rank=lora_rank),
    }


def mlp(p: Pytree, x: jnp.ndarray) -> jnp.ndarray:
    """SwiGLU MLP (all assigned archs use gated MLPs)."""
    return linear(p["w_down"], jax.nn.silu(linear(p["w_gate"], x)) * linear(p["w_up"], x))


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    d_model: int
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0
    d_ff_shared: int = 0
    capacity_factor: float = 1.25
    router_scoring: str = "softmax"  # softmax (v2) | sigmoid (v3)
    aux_loss_coef: float = 0.001
    routed_scaling: float = 1.0


def init_moe(key, cfg: MoEConfig, dtype=jnp.float32) -> Pytree:
    ks = jax.random.split(key, 4)
    E, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff_expert
    std = d ** -0.5
    p = {
        "router": {"w": normal_init(ks[0], (d, E), std=std, dtype=jnp.float32)},
        "experts": {
            "w_gate": normal_init(ks[1], (E, d, f), std=std, dtype=dtype),
            "w_up": normal_init(jax.random.fold_in(ks[1], 1), (E, d, f), std=std, dtype=dtype),
            "w_down": normal_init(jax.random.fold_in(ks[1], 2), (E, f, d), std=f ** -0.5, dtype=dtype),
        },
    }
    if cfg.n_shared:
        p["shared"] = init_mlp(ks[2], d, cfg.d_ff_shared or cfg.d_ff_expert * cfg.n_shared,
                               dtype=dtype)
    return p


def moe(p: Pytree, x: jnp.ndarray, cfg: MoEConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Capacity-based top-k MoE with gather dispatch / scatter-add combine.

    Returns (out, aux_loss).  Expert weight arrays carry a leading E axis
    that shards over the mesh ``model`` axis (expert parallelism); XLA
    SPMD inserts the dispatch collectives.
    """
    B, S, d = x.shape
    T = B * S
    xt = x.reshape(T, d)
    E, K = cfg.n_experts, cfg.top_k

    router_logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), p["router"]["w"])
    if cfg.router_scoring == "sigmoid":
        scores = jax.nn.sigmoid(router_logits)
    else:
        scores = jax.nn.softmax(router_logits, axis=-1)
    topk_scores, topk_idx = jax.lax.top_k(scores, K)  # (T, K)
    # normalize selected weights (deepseek convention)
    topk_w = topk_scores / (jnp.sum(topk_scores, axis=-1, keepdims=True) + 1e-20)
    topk_w = topk_w * cfg.routed_scaling

    # ---- load-balance aux loss (Switch-style) ----
    probs_mean = jnp.mean(jax.nn.softmax(router_logits, axis=-1), axis=0)     # (E,)
    onehot = jax.nn.one_hot(topk_idx[:, 0], E, dtype=jnp.float32)
    frac_tokens = jnp.mean(onehot, axis=0)
    aux = cfg.aux_loss_coef * E * jnp.sum(frac_tokens * probs_mean)

    # ---- capacity dispatch ----
    C = max(int(math.ceil(K * T / E * cfg.capacity_factor)), 1)
    flat_expert = topk_idx.reshape(-1)                       # (T*K,)
    flat_w = topk_w.reshape(-1)
    # position of each (token, k) within its expert queue
    eo = jax.nn.one_hot(flat_expert, E, dtype=jnp.int32)     # (T*K, E)
    pos_in_expert = (jnp.cumsum(eo, axis=0) - eo)            # exclusive cumsum
    slot = jnp.sum(pos_in_expert * eo, axis=-1)              # (T*K,)
    keep = slot < C
    # scatter token vectors into (E, C, d)
    token_idx = jnp.repeat(jnp.arange(T), K)
    dst_e = jnp.where(keep, flat_expert, 0)
    dst_c = jnp.where(keep, slot, 0)
    buf = jnp.zeros((E, C, d), xt.dtype)
    contrib = jnp.where(keep[:, None], xt[token_idx], 0)
    buf = buf.at[dst_e, dst_c].add(contrib)

    # ---- expert computation: grouped SwiGLU GEMMs ----
    w = p["experts"]
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, w["w_gate"].astype(xt.dtype))) * \
        jnp.einsum("ecd,edf->ecf", buf, w["w_up"].astype(xt.dtype))
    y = jnp.einsum("ecf,efd->ecd", h, w["w_down"].astype(xt.dtype))

    # ---- combine: gather back + weight ----
    gathered = y[dst_e, dst_c]                               # (T*K, d)
    gathered = jnp.where(keep[:, None], gathered, 0) * flat_w[:, None].astype(xt.dtype)
    out = jnp.zeros((T, d), xt.dtype).at[token_idx].add(gathered)

    if cfg.n_shared:
        out = out + mlp(p["shared"], xt)
    return out.reshape(B, S, d), aux
