"""deepseek-v2-lite-16b — MoE, 27L d_model=2048 16H d_ff_expert=1408
vocab=102400; MLA kv_lora=512; 2 shared + 64 routed experts, top-6.
[arXiv:2405.04434]

Note: the assignment line reads "2 shared+160 routed top-6"; 160 routed
is the full DeepSeek-V2 (236B) figure — V2-LITE (the named 16B model,
and the "MoE 64e" in the same line) has 64 routed experts.  We follow
the model card: 64 routed, 2 shared, top-6, first layer dense-FFN
(d_ff=10944), MLA without q-LoRA.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.common import register_arch
from repro.models.transformer import TransformerConfig


def config() -> TransformerConfig:
    return TransformerConfig(
        name="deepseek-v2-lite-16b", arch_type="moe",
        n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=10944,                      # dense-FFN prefix layer
        vocab_size=102400,
        attention="mla", kv_lora_rank=512, q_lora_rank=None,
        qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
        n_experts=64, top_k=6, n_shared_experts=2,
        d_ff_expert=1408, d_ff_shared=2816, n_dense_layers=1,
        router_scoring="softmax", capacity_factor=1.25, aux_loss_coef=0.001,
        dtype=jnp.bfloat16, param_dtype=jnp.bfloat16, remat=True,
    )


def reduced() -> TransformerConfig:
    return TransformerConfig(
        name="deepseek-v2-lite-smoke", arch_type="moe",
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=4,
        d_ff=512, vocab_size=512,
        attention="mla", kv_lora_rank=64, q_lora_rank=None,
        qk_nope_dim=32, qk_rope_dim=16, v_head_dim=32,
        n_experts=4, top_k=2, n_shared_experts=1,
        d_ff_expert=128, d_ff_shared=128, n_dense_layers=1,
    )


register_arch("deepseek-v2-lite-16b")((config, reduced))
