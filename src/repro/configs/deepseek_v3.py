"""deepseek-v3-671b — MoE, 61L d_model=7168 128H d_ff_expert=2048
vocab=129280; MLA (kv_lora=512, q_lora=1536); 1 shared + 256 routed,
top-8, sigmoid router; MTP depth-1; first 3 layers dense (d_ff=18432).
[arXiv:2412.19437]
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.common import register_arch
from repro.models.transformer import TransformerConfig


def config() -> TransformerConfig:
    return TransformerConfig(
        name="deepseek-v3-671b", arch_type="moe",
        n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128,
        d_ff=18432,                      # dense-FFN prefix layers
        vocab_size=129280,
        attention="mla", kv_lora_rank=512, q_lora_rank=1536,
        qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
        n_experts=256, top_k=8, n_shared_experts=1,
        d_ff_expert=2048, d_ff_shared=2048, n_dense_layers=3,
        router_scoring="sigmoid", capacity_factor=1.25, aux_loss_coef=0.0001,
        mtp=True, mtp_loss_weight=0.3,
        dtype=jnp.bfloat16, param_dtype=jnp.bfloat16, remat=True,
    )


def reduced() -> TransformerConfig:
    return TransformerConfig(
        name="deepseek-v3-smoke", arch_type="moe",
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=4,
        d_ff=512, vocab_size=512,
        attention="mla", kv_lora_rank=64, q_lora_rank=96,
        qk_nope_dim=32, qk_rope_dim=16, v_head_dim=32,
        n_experts=4, top_k=2, n_shared_experts=1,
        d_ff_expert=128, d_ff_shared=128, n_dense_layers=1,
        router_scoring="sigmoid", mtp=True,
    )


register_arch("deepseek-v3-671b")((config, reduced))
