"""musicgen-medium — audio, 48L d_model=1536 24H (MHA kv=24) d_ff=6144
vocab=2048 — decoder-only over EnCodec tokens, 4 codebooks (delay
pattern), 4 parallel output heads.  [arXiv:2306.05284]

Per the assignment carve-out, the EnCodec frontend is a STUB:
``input_specs`` supplies precomputed frame embeddings (the sum of the 4
codebook embeddings, as MusicGen feeds its decoder); this config is the
transformer that consumes them (input_mode='embeddings') and predicts
all 4 codebooks per frame.  MusicGen's non-gated GELU FFN is mapped to
this codebase's SwiGLU at equal d_ff (hardware-equivalent GEMM shapes).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.common import register_arch
from repro.models.transformer import TransformerConfig


def config() -> TransformerConfig:
    return TransformerConfig(
        name="musicgen-medium", arch_type="audio",
        n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24, head_dim=64,
        d_ff=6144, vocab_size=2048,
        input_mode="embeddings", n_codebooks=4,
        dtype=jnp.bfloat16, param_dtype=jnp.bfloat16, remat=True,
    )


def reduced() -> TransformerConfig:
    return TransformerConfig(
        name="musicgen-smoke", arch_type="audio",
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=4, head_dim=64,
        d_ff=512, vocab_size=128,
        input_mode="embeddings", n_codebooks=4,
    )


register_arch("musicgen-medium")((config, reduced))
