"""Shared config machinery: assigned input shapes, ShapeDtypeStruct
input specs per (architecture × shape), and the arch registry.

The four assigned input shapes (public pool):

  train_4k     seq_len=  4,096  global_batch=256   training
  prefill_32k  seq_len= 32,768  global_batch= 32   inference prefill
  decode_32k   seq_len= 32,768  global_batch=128   inference decode (1 token)
  long_500k    seq_len=524,288  global_batch=  1   long-context decode

``input_specs`` produces weak-type-correct ShapeDtypeStruct stand-ins for
every model input — shardable, zero allocation — which is what the
multi-pod dry-run lowers against.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.transformer import TransformerConfig, init_decode_cache
from repro.utils.registry import Registry

Pytree = Any

ARCHS: Registry = Registry("architecture")


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}


def register_arch(name: str):
    """Register an arch module's (config, reduced) pair."""

    def deco(fns):
        ARCHS.register(name)(fns)
        return fns

    return deco


def get_config(name: str) -> TransformerConfig:
    return ARCHS.get(name)[0]()


def get_reduced(name: str) -> TransformerConfig:
    return ARCHS.get(name)[1]()


def list_archs():
    return ARCHS.names()


def with_peft(cfg: TransformerConfig, peft: Optional[str]) -> TransformerConfig:
    """Apply a PEFT spec to an arch config: ``"lora:<r>"`` builds the
    model with rank-``r`` adapters on every attention/MLP projection
    (repro.models.layers.init_lora_linear) so the trainable filter in
    repro.sharding.rules has leaves to match.  ``None`` is the identity."""
    if peft is None:
        return cfg
    from repro.fl.local import parse_peft
    kind, rank = parse_peft(peft)
    assert kind == "lora"           # parse_peft rejects everything else
    return dataclasses.replace(cfg, lora_rank=rank)


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: TransformerConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """ShapeDtypeStruct tree for the model-input batch of one step.

    train   : full-sequence tokens + labels
    prefill : full-sequence tokens (KV cache built inside the step)
    decode  : ONE new token + the KV/SSM cache at seq_len + cache_len
    """
    B, S = shape.global_batch, shape.seq_len
    tok = jnp.int32

    def text_batch(with_labels: bool):
        b = {"tokens": _sds((B, S), tok)}
        if with_labels:
            b["labels"] = _sds((B, S), tok)
        return b

    def vlm_batch(with_labels: bool):
        P = cfg.n_prefix_tokens
        b = {
            "patch_embeds": _sds((B, P, cfg.d_model), cfg.dtype),
            "tokens": _sds((B, S - P), tok),
        }
        if with_labels:
            b["labels"] = _sds((B, S - P), tok)
        return b

    def audio_batch(with_labels: bool):
        b = {"frame_embeds": _sds((B, S, cfg.d_model), cfg.dtype)}
        if with_labels:
            shape_l = (B, S, cfg.n_codebooks) if cfg.n_codebooks > 1 else (B, S)
            b["labels"] = _sds(shape_l, tok)
        return b

    builders = {"tokens": text_batch, "vlm": vlm_batch, "embeddings": audio_batch}
    build = builders[cfg.input_mode]

    if shape.kind == "train":
        return build(True)
    if shape.kind == "prefill":
        return build(False)
    # decode: one token against a cache of size seq_len
    cache = jax.eval_shape(
        functools.partial(init_decode_cache, cfg, B, S))
    if cfg.input_mode == "embeddings":
        token = _sds((B, 1, cfg.d_model), cfg.dtype)
    else:
        token = _sds((B, 1), tok)
    return {"token": token, "cache": cache, "cache_len": _sds((), tok)}


def params_specs(cfg: TransformerConfig) -> Pytree:
    """Abstract parameter tree (no allocation) via eval_shape."""
    from repro.models.transformer import init_lm
    return jax.eval_shape(lambda k: init_lm(k, cfg), jax.random.PRNGKey(0))


def param_count(cfg: TransformerConfig) -> int:
    import math
    tree = params_specs(cfg)
    return sum(int(math.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree))


def active_param_count(cfg: TransformerConfig) -> int:
    """Per-token active parameters — MoE counts top_k (+shared) experts
    only; used for MODEL_FLOPS = 6·N_active·D in the roofline."""
    import math
    total = param_count(cfg)
    if not cfg.is_moe:
        return total
    tree = params_specs(cfg)
    expert_leaves = 0
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    for path, leaf in flat:
        keys = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        if "experts" in keys:
            expert_leaves += int(math.prod(leaf.shape))
    inactive_frac = 1.0 - cfg.top_k / cfg.n_experts
    return int(total - expert_leaves * inactive_frac)
