"""internvl2-1b — VLM, 24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151655 (Qwen2-0.5B language backbone).  [arXiv:2404.16821]

Per the assignment carve-out, the InternViT-300M vision frontend is a
STUB: ``input_specs`` supplies precomputed patch embeddings (256 tokens,
d_model-sized, post-projector) and this config implements the decoder
that consumes them (input_mode='vlm').
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.common import register_arch
from repro.models.transformer import TransformerConfig


def config() -> TransformerConfig:
    return TransformerConfig(
        name="internvl2-1b", arch_type="vlm",
        n_layers=24, d_model=896, n_heads=14, n_kv_heads=2, head_dim=64,
        d_ff=4864, vocab_size=151655,
        qkv_bias=True, rope_theta=1_000_000.0, tie_embeddings=True,
        input_mode="vlm", n_prefix_tokens=256,
        dtype=jnp.bfloat16, param_dtype=jnp.bfloat16, remat=True,
    )


def reduced() -> TransformerConfig:
    return TransformerConfig(
        name="internvl2-1b-smoke", arch_type="vlm",
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=2, head_dim=64,
        d_ff=512, vocab_size=512, qkv_bias=True, tie_embeddings=True,
        input_mode="vlm", n_prefix_tokens=16,
    )


register_arch("internvl2-1b")((config, reduced))
