"""mamba2-1.3b — attention-free SSM (SSD), 48L d_model=2048 vocab=50280,
ssm_state=128, head_dim=64, expand=2, groups=1.  [arXiv:2405.21060]

O(1)-state decode ⇒ the flagship ``long_500k`` architecture.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.common import register_arch
from repro.models.transformer import TransformerConfig


def config() -> TransformerConfig:
    return TransformerConfig(
        name="mamba2-1.3b", arch_type="ssm",
        n_layers=48, d_model=2048, n_heads=0, n_kv_heads=0,
        d_ff=0, vocab_size=50280, attention="none",
        ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_n_groups=1,
        ssm_chunk=128,
        dtype=jnp.bfloat16, param_dtype=jnp.bfloat16, remat=True,
    )


def reduced() -> TransformerConfig:
    return TransformerConfig(
        name="mamba2-smoke", arch_type="ssm",
        n_layers=2, d_model=256, n_heads=0, n_kv_heads=0,
        d_ff=0, vocab_size=512, attention="none",
        ssm_state=32, ssm_head_dim=32, ssm_expand=2, ssm_n_groups=1,
        ssm_chunk=32,
    )


register_arch("mamba2-1.3b")((config, reduced))
