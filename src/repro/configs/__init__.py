"""Assigned-architecture configs (public-literature pool).

10 assigned archs + 1 beyond-assignment SWA variant; each module carries
the exact assigned hyperparameters and its source citation, plus a
``reduced()`` smoke variant (≤2 layers, d_model≤512, ≤4 experts) that
runs a forward/train step on CPU.

``long_500k`` eligibility (sub-quadratic decode, DESIGN.md §4):
mamba2-1.3b, hymba-1.5b, tinyllama-1.1b-swa.
"""
from repro.configs.common import (
    ARCHS,
    SHAPES,
    ShapeSpec,
    batch_specs,
    params_specs,
    param_count,
    active_param_count,
    get_config,
    get_reduced,
    list_archs,
    with_peft,
)

# import for registration side effects
from repro.configs import (  # noqa: F401
    qwen3_32b,
    qwen15_05b,
    qwen2_15b,
    tinyllama_11b,
    deepseek_v2_lite,
    deepseek_v3,
    internvl2_1b,
    hymba_15b,
    mamba2_13b,
    musicgen_medium,
)

ASSIGNED = (
    "qwen3-32b", "qwen1.5-0.5b", "deepseek-v2-lite-16b", "internvl2-1b",
    "qwen2-1.5b", "hymba-1.5b", "deepseek-v3-671b", "mamba2-1.3b",
    "musicgen-medium", "tinyllama-1.1b",
)

# archs that may run the long_500k decode shape (sub-quadratic decode)
LONG_CONTEXT_OK = ("mamba2-1.3b", "hymba-1.5b", "tinyllama-1.1b-swa")


def shape_applicable(arch: str, shape: str) -> bool:
    """True if (arch, shape) is a runnable pair per DESIGN.md §4."""
    if shape == "long_500k":
        return arch in LONG_CONTEXT_OK
    return True
