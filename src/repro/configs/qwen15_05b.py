"""qwen1.5-0.5b — dense, 24L d_model=1024 16H (kv=16, MHA) d_ff=2816
vocab=151936, QKV bias.  [hf:Qwen/Qwen1.5-0.5B]"""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.common import register_arch
from repro.models.transformer import TransformerConfig


def config() -> TransformerConfig:
    return TransformerConfig(
        name="qwen1.5-0.5b", arch_type="dense",
        n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64,
        d_ff=2816, vocab_size=151936,
        qkv_bias=True, rope_theta=10_000.0, tie_embeddings=True,
        dtype=jnp.bfloat16, param_dtype=jnp.bfloat16, remat=True,
    )


def reduced() -> TransformerConfig:
    return TransformerConfig(
        name="qwen1.5-0.5b-smoke", arch_type="dense",
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=4, head_dim=64,
        d_ff=512, vocab_size=512, qkv_bias=True, tie_embeddings=True,
    )


register_arch("qwen1.5-0.5b")((config, reduced))
