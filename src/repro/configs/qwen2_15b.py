"""qwen2-1.5b — dense, 28L d_model=1536 12H (GQA kv=2) d_ff=8960
vocab=151936, QKV bias.  [arXiv:2407.10671]"""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.common import register_arch
from repro.models.transformer import TransformerConfig


def config() -> TransformerConfig:
    return TransformerConfig(
        name="qwen2-1.5b", arch_type="dense",
        n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2, head_dim=128,
        d_ff=8960, vocab_size=151936,
        qkv_bias=True, rope_theta=1_000_000.0, tie_embeddings=True,
        dtype=jnp.bfloat16, param_dtype=jnp.bfloat16, remat=True,
    )


def reduced() -> TransformerConfig:
    return TransformerConfig(
        name="qwen2-1.5b-smoke", arch_type="dense",
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=2, head_dim=64,
        d_ff=512, vocab_size=512, qkv_bias=True, tie_embeddings=True,
    )


register_arch("qwen2-1.5b")((config, reduced))
