"""hymba-1.5b — hybrid, 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16 — parallel attention + mamba heads in every
block (outputs averaged), sliding-window attention with 3 global-attn
layers (first / middle / last).  [arXiv:2411.13676]

Sub-quadratic (SWA + O(1) SSM state) ⇒ eligible for ``long_500k``.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.common import register_arch
from repro.models.transformer import TransformerConfig


def config() -> TransformerConfig:
    return TransformerConfig(
        name="hymba-1.5b", arch_type="hybrid",
        n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, head_dim=64,
        d_ff=5504, vocab_size=32001,
        window=1024, global_attn_layers=(0, 15, 31),
        ssm_state=16, ssm_head_dim=64, ssm_expand=2, ssm_n_groups=1,
        dtype=jnp.bfloat16, param_dtype=jnp.bfloat16, remat=True,
    )


def reduced() -> TransformerConfig:
    return TransformerConfig(
        name="hymba-1.5b-smoke", arch_type="hybrid",
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=2, head_dim=64,
        d_ff=512, vocab_size=512,
        window=32, global_attn_layers=(0,),
        ssm_state=16, ssm_head_dim=32, ssm_expand=2, ssm_n_groups=1,
        ssm_chunk=32,
    )


register_arch("hymba-1.5b")((config, reduced))
