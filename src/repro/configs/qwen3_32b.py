"""qwen3-32b — dense, 64L d_model=5120 64H (GQA kv=8) d_ff=25600
vocab=151936, qk_norm, head_dim=128.  [hf:Qwen/Qwen3-8B family card,
scaled per assignment]"""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.common import register_arch
from repro.models.transformer import TransformerConfig


def config() -> TransformerConfig:
    return TransformerConfig(
        name="qwen3-32b", arch_type="dense",
        n_layers=64, d_model=5120, n_heads=64, n_kv_heads=8, head_dim=128,
        d_ff=25600, vocab_size=151936,
        qk_norm=True, qkv_bias=False, rope_theta=1_000_000.0,
        dtype=jnp.bfloat16, param_dtype=jnp.bfloat16, remat=True,
    )


def reduced() -> TransformerConfig:
    return TransformerConfig(
        name="qwen3-32b-smoke", arch_type="dense",
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=2, head_dim=64,
        d_ff=512, vocab_size=512, qk_norm=True,
    )


register_arch("qwen3-32b")((config, reduced))
