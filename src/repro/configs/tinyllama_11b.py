"""tinyllama-1.1b — dense llama2-arch, 22L d_model=2048 32H (GQA kv=4)
d_ff=5632 vocab=32000.  [arXiv:2401.02385]

An extra sliding-window variant ``tinyllama-1.1b-swa`` (window=4096) is
registered as a beyond-assignment arch: it legitimately runs the
``long_500k`` decode shape (O(window) KV cache), whereas the assigned
full-attention variant skips it (DESIGN.md §long_500k).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.configs.common import register_arch
from repro.models.transformer import TransformerConfig


def config() -> TransformerConfig:
    return TransformerConfig(
        name="tinyllama-1.1b", arch_type="dense",
        n_layers=22, d_model=2048, n_heads=32, n_kv_heads=4, head_dim=64,
        d_ff=5632, vocab_size=32000,
        rope_theta=10_000.0,
        dtype=jnp.bfloat16, param_dtype=jnp.bfloat16, remat=True,
    )


def reduced() -> TransformerConfig:
    return TransformerConfig(
        name="tinyllama-1.1b-smoke", arch_type="dense",
        n_layers=2, d_model=256, n_heads=8, n_kv_heads=2, head_dim=32,
        d_ff=512, vocab_size=512,
    )


def config_swa() -> TransformerConfig:
    return dataclasses.replace(config(), name="tinyllama-1.1b-swa",
                               window=4096, global_attn_layers=())


def reduced_swa() -> TransformerConfig:
    return dataclasses.replace(reduced(), name="tinyllama-1.1b-swa-smoke",
                               window=64, global_attn_layers=())


register_arch("tinyllama-1.1b")((config, reduced))
register_arch("tinyllama-1.1b-swa")((config_swa, reduced_swa))
