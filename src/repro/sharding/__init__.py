from repro.sharding.rules import (
    DATA, MODEL, POD,
    param_pspecs, param_shardings,
    batch_pspecs, batch_shardings,
    replicated,
)
