"""Parameter/batch partition rules: FSDP (data) × tensor/expert (model).

Layout policy (MaxText-style logical rules, expressed as path-pattern →
PartitionSpec):

  embeddings / lm_head  : vocab on ``model``, d_model on ``data``
  attention / MLP in-proj: d_in on ``data`` (FSDP), d_out heads/ffn on
                           ``model`` (TP)
  out-proj / down-proj   : transposed — contraction dim on ``model``
  MoE experts            : expert axis on ``model`` (expert parallelism),
                           d_model on ``data``
  SSM                    : in/out projections like MLP; conv + per-head
                           scalars on ``model``'s head shards
  norms / small vectors  : replicated

Every rule degrades gracefully: if a dim is not divisible by the mesh
axis it falls back to replication on that axis, so the same rules drive
the 16×16 pod, the 2×16×16 multi-pod and single-device CPU tests.
Stacked scan layers (leading L axis) are handled by left-padding specs
with None to the leaf rank.

The rules match by PATH SUFFIX, so they also shard any pytree whose
leaves mirror the param tree under a wrapper prefix — in particular the
server-optimizer ``OptState`` (repro.optim.optimizers): a momentum/adam
moment at ``inner/.../wq/w`` gets the same spec as the param it tracks,
and non-mirroring leaves (the scalar step count) fall through every
rule to replication.  repro.fl.pod leans on this to place FedAvgM /
FedAdam state (``server_state_shardings``) without a second rule table.
"""
from __future__ import annotations

import re
from typing import Any, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Pytree = Any

DATA, MODEL, POD = "data", "model", "pod"

# (path regex, spec for the leaf's LOGICAL (unstacked) trailing dims)
_PARAM_RULES: Tuple[Tuple[str, Tuple[Optional[str], ...]], ...] = (
    (r"(^|/)embed$",                          (MODEL, DATA)),
    (r"(^|/)lm_head$",                        (DATA, MODEL)),
    # LoRA adapter factors (models.layers.init_lora_linear): A (d_in, r)
    # shards its d_in like an in-projection's FSDP dim, B (r, d_out) its
    # d_out like a TP output dim — the tiny rank dim replicates (and the
    # divisibility fallback replicates either when the dims are small)
    (r"/lora_a$",                             (DATA, None)),
    (r"/lora_b$",                             (None, MODEL)),
    # fused in-projections: (d_in, d_out) with d_out sharded over model
    (r"(wq|wk|wv|wq_a|wq_b|wkv_a|wkv_b|w_gate|w_up|in_proj|proj)/w$", (DATA, MODEL)),
    # out-projections: contraction dim over model
    (r"(wo|w_down|out_proj)/w$",              (MODEL, DATA)),
    # MoE expert banks: expert-parallel over model
    (r"experts/w_gate$",                      (MODEL, DATA, None)),
    (r"experts/w_up$",                        (MODEL, DATA, None)),
    (r"experts/w_down$",                      (MODEL, None, DATA)),
    (r"router/w$",                            (None, None)),
    # ssm conv + per-head params follow the d_inner/model sharding
    (r"conv_w$",                              (None, MODEL)),
    (r"conv_b$",                              (MODEL,)),
    (r"(A_log|D|dt_bias)$",                   (None,)),
    # in-proj biases live on the model-sharded output dim
    (r"(wq|wk|wv|w_gate|w_up|in_proj)/b$",    (MODEL,)),
    (r"/b$",                                  (None,)),
    (r"(scale|bias)$",                        (None,)),
)


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def _axis_size(ax, axis_sizes: dict) -> int:
    if isinstance(ax, (tuple, list)):
        n = 1
        for a in ax:
            n *= axis_sizes.get(a, 1)
        return n
    return axis_sizes.get(ax, 1)


def _fit_axes(spec: Sequence, shape: Tuple[int, ...], axis_sizes: dict) -> P:
    """Left-pad to rank; drop axes that don't divide the dim evenly.
    Entries may be axis names or tuples of axis names (combined axes)."""
    spec = list(spec)
    pad = len(shape) - len(spec)
    if pad < 0:                       # leaf smaller than rule (degenerate)
        spec = spec[-len(shape):] if shape else []
        pad = 0
    full = [None] * pad + spec
    out = []
    for dim, ax in zip(shape, full):
        n = _axis_size(ax, axis_sizes)
        if ax is not None and n > 1 and dim % n == 0:
            out.append(tuple(ax) if isinstance(ax, (tuple, list)) else ax)
        else:
            out.append(None)
    return P(*out)


# Layouts (beyond-paper perf knob — EXPERIMENTS.md §Perf):
#   fsdp_tp   : the default above — FSDP over ``data``, tensor/expert
#               parallel over ``model``.  Right for big models; for small
#               ones the per-layer TP activation all-reduce dominates.
#   fsdp_only : NO tensor parallelism — every ``model``-axis rule entry
#               becomes the combined (data, model) FSDP axis, so params
#               are sharded 256-way and the only collectives are the
#               per-step param all-gather + grad reduce-scatter.
#   replicated: params fully replicated (inference layout for models
#               that fit HBM — removes the per-use FSDP all-gather
#               entirely; batch still shards over all axes).
LAYOUTS = ("fsdp_tp", "fsdp_only", "replicated")


def _apply_layout(rule: Sequence, layout: str, mesh: Mesh) -> Sequence:
    if layout == "fsdp_tp" or layout not in LAYOUTS:
        return rule
    if layout == "replicated":
        return [None] * len(rule)
    combined = tuple(a for a in ("pod", "data", "model")
                     if a in mesh.axis_names)
    out = []
    used = False
    for ax in rule:
        if ax in (DATA, MODEL) and not used:
            out.append(combined)      # first shardable dim gets full FSDP
            used = True
        else:
            out.append(None)
    return out


def param_pspecs(params_tree: Pytree, mesh: Mesh,
                 layout: str = "fsdp_tp") -> Pytree:
    """PartitionSpec tree matching ``params_tree`` (abstract or concrete)."""
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_tree)
    specs = []
    for path, leaf in flat:
        ps = _path_str(path)
        shape = tuple(leaf.shape)
        for pattern, rule in _PARAM_RULES:
            if re.search(pattern, ps):
                specs.append(_fit_axes(_apply_layout(rule, layout, mesh),
                                       shape, axis_sizes))
                break
        else:
            specs.append(P())         # unmatched: replicate
    return jax.tree_util.tree_unflatten(treedef, specs)


def param_shardings(params_tree: Pytree, mesh: Mesh,
                    layout: str = "fsdp_tp") -> Pytree:
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s),
                                  param_pspecs(params_tree, mesh, layout))


# ---------------------------------------------------------------------------
# trainable-slice filters (federated PEFT)
# ---------------------------------------------------------------------------
#
# A trainable filter is a path pattern selecting which leaves the
# flat-first FL path optimizes and communicates; everything else packs
# into read-only "frozen:" buckets that never enter the kernels, the
# donated carry, or the wire (repro.utils.flatten).  Filters match by
# path suffix exactly like the param rules above, so a filter written
# against the model's param paths also selects the mirroring leaves of
# any wrapper pytree.

TRAINABLE_FILTERS = {
    # LoRA adapters: only the A/B factors train; every base weight
    # stays frozen (models.layers.init_lora_linear)
    "lora": r"/(lora_a|lora_b)$",
    # head-only fine-tuning: output head (+ tied embedding) and final norm
    "head": r"((^|/)(embed|lm_head)|norm_f/(scale|bias))$",
}


def resolve_trainable_filter(filter_spec: Optional[str]) -> Optional[str]:
    """A named filter resolves to its registered path regex; anything
    else is taken as a path regex verbatim."""
    if filter_spec is None:
        return None
    return TRAINABLE_FILTERS.get(filter_spec, filter_spec)


def trainable_mask(tree: Pytree,
                   filter_spec: Optional[str]) -> Optional[Tuple[bool, ...]]:
    """Per-leaf trainable booleans in ``tree_flatten`` order for a
    path-pattern filter (the ``filter=`` argument of
    ``FlatView.of`` / ``ShardedFlatView.of``).  ``None`` means no filter
    — every leaf trains, and the views compile to the exact unfiltered
    program.  A filter that selects zero leaves is a config error
    (nothing would train), raised here at construction time."""
    pattern = resolve_trainable_filter(filter_spec)
    if pattern is None:
        return None
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    mask = tuple(bool(re.search(pattern, _path_str(path))) for path, _ in flat)
    if flat and not any(mask):
        raise ValueError(
            f"trainable filter {filter_spec!r} (pattern {pattern!r}) "
            f"matches zero leaves of the param tree — nothing would "
            f"train; check the filter against the model's param paths "
            f"(a LoRA filter needs a model built with lora_rank > 0)")
    return mask


# ---------------------------------------------------------------------------
# batch / cache sharding
# ---------------------------------------------------------------------------

def _batch_axes(mesh: Mesh, layout: str = "fsdp_tp") -> Tuple[str, ...]:
    if layout in ("fsdp_only", "replicated"):
        return tuple(a for a in (POD, DATA, MODEL) if a in mesh.axis_names)
    return tuple(a for a in (POD, DATA) if a in mesh.axis_names)


def batch_pspecs(batch_tree: Pytree, mesh: Mesh,
                 layout: str = "fsdp_tp") -> Pytree:
    """Shard every batch/cache leaf's leading batch dim over (pod, data)
    — or over ALL axes for the fsdp_only layout; when the batch dim is
    too small (long_500k B=1), fall back to sharding the sequence dim
    over ``data`` so giant KV caches still distribute."""
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    baxes = _batch_axes(mesh, layout)
    b_total = 1
    for a in baxes:
        b_total *= axis_sizes[a]

    def leaf_spec(leaf):
        shape = tuple(leaf.shape)
        if not shape:
            return P()
        if shape[0] % b_total == 0 and shape[0] >= b_total:
            return P(baxes if len(baxes) > 1 else baxes[0],
                     *([None] * (len(shape) - 1)))
        # sequence-dim fallback (dim 1 = time for caches / long decode)
        if len(shape) >= 2 and shape[1] % axis_sizes.get(DATA, 1) == 0 \
                and shape[1] >= axis_sizes.get(DATA, 1) and axis_sizes.get(DATA, 1) > 1:
            return P(None, DATA, *([None] * (len(shape) - 2)))
        return P()

    return jax.tree_util.tree_map(leaf_spec, batch_tree)


def batch_shardings(batch_tree: Pytree, mesh: Mesh,
                    layout: str = "fsdp_tp") -> Pytree:
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s),
                                  batch_pspecs(batch_tree, mesh, layout))


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


# ---------------------------------------------------------------------------
# sharded flat buffers (the pod's fused flat-first carries)
# ---------------------------------------------------------------------------

def sharded_flat_view(params_tree: Pytree, mesh: Mesh,
                      layout: str = "fsdp_tp",
                      filter_spec: Optional[str] = None):
    """ShardedFlatView for ``params_tree`` under this mesh + layout:
    leaves bucket per (dtype, mesh-axis group) straight from the
    :func:`param_pspecs` rules, so packing preserves exactly the FSDP×TP
    decomposition the per-leaf path would use — each device ends up with
    one contiguous local buffer per bucket (see
    repro.utils.flatten.ShardedFlatView).  ``filter_spec`` (a trainable
    filter, see :func:`trainable_mask`) partitions the leaves into
    trainable buckets and read-only ``frozen:`` buckets that keep the
    same per-group FSDP×TP decomposition."""
    from repro.utils.flatten import ShardedFlatView
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return ShardedFlatView.of(params_tree,
                              param_pspecs(params_tree, mesh, layout),
                              axis_sizes,
                              filter=trainable_mask(params_tree, filter_spec))


def flat_buffer_pspec(group) -> P:
    """PartitionSpec for one ShardGroup's ``(n_shards, per_shard)``
    buffer: the shard axis over the group's mesh axes, per-shard data
    unsharded."""
    if not group.axes:
        return P(None, None)
    entry = group.axes if len(group.axes) > 1 else group.axes[0]
    return P(entry, None)


def flat_param_shardings(view, mesh: Mesh) -> dict:
    """NamedSharding per TRAINABLE bucket for a ShardedFlatView's
    buffers — the placement of the engine's donated flat carries."""
    return {g.name: NamedSharding(mesh, flat_buffer_pspec(g))
            for g in view.trainable_groups}


def frozen_flat_shardings(view, mesh: Mesh) -> dict:
    """NamedSharding per FROZEN bucket: the read-only constant bucket a
    filtered run closes over keeps the same per-group FSDP×TP
    decomposition as the trainable carries (frozen leaves shard instead
    of replicating — the big frozen base is exactly what must not be
    resident per device)."""
    return {g.name: NamedSharding(mesh, flat_buffer_pspec(g))
            for g in view.frozen_groups}


def mesh_axis_size(mesh: Mesh, axis: str = DATA) -> int:
    """Size of a named mesh axis (1 when the axis is absent)."""
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(axis, 1)


def lane_axis_pspec(leaf_rank: int = 3) -> P:
    """Hierarchical-aggregation lane buffers ``(G, n_shards,
    per_shard)``: the pod-lane axis shards over the mesh ``data`` axis —
    one pod per data shard — while each lane's flat tile stays whole
    (replicated over the remaining axes) so the per-lane
    ``fused_delta_accum`` is shard-local and the cross-pod combine is
    one ``psum`` over ``data``."""
    return P(DATA, *([None] * (leaf_rank - 1)))


def lane_shardings(view, mesh: Mesh) -> dict:
    """NamedSharding per (trainable) bucket for lane-stacked ``(G,
    n_shards, per_shard)`` accumulators — deltas only ever cover the
    optimized slice."""
    return {g.name: NamedSharding(mesh, lane_axis_pspec())
            for g in view.trainable_groups}


# ---------------------------------------------------------------------------
# federated batch / client-stack sharding (pod round programs)
# ---------------------------------------------------------------------------

def fl_batch_pspec(mesh: Mesh, leaf_rank: int, batch_axis: int = 2) -> P:
    """Client batch stacks: shard ONE batch-like axis over (pod, data).

    The pre-sampled round layout is ``(K, t_i, B, ...)`` — K and t_i are
    schedule axes (K is scanned sequentially; t_i is the SGD step axis)
    so the per-step batch dim B (axis 2, the default) is the one that
    distributes.  The engine's on-device-sampling layout is
    ``(n_clients, n_per_client, ...)`` where the sample pool (axis 1) is
    the batch-like axis — pass ``batch_axis=1`` for it.
    """
    baxes = tuple(a for a in (POD, DATA) if a in mesh.axis_names)
    ax = baxes if len(baxes) > 1 else baxes[0]
    spec = [None] * leaf_rank
    if leaf_rank > batch_axis:
        spec[batch_axis] = ax
    return P(*spec)


def fl_batch_shardings(batch_tree: Pytree, mesh: Mesh,
                       batch_axis: int = 2) -> Pytree:
    return jax.tree_util.tree_map(
        lambda leaf: NamedSharding(
            mesh, fl_batch_pspec(mesh, len(leaf.shape), batch_axis)),
        batch_tree)


def client_axis_pspec(mesh: Mesh, leaf_rank: int, n_clients: int) -> P:
    """Stacked per-client leaves ``(n_clients, ...)``: shard the leading
    client axis over the mesh ``data`` axis (replicate when the client
    count does not divide it — the same graceful degradation as the
    param rules, so 1-device test meshes stay bit-compatible)."""
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n = axis_sizes.get(DATA, 1)
    if leaf_rank < 1 or n <= 1 or n_clients % n != 0 or n_clients < n:
        return P(*([None] * leaf_rank))
    return P(DATA, *([None] * (leaf_rank - 1)))


def client_axis_shardings(tree: Pytree, mesh: Mesh) -> Pytree:
    """NamedSharding tree for client-stacked leaves (shape-aware: dim 0
    is the client axis)."""
    return jax.tree_util.tree_map(
        lambda leaf: NamedSharding(
            mesh, client_axis_pspec(mesh, len(leaf.shape), leaf.shape[0])),
        tree)


# ---------------------------------------------------------------------------
# decode-cache sharding
# ---------------------------------------------------------------------------

def cache_pspecs(cache_tree: Pytree, mesh: Mesh, batch_size: int) -> Pytree:
    """Generic KV/SSM-cache layout.

    Leaves are either per-layer ``(B, ...)`` or scan-stacked ``(L, B, ...)``.
    Policy: shard the batch dim over (pod, data); then shard ONE more dim
    over ``model`` — the largest trailing dim divisible by the axis (KV
    heads, MLA latent rank, SSM head dim, or the time axis when batch is
    too small to shard, e.g. long_500k's B=1 giant cache).
    """
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    baxes = _batch_axes(mesh)
    b_total = 1
    for a in baxes:
        b_total *= axis_sizes[a]
    m = axis_sizes.get(MODEL, 1)

    def leaf_spec(leaf):
        shape = tuple(leaf.shape)
        if not shape:
            return P()
        # locate the batch dim: 0 for per-layer leaves, 1 for scan-stacked
        bdim = None
        if shape[0] == batch_size:
            bdim = 0
        elif len(shape) > 1 and shape[1] == batch_size:
            bdim = 1
        spec: list = [None] * len(shape)
        if bdim is not None and batch_size % b_total == 0 and batch_size >= b_total:
            spec[bdim] = baxes if len(baxes) > 1 else baxes[0]
        # one model-sharded dim: the largest eligible dim after the batch dim
        if m > 1:
            start = (bdim + 1) if bdim is not None else 1
            cands = [(shape[d], d) for d in range(start, len(shape))
                     if shape[d] % m == 0 and shape[d] >= m]
            if cands:
                spec[max(cands)[1]] = MODEL
        return P(*spec)

    return jax.tree_util.tree_map(leaf_spec, cache_tree)


def cache_shardings(cache_tree: Pytree, mesh: Mesh, batch_size: int) -> Pytree:
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s),
                                  cache_pspecs(cache_tree, mesh, batch_size))
