# One-command entry points for the tier-1 verify recipe and quick benches.
PY := python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-fast verify docs-check bench-quick bench-engine bench-pod bench-fused bench-store bench-pipeline bench-compress bench-peft

test:            ## tier-1 suite (ROADMAP verify command)
	$(PY) -m pytest -x -q

test-fast:       ## tier-1 minus tests marked slow
	$(PY) -m pytest -x -q -m "not slow"

docs-check:      ## verify README/docs path:symbol references resolve
	$(PY) tools/check_docs.py

verify: test docs-check  ## tier-1 suite + docs reference check

bench-quick:     ## minutes-scale sanity benchmark (Table II subset)
	$(PY) -m benchmarks.run --only table2 --scale quick

bench-engine:    ## round-engine dispatch benchmark (chunk 1/4/16)
	$(PY) -m benchmarks.perf_round_engine

bench-pod:       ## pod-backend dispatch benchmark (chunked vs per-round)
	$(PY) -m benchmarks.perf_pod_round

bench-fused:     ## fused flat-buffer update kernels vs tree_math
	$(PY) -m benchmarks.perf_fused_update

bench-store:     ## client-state store scaling (dense vs sparse)
	$(PY) -m benchmarks.perf_client_store

bench-pipeline:  ## overlapped round pipeline vs synchronous (sparse store)
	$(PY) -m benchmarks.perf_pipeline

bench-compress:  ## compressed client uploads vs baseline (wire + throughput)
	$(PY) -m benchmarks.perf_compression

bench-peft:      ## trainable-slice (LoRA) rounds vs full fine-tune
	$(PY) -m benchmarks.perf_peft
