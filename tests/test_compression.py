"""Compressed communication (repro.fl.compression) — parity + accounting.

The contract under test, layer by layer:

  - kernel parity: the blocked Pallas compress kernel == the pure-jnp
    ``reference_compress`` == the NumPy ground truth ``numpy_compress``,
    BITWISE, across sizes / bits / densities (plus hypothesis sweeps
    when installed: quantization error ≤ wire-scale/2, identity specs
    bit-exact);
  - engine parity: the identity spec compiles to the exact baseline
    program (bitwise, fused AND tree); lossy fused == lossy tree
    bitwise (same flat buckets, same accumulation order);
  - error feedback: residual rows ride the ClientStateStore contract —
    sparse == dense bitwise across LRU eviction/spill, sync and
    overlapped; EF-FedAvg tracks the uncompressed run within tolerance;
  - wire accounting: ``CommLedger`` totals == the closed forms exactly,
    and the int8 dense upload ratio clears the ≥3.9× gate;
  - invalid combos fail loudly AT CONSTRUCTION with actionable messages;
  - (slow) a 16-fake-device subprocess run: compressed hierarchical ==
    compressed sequential on a real 4×4 mesh, identity == baseline
    bitwise on the pod.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import comm_accounting as acc
from repro.core.comm_accounting import CommLedger
from repro.data.federated import FederatedDataset
from repro.fl import compression as comp
from repro.fl.compression import CompressionSpec
from repro.fl.engine import (
    AggregateStrategy,
    DenseClientStateStore,
    RelayStrategy,
    RoundSchedule,
    SparseClientStateStore,
    run_rounds,
)
from repro.fl.local import LocalSpec, host_flat_ops
from repro.fl.pod import PodAggregateStrategy, PodFLSpec
from repro.fl.privacy import DPSpec
from repro.fl.simulation import FLConfig
from repro.fl.task import vision_task
from repro.kernels import ops
from repro.launch.mesh import make_host_mesh

SEED = 0
N_CLIENTS = 8

try:
    from hypothesis import given, settings
    from hypothesis import strategies as hst
    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# kernel ↔ jnp reference ↔ NumPy oracle, bitwise
# ---------------------------------------------------------------------------

def _kernel_compress(d, spec):
    """The blocked kernel, called the way FlatParamOps.compress_delta
    calls it (threshold computed outside, logical k)."""
    d = jnp.asarray(d, jnp.float32)
    tau = (comp.topk_threshold(d, comp.topk_k(spec, d.shape[-1]))
           if spec.sparsifies else jnp.float32(0.0))
    c, r = ops.fused_compress_delta(d, tau, bits=spec.bits,
                                    topk=spec.sparsifies,
                                    with_residual=True, interpret=True)
    return np.asarray(c), np.asarray(r)


def _check_parity(d, spec):
    c_np, r_np = comp.numpy_compress(d, spec)
    c_ref, r_ref = comp.reference_compress(jnp.asarray(d), spec)
    c_k, r_k = _kernel_compress(d, spec)
    np.testing.assert_array_equal(c_np, np.asarray(c_ref))
    np.testing.assert_array_equal(r_np, np.asarray(r_ref))
    np.testing.assert_array_equal(c_np, c_k)
    np.testing.assert_array_equal(r_np, r_k)
    np.testing.assert_array_equal(r_np, d.astype(np.float32) - c_np)
    return c_np, r_np


def _delta(n, seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    d = (rng.normal(size=n) * scale).astype(np.float32)
    if n >= 256:
        d[128:256] = 0.0        # a whole zero block → guarded divide path
    return d


@pytest.mark.parametrize("n", [1, 7, 128, 1000, 1024, 5000])
@pytest.mark.parametrize("bits", [8, 16, 32])
@pytest.mark.parametrize("density", [1.0, 0.25])
def test_kernel_matches_numpy_oracle(n, bits, density):
    spec = CompressionSpec(bits=bits, density=density)
    if spec.identity:
        pytest.skip("identity spec never reaches the kernel")
    d = _delta(n, seed=n + bits)
    c, _ = _check_parity(d, spec)
    if spec.sparsifies:
        # the threshold mask keeps AT LEAST k elements (ties kept) and
        # only elements at/above the k-th largest magnitude
        k = comp.topk_k(spec, n)
        tau = np.partition(np.abs(d), n - k)[n - k]
        kept = np.flatnonzero(c)
        assert len(np.flatnonzero(np.abs(d) >= tau)) >= k
        assert np.all(np.abs(d[kept]) >= tau)


def test_quantization_error_bounded_by_half_scale():
    """SCALE_PAD rounds the bf16 wire scale UP, so no value clips and
    the per-element error is ≤ scale/2 (round-half-even)."""
    from repro.kernels.fused_update import LANES, QMAX, SCALE_PAD
    import ml_dtypes
    for bits in (8, 16):
        spec = CompressionSpec(bits=bits)
        d = _delta(1000, seed=bits, scale=3.0)
        c, _ = _check_parity(d, spec)
        rows = -(-1000 // LANES)
        xb = np.pad(d, (0, rows * LANES - 1000)).reshape(rows, LANES)
        amax = np.max(np.abs(xb), axis=-1, keepdims=True)
        scale = ((amax / np.float32(QMAX[bits])) * np.float32(SCALE_PAD)) \
            .astype(ml_dtypes.bfloat16).astype(np.float32)
        err = np.abs(xb - np.pad(c, (0, rows * LANES - 1000))
                     .reshape(rows, LANES))
        assert np.all(err <= 0.5 * scale * (1 + 1e-6) + 1e-30)
        assert np.all(scale * np.float32(QMAX[bits]) >= amax)  # no clipping


def test_zero_delta_compresses_to_zero():
    for spec in (CompressionSpec(bits=8), CompressionSpec(density=0.5),
                 CompressionSpec(bits=16, density=0.5)):
        c, r = _check_parity(np.zeros(300, np.float32), spec)
        assert not c.any() and not r.any()


def test_padded_buffer_with_logical_k_is_exact():
    """Zero padding changes neither τ nor block scales: compressing the
    padded buffer with a LOGICAL k equals compressing the logical
    prefix (the invariant the padded engine carries rely on)."""
    spec = CompressionSpec(bits=8, density=0.5)
    n, padded_n = 700, 1024
    d = _delta(n, seed=3)
    dp_ = np.zeros(padded_n, np.float32)
    dp_[:n] = d
    c_logical, _ = comp.numpy_compress(d, spec)
    c_padded, _ = comp.numpy_compress(dp_, spec, logical_size=n)
    np.testing.assert_array_equal(c_padded[:n], c_logical)
    assert not c_padded[n:].any()


def test_error_feedback_mass_is_deferred_not_lost():
    """T rounds of compress(δ + r) with a CONSTANT per-round delta: the
    cumulative compressed sum tracks T·δ with error = |r_T|, bounded
    independent of T — without EF the sparsification error grows ∝ T."""
    spec = CompressionSpec(bits=8, density=0.25, error_feedback=True)
    d = _delta(512, seed=5)
    r = np.zeros_like(d)
    total = np.zeros_like(d)
    T = 12
    for _ in range(T):
        c, r = comp.numpy_compress(d + r, spec)
        total += c
    ef_err = np.max(np.abs(total - T * d))
    # Σc telescopes to T·δ − r_T (up to f32 rounding of the running sum)
    np.testing.assert_allclose(total, T * d - r, atol=1e-5, rtol=0)
    c1, _ = comp.numpy_compress(d, spec)
    no_ef_err = T * np.max(np.abs(d - c1))
    assert ef_err < 0.25 * no_ef_err


# ---------------------------------------------------------------------------
# hypothesis sweeps (skipped cleanly when hypothesis is absent)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    @settings(max_examples=60, deadline=None)
    @given(n=hst.integers(1, 2048),
           bits=hst.sampled_from([8, 16, 32]),
           density=hst.floats(0.01, 1.0),
           seed=hst.integers(0, 2**31 - 1),
           scale_pow=hst.integers(-8, 8))
    def test_hypothesis_roundtrip_parity(n, bits, density, seed, scale_pow):
        spec = CompressionSpec(bits=bits, density=density)
        d = _delta(n, seed=seed, scale=float(2.0 ** scale_pow))
        if spec.identity:
            c, r = comp.numpy_compress(d, spec)
            np.testing.assert_array_equal(c, d.astype(np.float32))
            assert not r.any()
            return
        _check_parity(d, spec)

    @settings(max_examples=40, deadline=None)
    @given(n=hst.integers(1, 1024), bits=hst.sampled_from([8, 16]),
           seed=hst.integers(0, 2**31 - 1))
    def test_hypothesis_quantization_error_half_scale(n, bits, seed):
        from repro.kernels.fused_update import LANES, QMAX, SCALE_PAD
        import ml_dtypes
        spec = CompressionSpec(bits=bits)
        d = _delta(n, seed=seed)
        c, _ = comp.numpy_compress(d, spec)
        rows = -(-n // LANES)
        xb = np.pad(d, (0, rows * LANES - n)).reshape(rows, LANES)
        amax = np.max(np.abs(xb), axis=-1, keepdims=True)
        scale = ((amax / np.float32(QMAX[bits])) * np.float32(SCALE_PAD)) \
            .astype(ml_dtypes.bfloat16).astype(np.float32)
        err = np.abs(xb - np.pad(c, (0, rows * LANES - n)).reshape(rows,
                                                                   LANES))
        assert np.all(err <= 0.5 * scale * (1 + 1e-6) + 1e-30)
else:                                                 # pragma: no cover
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_hypothesis_roundtrip_parity():
        pass


# ---------------------------------------------------------------------------
# wire accounting — closed forms and the ledger
# ---------------------------------------------------------------------------

def test_payload_bytes_closed_forms():
    assert comp.payload_bytes(None, (1000,)) == 4000
    assert comp.payload_bytes(CompressionSpec(), (1000,)) == 4000
    # int8 dense: 1 byte/elt + one bf16 scale per 128-lane block
    assert comp.payload_bytes(CompressionSpec(bits=8), (1000,)) == \
        1000 + 2 * 8
    # top-k: bits/8 per kept + int32 coordinate per kept
    s = CompressionSpec(density=0.25)
    assert comp.payload_bytes(s, (1000,)) == 250 * 4 + 250 * 4
    both = CompressionSpec(bits=8, density=0.25)
    assert comp.payload_bytes(both, (1000,)) == 250 + 250 * 4 + 2 * 8
    assert comp.payload_bytes(both, (0, 1000)) == \
        comp.payload_bytes(both, (1000,))


def test_int8_dense_ratio_clears_the_gate():
    """bf16 block scales keep the int8 dense upload ratio at
    4/(1 + 2/128) ≈ 3.94 ≥ 3.9 — f32 scales would cap it at 3.88."""
    ratio = comp.payload_ratio(CompressionSpec(bits=8), (1 << 20,))
    assert ratio >= 3.9, ratio


def test_topk_k_edges():
    assert comp.topk_k(CompressionSpec(density=1e-9), 1000) == 1
    assert comp.topk_k(CompressionSpec(density=1.0), 1000) == 1000
    assert comp.topk_k(CompressionSpec(density=0.5), 3) == 2
    assert comp.topk_k(CompressionSpec(density=0.5), 1) == 1


# ---------------------------------------------------------------------------
# construction-time validation
# ---------------------------------------------------------------------------

LOSSY = CompressionSpec(bits=8)


def _lspec(**kw):
    return LocalSpec(n_steps=1, batch_size=4, lr=0.1, **kw)


def test_spec_rejects_bad_bits():
    with pytest.raises(ValueError, match="bits must be one of 8\\|16\\|32"):
        CompressionSpec(bits=12)


@pytest.mark.parametrize("density", [0.0, -0.1, 1.5])
def test_spec_rejects_bad_density(density):
    with pytest.raises(ValueError, match="density must be in \\(0, 1\\]"):
        CompressionSpec(density=density)


def test_spec_rejects_ef_on_identity():
    with pytest.raises(ValueError, match="error_feedback=True needs lossy"):
        CompressionSpec(error_feedback=True)


def test_local_spec_rejects_secure_agg_plus_lossy():
    with pytest.raises(ValueError, match="pairwise masks cancel only"):
        _lspec(secure_agg=True, compression=LOSSY)


def test_local_spec_rejects_dp_plus_lossy():
    with pytest.raises(ValueError, match="dp is incompatible"):
        _lspec(dp=DPSpec(1.0, 0.1), compression=LOSSY)


def test_fl_config_rejects_invalid_combo_at_construction():
    with pytest.raises(ValueError, match="pairwise masks cancel only"):
        FLConfig(secure_agg=True, compression=LOSSY)


def test_relay_strategy_rejects_lossy_compression():
    with pytest.raises(ValueError, match="P2 round deltas only"):
        RelayStrategy(spec=_lspec(compression=LOSSY))


def test_pod_spec_rejects_tree_plus_lossy():
    with pytest.raises(ValueError, match="fused flat path"):
        PodFLSpec(update_impl="tree", compression=LOSSY)
    with pytest.raises(ValueError, match="fused flat path"):
        PodAggregateStrategy(spec=_lspec(compression=LOSSY),
                             mesh=make_host_mesh())


# ---------------------------------------------------------------------------
# engine-level parity (host)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def setup():
    task = vision_task("mlp", in_ch=1, seed_kwargs={"img": 8, "d_hidden": 16})
    rng = np.random.default_rng(SEED)
    per = 16
    x = rng.normal(size=(N_CLIENTS, per, 8, 8, 1)).astype(np.float32)
    y = rng.integers(0, 10, size=(N_CLIENTS, per)).astype(np.int32)
    data = FederatedDataset(x=x, y=y,
                            n_real=np.full((N_CLIENTS,), per, np.int32),
                            test_x=x[0], test_y=y[0], n_classes=10,
                            name="compression-test")
    return task, data


def _run_host(task, data, *, compression=None, impl="fused_interpret",
              algo="fedavg", store=None, rounds=6, ledger=None,
              overlap=False):
    spec = LocalSpec(n_steps=2, batch_size=4, lr=0.05,
                     variant="scaffold" if algo == "scaffold" else "plain",
                     update_impl=impl, compression=compression)
    kw = {"state_store": store} if store is not None else {}
    strat = AggregateStrategy(spec=spec, algorithm=algo,
                              participation=0.25, **kw)
    sched = RoundSchedule(rounds=rounds, lr_decay=1.0, eval_every=0,
                          seed=SEED, chunk_size=2, sampling="host",
                          host_rng_offset=17, overlap=overlap)
    return run_rounds(task, data, strat, sched, ledger=ledger)


def _assert_same_run(a, b, *, bitwise=True, state=False):
    la = [h["local_loss"] for h in a.history]
    lb = [h["local_loss"] for h in b.history]
    if bitwise:
        np.testing.assert_array_equal(la, lb)
    else:
        np.testing.assert_allclose(la, lb, atol=5e-5, rtol=0)
    for x, y in zip(jax.tree_util.tree_leaves(a.params),
                    jax.tree_util.tree_leaves(b.params)):
        if bitwise:
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        else:
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       atol=5e-5, rtol=0)
    if state:
        for x, y in zip(jax.tree_util.tree_leaves(a.algo_state),
                        jax.tree_util.tree_leaves(b.algo_state)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.mark.parametrize("impl", ["fused_interpret", "tree"])
def test_identity_compression_is_baseline_bitwise(setup, impl):
    task, data = setup
    base = _run_host(task, data, compression=None, impl=impl)
    ident = _run_host(task, data, compression=CompressionSpec(), impl=impl)
    _assert_same_run(base, ident, state=True)


@pytest.mark.parametrize("spec", [
    CompressionSpec(bits=8),
    CompressionSpec(density=0.5),
    CompressionSpec(bits=8, density=0.5, error_feedback=True),
], ids=["int8", "topk", "int8+topk+ef"])
def test_lossy_fused_matches_tree_bitwise(setup, spec):
    """Compression is defined on the flat buckets, so the tree path (the
    parity oracle, via reference_compress) and the fused kernel path
    agree BITWISE — same blocks, same accumulation order."""
    task, data = setup
    fused = _run_host(task, data, compression=spec)
    tree = _run_host(task, data, compression=spec, impl="tree")
    _assert_same_run(fused, tree)


@pytest.mark.parametrize("algo", ["fedavg", "scaffold"])
def test_ef_residuals_sparse_equals_dense_bitwise(setup, algo):
    """EF residual rows ride the ClientStateStore contract: the sparse
    active-set table (capacity forcing eviction + spill + refault across
    every dispatch) carries them bitwise-identically to the dense
    stacks, sync and overlapped."""
    task, data = setup
    spec = CompressionSpec(bits=8, density=0.5, error_feedback=True)
    dense = _run_host(task, data, compression=spec, algo=algo,
                      store=DenseClientStateStore())
    assert "ef_residuals" in dense.algo_state
    for overlap in (False, True):
        sparse = _run_host(task, data, compression=spec, algo=algo,
                           store=SparseClientStateStore(capacity=4),
                           overlap=overlap)
        _assert_same_run(dense, sparse)


def test_ef_fedavg_tracks_uncompressed(setup):
    """int8+EF FedAvg stays close to the uncompressed run — quantization
    error is ≤ half a block scale per element and EF defers the rest."""
    task, data = setup
    base = _run_host(task, data, compression=None)
    ef = _run_host(task, data,
                   compression=CompressionSpec(bits=8, error_feedback=True))
    np.testing.assert_allclose([h["local_loss"] for h in base.history],
                               [h["local_loss"] for h in ef.history],
                               atol=0.05, rtol=0)
    for a, b in zip(jax.tree_util.tree_leaves(base.params),
                    jax.tree_util.tree_leaves(ef.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-3, rtol=0)


def test_ledger_matches_closed_form_and_clears_ratio_gate(setup):
    task, data = setup
    spec = CompressionSpec(bits=8)
    led = CommLedger()
    rounds = 4
    _run_host(task, data, compression=spec, rounds=rounds, ledger=led)
    view = host_flat_ops(task, True).view
    sizes = tuple(view.buffer_sizes.values())
    payload = comp.payload_bytes(spec, sizes)
    x = led.summary()["model_bytes"]
    assert x == 4 * sum(sizes)          # f32 model, logical bytes
    k = 2                               # participation 0.25 of 8 clients
    assert led.p2_bytes == rounds * acc.compressed_round_bytes(
        "fedavg", k, x, payload)
    assert led.p2_upload_bytes == rounds * k * payload
    assert led.summary()["payload_ratio"] == x / payload
    assert led.summary()["payload_ratio"] >= 3.9


# ---------------------------------------------------------------------------
# (slow) pod: compressed hierarchical == compressed sequential, 16 devices
# ---------------------------------------------------------------------------

_COMPRESS_SUBPROCESS_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    import jax
    import numpy as np
    from repro.data.federated import FederatedDataset
    from repro.fl.compression import CompressionSpec
    from repro.fl.engine import RoundSchedule, run_rounds
    from repro.fl.local import LocalSpec
    from repro.fl.pod import PodAggregateStrategy, ShardedSparseClientStateStore
    from repro.fl.task import vision_task

    mesh = jax.make_mesh((4, 4), ("data", "model"))
    task = vision_task("mlp", in_ch=1, seed_kwargs={"img": 8, "d_hidden": 16})
    rng = np.random.default_rng(0)
    N, per = 8, 16
    x = rng.normal(size=(N, per, 8, 8, 1)).astype(np.float32)
    y = rng.integers(0, 10, size=(N, per)).astype(np.int32)
    data = FederatedDataset(x=x, y=y, n_real=np.full((N,), per, np.int32),
                            test_x=x[0], test_y=y[0], n_classes=10,
                            name="compress-pod")
    sched = RoundSchedule(rounds=4, lr_decay=1.0, eval_every=0, seed=0,
                          chunk_size=2, sampling="host", host_rng_offset=17)

    def run(aggregation, compression):
        spec = LocalSpec(n_steps=2, batch_size=4, lr=0.05, variant="plain",
                         update_impl="fused_interpret",
                         compression=compression)
        strat = PodAggregateStrategy(
            spec=spec, algorithm="fedavg", mesh=mesh, clients_per_round=4,
            aggregation=aggregation, n_pods=4,
            state_store=ShardedSparseClientStateStore(capacity=8, mesh=mesh))
        return run_rounds(task, data, strat, sched)

    comp = CompressionSpec(bits=8, density=0.5, error_feedback=True)
    seq = run("sequential", comp)
    hier = run("hierarchical", comp)     # G=4 sharded lanes + one psum
    np.testing.assert_allclose(
        [h["local_loss"] for h in seq.history],
        [h["local_loss"] for h in hier.history], atol=5e-5, rtol=0)
    for a, b in zip(jax.tree_util.tree_leaves(seq.params),
                    jax.tree_util.tree_leaves(hier.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-5, rtol=0)

    # identity spec == baseline, BITWISE, on the sharded backend too
    base = run("hierarchical", None)
    ident = run("hierarchical", CompressionSpec())
    np.testing.assert_array_equal(
        [h["local_loss"] for h in base.history],
        [h["local_loss"] for h in ident.history])
    for a, b in zip(jax.tree_util.tree_leaves(base.params),
                    jax.tree_util.tree_leaves(ident.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    print("POD_COMPRESS_SUBPROCESS_OK")
""")


@pytest.mark.slow
def test_pod_compressed_hierarchical_matches_sequential_16dev_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", _COMPRESS_SUBPROCESS_SCRIPT],
                         capture_output=True, text=True, env=env,
                         timeout=420)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "POD_COMPRESS_SUBPROCESS_OK" in out.stdout
