"""Secure-aggregation simulation (repro.fl.privacy pairwise masks).

The mechanism: clients i < j share the per-round pair key
``fold_in(fold_in(fold_in(rk, MASK_TAG), i), j)``; both draw the same
``z`` and add ``+z`` (lower id) / ``−z`` (higher id) to their weighted
uploads.  Antisymmetry ``m_ij = −m_ji`` is BITWISE (shared key + sign
convention); the per-client masks therefore telescope to zero over a
full participant set up to float reassociation, and a masked round is
numerically the unmasked round on host, pod, and the hierarchical
psum-lowered combine (16-fake-device subprocess).
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.federated import FederatedDataset
from repro.fl import privacy
from repro.fl.engine import RoundSchedule, run_rounds
from repro.fl.local import FlatParamOps, LocalSpec
from repro.fl.pod import PodAggregateStrategy
from repro.fl.simulation import FLConfig, run_federated
from repro.fl.task import vision_task
from repro.utils.flatten import FlatView

SEED = 0


# ---------------------------------------------------------------------------
# the mask algebra itself
# ---------------------------------------------------------------------------

def test_pair_key_and_sign_antisymmetry_bitwise():
    mk = privacy.mask_base_key(jax.random.PRNGKey(3))
    kij = privacy.pair_mask_key(mk, jnp.int32(2), jnp.int32(7))
    kji = privacy.pair_mask_key(mk, jnp.int32(7), jnp.int32(2))
    np.testing.assert_array_equal(np.asarray(kij), np.asarray(kji))
    assert float(privacy.pair_sign(2, 7)) == 1.0
    assert float(privacy.pair_sign(7, 2)) == -1.0
    assert float(privacy.pair_sign(5, 5)) == 0.0
    # distinct pairs draw from distinct keys
    other = privacy.pair_mask_key(mk, jnp.int32(2), jnp.int32(6))
    assert (np.asarray(kij) != np.asarray(other)).any()


def _tree():
    ks = jax.random.split(jax.random.PRNGKey(11), 2)
    return {"w": jax.random.normal(ks[0], (17, 33)),
            "b": jax.random.normal(ks[1], (65,))}


def test_full_participation_masks_sum_to_zero_tree_and_flat():
    tree = _tree()
    mk = privacy.mask_base_key(jax.random.PRNGKey(4))
    ids = jnp.asarray([9, 2, 5, 0, 7])

    def tree_zeros():
        return jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), tree)

    masks = [privacy.client_mask(mk, cid, ids,
                                 lambda k: privacy.tree_normal(k, tree),
                                 tree_zeros)
             for cid in np.asarray(ids)]
    total = jax.tree_util.tree_map(lambda *ms: sum(ms), *masks)
    for leaf, src in zip(jax.tree_util.tree_leaves(total),
                         jax.tree_util.tree_leaves(tree)):
        # each pair contributes +z and −z; only reassociation survives
        np.testing.assert_allclose(np.asarray(leaf),
                                   np.zeros(src.shape, np.float32),
                                   atol=1e-5)
    # a 2-client set cancels BITWISE: m_i = +z, m_j = −z exactly
    pair = jnp.asarray([3, 8])
    mi = privacy.client_mask(mk, pair[0], pair,
                             lambda k: privacy.tree_normal(k, tree),
                             tree_zeros)
    mj = privacy.client_mask(mk, pair[1], pair,
                             lambda k: privacy.tree_normal(k, tree),
                             tree_zeros)
    for a, b in zip(jax.tree_util.tree_leaves(mi),
                    jax.tree_util.tree_leaves(mj)):
        np.testing.assert_array_equal(np.asarray(a), -np.asarray(b))

    # flat buffers draw the same bits per parameter (single draws are
    # bitwise twins; the scan-accumulated mask is compared at ulp level
    # because XLA fuses the draw pipeline into the scan body differently
    # per representation — fma contraction in erfinv)
    view = FlatView.of(tree)
    fops = FlatParamOps(view=view, interpret=True)
    k01 = privacy.pair_mask_key(mk, ids[0], ids[1])
    np.testing.assert_array_equal(
        np.asarray(fops.normal(k01)["float32"]),
        np.asarray(fops.pad(view.flatten(privacy.tree_normal(k01, tree)))
                   ["float32"]))
    flat = privacy.client_mask(mk, ids[0], ids, fops.normal,
                               lambda: fops.zeros(jnp.float32))
    packed = fops.pad(view.flatten(
        jax.tree_util.tree_map(lambda x: x.astype(jnp.float32), masks[0])))
    for name in flat:
        np.testing.assert_allclose(np.asarray(flat[name]),
                                   np.asarray(packed[name]),
                                   atol=2e-6, rtol=2e-6)


def test_masked_aggregate_equals_unmasked_host():
    # direct aggregate-level check: masks change nothing but fp order
    tree = _tree()
    K = 4
    w_locals = jax.tree_util.tree_map(
        lambda p: p[None] + 0.1 * jax.random.normal(
            jax.random.PRNGKey(21), (K,) + p.shape), tree)
    weights = jnp.asarray([1.0, 2.0, 3.0, 4.0])
    ids = jnp.asarray([6, 1, 4, 2])
    rk = jax.random.PRNGKey(5)
    base = privacy.tree_dp_aggregate(None, False, rk, ids, tree,
                                     w_locals, weights)
    masked = privacy.tree_dp_aggregate(None, True, rk, ids, tree,
                                       w_locals, weights)
    for a, b in zip(jax.tree_util.tree_leaves(base),
                    jax.tree_util.tree_leaves(masked)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)

    view = FlatView.of(tree)
    fops = FlatParamOps(view=view, interpret=True)
    fused = fops.unflatten(privacy.fused_dp_aggregate(
        None, True, fops, rk, ids, fops.flatten(tree),
        view.flatten_stacked(w_locals), weights))
    for a, b in zip(jax.tree_util.tree_leaves(masked),
                    jax.tree_util.tree_leaves(fused)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


# ---------------------------------------------------------------------------
# engine runs: masked == unmasked on host and pod
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def vision_setup():
    rng = np.random.default_rng(SEED)
    N, per = 8, 16
    x = rng.normal(size=(N, per, 8, 8, 1)).astype(np.float32)
    y = rng.integers(0, 10, size=(N, per)).astype(np.int32)
    data = FederatedDataset(x=x, y=y, n_real=np.full((N,), per, np.int32),
                            test_x=x[0], test_y=y[0], n_classes=10,
                            name="secure-agg-test")
    task = vision_task("mlp", in_ch=1, seed_kwargs={"img": 8, "d_hidden": 16})
    return task, data


@pytest.mark.parametrize("update_impl", ["tree", "fused_interpret"])
def test_masked_run_matches_unmasked_host(vision_setup, update_impl):
    task, data = vision_setup

    def run(**kw):
        return run_federated(task, data, FLConfig(
            rounds=3, chunk_size=3, participation=0.5, local_steps=2,
            batch_size=8, lr=0.05, eval_every=0, seed=SEED,
            update_impl=update_impl, **kw))

    base, masked = run(), run(secure_agg=True)
    for a, b in zip(jax.tree_util.tree_leaves(base.params),
                    jax.tree_util.tree_leaves(masked.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(
        [h["local_loss"] for h in base.history],
        [h["local_loss"] for h in masked.history], atol=1e-4, rtol=1e-4)


def test_masked_run_matches_unmasked_pod(vision_setup):
    task, data = vision_setup
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    def run(secure_agg):
        strat = PodAggregateStrategy(
            spec=LocalSpec(n_steps=2, batch_size=8, lr=0.05,
                           update_impl="fused_interpret",
                           secure_agg=secure_agg),
            algorithm="fedavg", mesh=mesh, clients_per_round=4)
        return run_rounds(task, data, strat,
                          RoundSchedule(rounds=3, eval_every=0, seed=SEED,
                                        chunk_size=3, sampling="host",
                                        host_rng_offset=17))

    base, masked = run(False), run(True)
    for a, b in zip(jax.tree_util.tree_leaves(base.params),
                    jax.tree_util.tree_leaves(masked.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=2e-4, rtol=2e-4)


def test_secure_agg_bytes_in_ledger(vision_setup):
    from repro.core.comm_accounting import CommLedger, secure_agg_mask_bytes
    task, data = vision_setup
    ledger = CommLedger()
    run_federated(task, data, FLConfig(
        rounds=2, chunk_size=2, participation=0.5, local_steps=2,
        batch_size=8, lr=0.05, eval_every=0, seed=SEED, secure_agg=True),
        ledger=ledger)
    led = ledger.summary()
    k = max(1, int(round(0.5 * 8)))
    assert led["mask_bytes"] == 2 * secure_agg_mask_bytes(k)
    assert led["total_bytes"] == led["p2_bytes"] + led["mask_bytes"]


# ---------------------------------------------------------------------------
# multi-device: masked == unmasked under the hierarchical psum combine
# ---------------------------------------------------------------------------

_MASK_SUBPROCESS_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.data.federated import FederatedDataset
    from repro.fl.engine import RoundSchedule, run_rounds
    from repro.fl.local import LocalSpec
    from repro.fl.pod import PodAggregateStrategy
    from repro.fl.privacy import DPSpec
    from repro.fl.task import vision_task

    mesh = jax.make_mesh((4, 4), ("data", "model"))
    task = vision_task("mlp", in_ch=1, seed_kwargs={"img": 8, "d_hidden": 16})
    rng = np.random.default_rng(0)
    N, per = 8, 16
    x = rng.normal(size=(N, per, 8, 8, 1)).astype(np.float32)
    y = rng.integers(0, 10, size=(N, per)).astype(np.int32)
    data = FederatedDataset(x=x, y=y, n_real=np.full((N,), per, np.int32),
                            test_x=x[0], test_y=y[0], n_classes=10,
                            name="mask-psum-test")
    sched = RoundSchedule(rounds=4, lr_decay=1.0, eval_every=0, seed=0,
                          chunk_size=2, sampling="host", host_rng_offset=17)

    def run(aggregation, **spec_kw):
        strat = PodAggregateStrategy(
            spec=LocalSpec(n_steps=2, batch_size=4, lr=0.05,
                           update_impl="fused_interpret", **spec_kw),
            algorithm="fedavg", mesh=mesh, clients_per_round=4,
            aggregation=aggregation, n_pods=4)
        return run_rounds(task, data, strat, sched)

    # the sharded-lane psum path engages (G == |data| == 4, fused):
    # masked == unmasked under the hierarchical combine
    base = run("hierarchical")
    masked = run("hierarchical", secure_agg=True)
    for a, b in zip(jax.tree_util.tree_leaves(base.params),
                    jax.tree_util.tree_leaves(masked.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=5e-4, rtol=5e-4)

    # DP clipping on the psum path carries the coefficient sum next to
    # the p-free partials: hierarchical == sequential for ONE round
    # (tight — identical noise bits, only reduction order differs)
    sched1 = RoundSchedule(rounds=1, eval_every=0, seed=0, chunk_size=1,
                           sampling="host", host_rng_offset=17)

    def run1(aggregation, **spec_kw):
        strat = PodAggregateStrategy(
            spec=LocalSpec(n_steps=2, batch_size=4, lr=0.05,
                           update_impl="fused_interpret", **spec_kw),
            algorithm="fedavg", mesh=mesh, clients_per_round=4,
            aggregation=aggregation, n_pods=4)
        return run_rounds(task, data, strat, sched1)

    kw = dict(dp=DPSpec(0.5, 0.3), secure_agg=True)
    seqp = run1("sequential", **kw)
    hierp = run1("hierarchical", **kw)
    for a, b in zip(jax.tree_util.tree_leaves(seqp.params),
                    jax.tree_util.tree_leaves(hierp.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=5e-6, rtol=5e-6)

    # identity spec stays bitwise on the psum path too
    ident = run("hierarchical", dp=DPSpec(float("inf"), 0.0))
    for a, b in zip(jax.tree_util.tree_leaves(base.params),
                    jax.tree_util.tree_leaves(ident.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    print("SECURE_AGG_PSUM_OK")
""")


@pytest.mark.slow
def test_secure_agg_hierarchical_psum_16dev_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", _MASK_SUBPROCESS_SCRIPT],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "SECURE_AGG_PSUM_OK" in out.stdout
