"""In-program eval stream + pod server-optimizer tests.

The engine evaluates INSIDE the compiled chunk program (per-round mask
scan input + batched test stream, see repro.fl.engine).  These tests pin
the contract down:

  - the streamed metric equals the host-side reference evaluation
    (``make_eval_fn``) to fp tolerance, including when the test-set size
    does not divide ``eval_batch`` (wrap-around padding + weights);
  - histories (losses AND acc rows) are invariant to ``chunk_size``
    even when ``eval_every`` does not divide it — the decoupling that
    removed ``_rounds_until_eval`` chunk-splitting;
  - evaluating costs ZERO extra dispatches: ceil(rounds / chunk_size)
    chunk invocations with eval on or off;
  - pod ``server_opt="momentum"|"adam"`` matches the host engine
    round-for-round, and the optimizer moments shard like the params
    they mirror.
"""
import dataclasses as dc

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.data.synthetic import DATASETS, make_synthetic_tokenlm
from repro.fl.engine import (
    AggregateStrategy,
    RoundSchedule,
    batch_test_set,
    make_eval_fn,
    run_rounds,
)
from repro.fl.local import LocalSpec
from repro.fl.simulation import HOST_RNG_OFFSET_P2, FLConfig, run_federated
from repro.fl.task import lm_task, vision_task
from repro.launch.mesh import make_host_mesh

SEED = 0


@pytest.fixture(scope="module")
def vision_setup():
    # n_test=250 deliberately does not divide eval_batch=64: the tail
    # batch exercises the wrap-around padding + weight masking
    data = DATASETS.get("cifar10-like")(n_clients=8, beta=0.5, seed=SEED,
                                        n_train=256, n_test=250)
    task = vision_task("lenet5", n_classes=10, in_ch=3)
    return task, data


@pytest.fixture(scope="module")
def lm_setup():
    from repro.configs import get_reduced
    cfg = get_reduced("qwen1.5-0.5b")
    data = make_synthetic_tokenlm(n_clients=8, seq_len=16,
                                  n_seq_per_client=8,
                                  vocab=cfg.vocab_size, beta=0.5, seed=SEED)
    return lm_task(cfg), data


def _fl(rounds=4, **kw):
    kw.setdefault("eval_batch", 64)
    return FLConfig(algorithm="fedavg", rounds=rounds, participation=0.25,
                    local_steps=2, seed=SEED, **kw)


def _leaves32(tree):
    return [np.asarray(x, np.float32) for x in jax.tree_util.tree_leaves(tree)]


# ---------------------------------------------------------------------------
# batching helper
# ---------------------------------------------------------------------------

def test_batch_test_set_pads_with_wraparound_and_weights():
    x = np.arange(10, dtype=np.float32)[:, None]
    y = np.arange(10)
    bx, by, w = batch_test_set(x, y, 4)
    assert bx.shape == (3, 4, 1) and by.shape == (3, 4) and w.shape == (3, 4)
    np.testing.assert_array_equal(by.ravel()[:10], y)
    np.testing.assert_array_equal(by.ravel()[10:], y[:2])   # wrap-around pad
    np.testing.assert_array_equal(w.ravel(),
                                  [1] * 10 + [0] * 2)
    # eval_batch larger than the test set clamps to one full batch
    bx, by, w = batch_test_set(x, y, 256)
    assert bx.shape == (1, 10, 1) and w.sum() == 10


# ---------------------------------------------------------------------------
# stream ↔ host-reference parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("eval_every", [1, 3])
def test_inprogram_eval_matches_host_reference(vision_setup, eval_every):
    """The final round always evaluates; its in-program acc must equal
    the host-side batched reference on the final params."""
    task, data = vision_setup
    res = run_federated(task, data, _fl(rounds=4, eval_every=eval_every,
                                        chunk_size=4))
    want = make_eval_fn(task, 64)(res.params, data.test_x, data.test_y)
    assert abs(res.history[-1]["acc"] - want) <= 1e-5


def test_inprogram_eval_matches_host_reference_tokenlm(lm_setup):
    task, data = lm_setup
    spec = LocalSpec(n_steps=2, batch_size=4, lr=0.05)
    sched = RoundSchedule(rounds=2, lr_decay=1.0, eval_every=2, eval_batch=8,
                          seed=SEED, chunk_size=2)
    res = run_rounds(task, data,
                     AggregateStrategy(spec=spec, participation=0.25), sched)
    want = make_eval_fn(task, 8)(res.params, data.test_x, data.test_y)
    assert abs(res.history[-1]["acc"] - want) <= 1e-5


# ---------------------------------------------------------------------------
# eval_every ⊥ chunk_size
# ---------------------------------------------------------------------------

def test_eval_cadence_decoupled_from_chunking(vision_setup):
    """eval_every=3 with chunk_size=4 (neither divides the other):
    histories — including which rounds carry acc and their values —
    must match the chunk_size=1 run."""
    task, data = vision_setup
    cfg = _fl(rounds=7, eval_every=3, chunk_size=4)
    r1 = run_federated(task, data, dc.replace(cfg, chunk_size=1))
    r4 = run_federated(task, data, cfg)
    assert [h["round"] for h in r4.history] == list(range(7))
    # cadence: rounds 3, 6 (1-based) plus the final round
    assert [h["round"] for h in r4.history if "acc" in h] == [2, 5, 6]
    for a, b in zip(r1.history, r4.history):
        assert ("acc" in a) == ("acc" in b)
        assert abs(a["local_loss"] - b["local_loss"]) <= 1e-5
        assert abs(a.get("acc", 0.0) - b.get("acc", 0.0)) <= 1e-5
    for a, b in zip(_leaves32(r1.params), _leaves32(r4.params)):
        np.testing.assert_allclose(a, b, atol=1e-5, rtol=1e-5)


def test_eval_costs_zero_extra_dispatches(vision_setup):
    """ceil(rounds / chunk) dispatches, evaluation on or off — the
    pre-eval-stream engine split every chunk at eval boundaries."""
    task, data = vision_setup
    off = run_federated(task, data, _fl(rounds=6, eval_every=0, chunk_size=4))
    on = run_federated(task, data, _fl(rounds=6, eval_every=3, chunk_size=4))
    assert off.dispatches == on.dispatches == 2
    assert [h["round"] for h in on.history if "acc" in h] == [2, 5]


# ---------------------------------------------------------------------------
# pod server-side optimizers (FedAvgM / FedAdam)
# ---------------------------------------------------------------------------

def _sched(rounds, chunk):
    return RoundSchedule(rounds=rounds, lr_decay=1.0, eval_every=0,
                         seed=SEED, chunk_size=chunk, sampling="host",
                         host_rng_offset=HOST_RNG_OFFSET_P2)


@pytest.mark.parametrize("server_opt,server_lr,tol",
                         [("momentum", 0.5, 1e-5),
                          ("adam", 0.02, 2e-3)])
def test_pod_server_opt_matches_host_engine(lm_setup, server_opt, server_lr,
                                            tol):
    """Pod FedAvgM/FedAdam vs the host engine, same seeds + host
    sampling.  momentum is tight; adam's sign-like normalization
    amplifies the scan-delta vs vmap-mean fp reduction-order difference
    on near-zero pseudo-gradient elements, hence the looser tolerance
    and step size."""
    from repro.fl.pod import PodAggregateStrategy

    task, data = lm_setup
    spec = LocalSpec(n_steps=2, batch_size=4, lr=0.01)
    host = run_rounds(task, data,
                      AggregateStrategy(spec=spec, participation=0.25,
                                        server_opt=server_opt,
                                        server_lr=server_lr),
                      _sched(3, 2))
    pod = run_rounds(task, data,
                     PodAggregateStrategy(spec=spec, mesh=make_host_mesh(),
                                          clients_per_round=2,
                                          server_opt=server_opt,
                                          server_lr=server_lr),
                     _sched(3, 2))
    np.testing.assert_allclose([h["local_loss"] for h in host.history],
                               [h["local_loss"] for h in pod.history],
                               atol=tol, rtol=tol)
    for a, b in zip(_leaves32(host.params), _leaves32(pod.params)):
        np.testing.assert_allclose(a, b, atol=5 * tol, rtol=5 * tol)
    # the server state rides the carry: momentum buffers must have moved
    inner = jax.tree_util.tree_leaves(pod.server_state.inner)
    assert inner and any(np.abs(np.asarray(l)).max() > 0 for l in inner)


def test_pod_server_state_shards_like_params(lm_setup):
    """The OptState moments mirror the param tree, so the param
    path-pattern rules shard them identically (scalar step replicated)."""
    from repro.fl.pod import PodAggregateStrategy
    from repro.optim.optimizers import adamw
    from repro.sharding import rules
    from types import SimpleNamespace

    mesh = SimpleNamespace(axis_names=("data", "model"),
                           devices=np.empty((4, 4)))
    p_specs = {"blk": {"wq": {"w": jax.ShapeDtypeStruct((8, 8), jnp.float32)},
                       "norm": {"scale": jax.ShapeDtypeStruct((8,),
                                                              jnp.float32)}}}
    state = jax.eval_shape(adamw(0.1).init, p_specs)
    specs = rules.param_pspecs(state, mesh)
    assert specs.step == P()
    assert specs.inner.mu["blk"]["wq"]["w"] == P("data", "model")
    assert specs.inner.nu["blk"]["wq"]["w"] == P("data", "model")
    assert specs.inner.mu["blk"]["norm"]["scale"] == P(None)

    # and the strategy-level hook wires those rules to a real mesh
    # (keyed by task since the fused path builds a flat OptState)
    task, _ = lm_setup
    strat = PodAggregateStrategy(
        spec=LocalSpec(n_steps=1, batch_size=2, lr=0.01),
        mesh=make_host_mesh(), clients_per_round=2, server_opt="adam")
    sh = strat.server_state_shardings(task)
    assert jax.tree_util.tree_leaves(sh)          # non-empty placement tree
