"""End-to-end behaviour tests for the CyclicFL system.

These assert the paper's QUALITATIVE claims at test scale (seconds, not
benchmark-grade):
  - the pipeline runs P1→P2 and improves over random init (RQ1/RQ2),
  - all four FL algorithms compose with cyclic pre-training,
  - the communication ledger matches Table IV closed forms exactly,
  - the pod-scale (sharded) driver agrees with the host simulator's
    semantics and reduces training loss,
  - switch policies terminate P1 when they should.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import comm_accounting as acc
from repro.core.cyclic import CyclicConfig, cyclic_pretrain
from repro.core.pipeline import run_cyclic_then_federated
from repro.core.switch import AccuracyPlateau, BudgetFraction, FixedRounds
from repro.data.synthetic import DATASETS, make_synthetic_tokenlm
from repro.fl.simulation import FLConfig, run_federated
from repro.fl.task import charlm_task, vision_task

SEED = 0


@pytest.fixture(scope="module")
def vision_setup():
    data = DATASETS.get("cifar10-like")(n_clients=8, beta=0.5, seed=SEED,
                                        n_train=512, n_test=256)
    task = vision_task("lenet5", n_classes=10, in_ch=3)
    return task, data


def _tiny_cyc(rounds=2, steps=4):
    return CyclicConfig(rounds=rounds, participation=0.25, local_steps=steps,
                        eval_every=1, seed=SEED)


def _tiny_fl(algorithm="fedavg", rounds=3, steps=4):
    return FLConfig(algorithm=algorithm, rounds=rounds, participation=0.25,
                    local_steps=steps, eval_every=1, seed=SEED)


def test_cyclic_pretrain_reduces_loss(vision_setup):
    task, data = vision_setup
    res = cyclic_pretrain(task, data, _tiny_cyc(rounds=3, steps=8))
    losses = [h["local_loss"] for h in res.history]
    assert losses[-1] < losses[0]
    assert len(res.history) == 3


def test_pipeline_beats_random_init_same_budget(vision_setup):
    """RQ1/RQ2 at test scale: with a fixed total budget, Cyclic+FedAvg
    reaches at-least-as-good accuracy as FedAvg from random init."""
    task, data = vision_setup
    cyc = run_cyclic_then_federated(task, data, _tiny_cyc(rounds=3, steps=8),
                                    _tiny_fl(rounds=5, steps=8))
    base = run_cyclic_then_federated(task, data, None,
                                     _tiny_fl(rounds=8, steps=8))
    a = cyc.best_acc().get("acc", 0.0)
    b = base.best_acc().get("acc", 0.0)
    # generous slack: tiny scale is noisy, but cyclic must not be WORSE
    # by a wide margin, and usually wins
    assert a >= b - 0.05, (a, b)


@pytest.mark.parametrize("algorithm", ["fedavg", "fedprox", "scaffold", "moon"])
def test_all_algorithms_run_and_learn(vision_setup, algorithm):
    task, data = vision_setup
    res = run_federated(task, data, _tiny_fl(algorithm, rounds=3))
    assert len(res.history) == 3
    accs = [h["acc"] for h in res.history if "acc" in h]
    assert accs and all(np.isfinite(a) for a in accs)
    assert accs[-1] > 1.0 / data.n_classes * 0.8  # above-chance-ish


@pytest.mark.parametrize("algorithm", ["fedavg", "scaffold"])
def test_ledger_matches_closed_form(vision_setup, algorithm):
    task, data = vision_setup
    res = run_cyclic_then_federated(task, data, _tiny_cyc(rounds=2),
                                    _tiny_fl(algorithm, rounds=3))
    led = res.ledger.summary()
    k_p1 = _tiny_cyc().n_selected(data.n_clients)
    k_p2 = _tiny_fl(algorithm).n_selected(data.n_clients)
    want = acc.overhead_with_cyclic(algorithm, k_p1, 2, k_p2, 3,
                                    led["model_bytes"])
    assert led["total_bytes"] == want


def test_cyclic_is_strictly_sequential(vision_setup):
    """Algorithm-1 semantics: the relay visits clients IN ORDER — running
    one round over clients [a, b] must equal local(local(w, a), b)."""
    from repro.core.cyclic import make_cyclic_round_fn
    from repro.fl.local import make_local_fn

    task, data = vision_setup
    ccfg = _tiny_cyc(rounds=1, steps=3)
    round_fn = make_cyclic_round_fn(task, ccfg)
    x_all, y_all, _ = data.device_arrays()
    params = task.init(jax.random.PRNGKey(SEED))
    key = jax.random.PRNGKey(42)
    ids = jnp.asarray([2, 5])

    got, _ = round_fn(key, params, x_all, y_all, ids, jnp.float32(1.0))

    local = make_local_fn(task, ccfg.local_spec())
    keys = jax.random.split(key, 2)
    w1, _ = local(keys[0], params, {}, x_all[2], y_all[2], jnp.float32(1.0))
    w2, _ = local(keys[1], w1, {}, x_all[5], y_all[5], jnp.float32(1.0))

    for a, b in zip(jax.tree_util.tree_leaves(got),
                    jax.tree_util.tree_leaves(w2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=1e-5, rtol=1e-5)


def test_switch_policies():
    hist_flat = [{"round": i, "acc": 0.5} for i in range(10)]
    hist_rising = [{"round": i, "acc": 0.1 * i} for i in range(10)]
    assert FixedRounds(t_cyc=3).should_switch(2, hist_flat[:3])
    assert not FixedRounds(t_cyc=3).should_switch(1, hist_flat[:2])
    p = AccuracyPlateau(patience=2, min_delta=0.01, min_rounds=2)
    assert p.should_switch(9, hist_flat)
    assert not p.should_switch(9, hist_rising)
    b = BudgetFraction(total_rounds=20, fraction=0.25)
    assert b.should_switch(4, hist_flat) and not b.should_switch(3, hist_flat)


def test_charlm_task_runs():
    data = DATASETS.get("shakespeare-like")(n_clients=8, seed=SEED,
                                            n_seq_per_client=16, n_test=64)
    task = charlm_task(vocab=64)
    res = run_federated(task, data, _tiny_fl(rounds=2, steps=4))
    assert np.isfinite(res.history[-1]["local_loss"])


# ---------------------------------------------------------------------------
# pod-scale (sharded) driver
# ---------------------------------------------------------------------------

def test_pod_driver_trains_and_matches_budget():
    from repro.configs import get_reduced
    from repro.launch.train import PodFLSpec, run_pod_training

    cfg = get_reduced("qwen1.5-0.5b")
    data = make_synthetic_tokenlm(n_clients=8, seq_len=32,
                                  n_seq_per_client=16,
                                  vocab=cfg.vocab_size, beta=0.5, seed=SEED)
    spec = PodFLSpec(local_steps=3, lr=0.05)
    res = run_pod_training(cfg, data, cyclic_rounds=2, fl_rounds=2,
                           clients_per_round=3, spec=spec, seed=SEED)
    assert len(res.history) == 4
    assert res.history[0]["phase"] == "P1" and res.history[-1]["phase"] == "P2"
    assert res.history[-1]["loss"] < res.history[0]["loss"]


def test_pod_cyclic_round_is_relay():
    """Pod P1 semantics: scan(K clients) == sequential local SGD chain."""
    from repro.configs import get_reduced
    from repro.launch.train import (PodFLSpec, _local_sgd,
                                    make_pod_cyclic_round)
    from repro.models.transformer import init_lm

    cfg = get_reduced("tinyllama-1.1b")
    spec = PodFLSpec(local_steps=2, lr=0.05)
    params = init_lm(jax.random.PRNGKey(SEED), cfg)
    key = jax.random.PRNGKey(7)
    K, B, S = 2, 4, 16
    toks = jax.random.randint(key, (K, spec.local_steps, B, S), 0,
                              cfg.vocab_size)
    batches = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=-1)}

    round_fn = make_pod_cyclic_round(cfg, spec)
    got, _ = round_fn(params, batches, jnp.float32(1.0))

    local = _local_sgd(cfg, spec)
    w = params
    for i in range(K):
        w, _ = local(w, jax.tree_util.tree_map(lambda x: x[i], batches),
                     jnp.float32(1.0), None)
    for a, b in zip(jax.tree_util.tree_leaves(got),
                    jax.tree_util.tree_leaves(w)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=2e-5, rtol=2e-5)


def test_pod_fl_round_equals_weighted_mean():
    """Pod P2 semantics: delta aggregation == weighted mean of client
    results (the FedAvg identity)."""
    from repro.configs import get_reduced
    from repro.launch.train import PodFLSpec, _local_sgd, make_pod_fl_round
    from repro.models.transformer import init_lm

    cfg = get_reduced("tinyllama-1.1b")
    spec = PodFLSpec(local_steps=2, lr=0.05)
    params = init_lm(jax.random.PRNGKey(SEED), cfg)
    key = jax.random.PRNGKey(11)
    K, B, S = 3, 4, 16
    toks = jax.random.randint(key, (K, spec.local_steps, B, S), 0,
                              cfg.vocab_size)
    batches = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=-1)}
    weights = jnp.asarray([1.0, 2.0, 3.0])

    round_fn = make_pod_fl_round(cfg, spec)
    got, _ = round_fn(params, batches, weights, jnp.float32(1.0))

    local = _local_sgd(cfg, spec)
    locals_ = [local(params, jax.tree_util.tree_map(lambda x: x[i], batches),
                     jnp.float32(1.0), None)[0] for i in range(K)]
    p32 = jax.tree_util.tree_map(lambda x: np.asarray(x, np.float32), params)
    ws32 = [jax.tree_util.tree_map(lambda x: np.asarray(x, np.float32), w)
            for w in locals_]
    wsum = float(weights.sum())
    want = jax.tree_util.tree_map(
        lambda p, *ws: p + sum(float(weights[i]) / wsum * (ws[i] - p)
                               for i in range(K)),
        p32, *ws32)
    for a, b in zip(jax.tree_util.tree_leaves(got),
                    jax.tree_util.tree_leaves(want)):
        np.testing.assert_allclose(np.asarray(a, np.float32), b,
                                   atol=3e-5, rtol=3e-5)


def test_server_optimizer_none_equals_plain_fedavg(vision_setup):
    """server_opt='none' must reproduce vanilla FedAvg bit-for-bit, and
    server_opt='momentum' with server_lr=1, momentum=0 likewise (the
    pseudo-gradient step degenerates to w ← w_avg)."""
    import dataclasses as dc
    task, data = vision_setup
    base = _tiny_fl(rounds=2, steps=4)
    r_plain = run_federated(task, data, base)
    r_mom0 = run_federated(task, data, dc.replace(
        base, server_opt="momentum", server_lr=1.0, server_momentum=0.0))
    for a, b in zip(jax.tree_util.tree_leaves(r_plain.params),
                    jax.tree_util.tree_leaves(r_mom0.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-6, rtol=1e-6)


@pytest.mark.parametrize("server_opt", ["momentum", "adam"])
def test_server_optimizer_runs_and_learns(vision_setup, server_opt):
    """Beyond-paper server optimizers (FedAvgM / FedAdam) train sanely
    and compose with cyclic pre-training."""
    import dataclasses as dc
    task, data = vision_setup
    # adam normalizes the pseudo-gradient, so server_lr IS the parameter
    # step size — keep it small (FedAdam convention)
    cfg = dc.replace(_tiny_fl(rounds=3, steps=6), server_opt=server_opt,
                     server_lr=1.0 if server_opt == "momentum" else 0.03)
    res = run_cyclic_then_federated(task, data, _tiny_cyc(rounds=2), cfg)
    accs = [h["acc"] for h in res.history if "acc" in h]
    assert accs and np.isfinite(accs[-1])
    assert accs[-1] > 1.0 / data.n_classes * 0.8


def test_serve_engine_greedy_decode_matches_forward():
    """Engine.generate greedy path == argmax over the parallel forward."""
    from repro.configs import get_reduced
    from repro.launch.serve import Engine
    from repro.models.transformer import lm_forward

    cfg = get_reduced("qwen2-1.5b")
    eng = Engine(cfg, seed=SEED)
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, 12), 0,
                              cfg.vocab_size)
    out, _ = eng.generate({"tokens": toks}, new_tokens=3)
    # replay: greedy continuation via repeated full forwards
    seq = toks
    for _ in range(3):
        logits, _, _ = lm_forward(eng.params, cfg, {"tokens": seq})
        seq = jnp.concatenate([seq, jnp.argmax(logits[:, -1], -1)[:, None]],
                              axis=1)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(seq[:, 12:]))
