"""Docs stay wired to the code: `make docs-check` semantics as a test.

Runs tools/check_docs.py over README.md + docs/*.md (every backticked
``path`` / ``path:symbol`` reference must resolve against the source
tree) and asserts the checker itself still catches breakage.
"""
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
CHECKER = ROOT / "tools" / "check_docs.py"


def _run(*args):
    return subprocess.run([sys.executable, str(CHECKER), *args],
                          capture_output=True, text=True, timeout=120)


def test_docs_references_resolve():
    out = _run()
    assert out.returncode == 0, out.stderr + out.stdout


def test_docs_suite_is_present():
    for f in ("README.md", "docs/ARCHITECTURE.md", "docs/BENCHMARKS.md"):
        assert (ROOT / f).is_file(), f


def test_checker_catches_broken_refs(tmp_path):
    bad = tmp_path / "bad.md"
    bad.write_text("`src/repro/fl/engine.py:definitely_not_a_symbol` and\n"
                   "`src/repro/no/such/file.py` but `lax.scan` is prose\n"
                   "and `src/repro/fl/engine.py:run_rounds` is real.\n")
    out = _run(str(bad))
    assert out.returncode == 1
    assert "definitely_not_a_symbol" in out.stderr
    assert "does not exist" in out.stderr
    assert "run_rounds" not in out.stderr
