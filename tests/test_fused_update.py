"""Fused flat-buffer update path vs the tree parity oracle.

``update_impl="fused_interpret"`` routes the client step tail, the
FedAvg aggregation and the server optimizers through the FlatView +
Pallas kernels (repro.kernels.fused_update, interpret mode on this
CPU container); ``"tree"`` is the per-leaf tree_math oracle.  These
tests pin numerical parity at three levels:

  - the step tail alone (fused_step_tail vs tree_step_tail, all term
    combinations incl. clip / correction / decay / momentum);
  - full host-engine runs for all four variants and both server
    optimizers;
  - full pod-backend runs (sequential fused delta accumulation +
    fused server moments).

Adam comparisons carry the looser tolerance documented in
tests/test_eval_stream.py: its sign-like normalization amplifies fp
reduction-order differences on near-zero pseudo-gradient elements.
"""
import dataclasses as dc

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.synthetic import DATASETS, make_synthetic_tokenlm
from repro.fl.engine import RoundSchedule, run_rounds
from repro.fl.local import LocalSpec, fused_step_tail, tree_step_tail
from repro.fl.simulation import HOST_RNG_OFFSET_P2, FLConfig, run_federated
from repro.fl.task import lm_task, vision_task
from repro.utils.flatten import FlatView

SEED = 0


def _leaves32(tree):
    return [np.asarray(x, np.float32) for x in jax.tree_util.tree_leaves(tree)]


def _assert_tree_close(a, b, tol):
    for x, y in zip(_leaves32(a), _leaves32(b)):
        np.testing.assert_allclose(x, y, atol=tol, rtol=tol)


# ---------------------------------------------------------------------------
# step tail: fused kernel vs tree oracle
# ---------------------------------------------------------------------------

def _random_tree(key, scale=1.0):
    ks = jax.random.split(key, 3)
    return {"w": jax.random.normal(ks[0], (17, 33)) * scale,
            "b": jax.random.normal(ks[1], (33,)) * scale,
            "head": {"w": jax.random.normal(ks[2], (33, 5)) * scale}}


@pytest.mark.parametrize("grad_clip,momentum,weight_decay,with_c", [
    (None, 0.0, 0.0, False),            # bare axpy
    (0.5, 0.0, 0.0, False),             # clip only
    (None, 0.9, 0.0, False),            # momentum only
    (None, 0.0, 1e-2, False),           # decay only
    (0.5, 0.9, 1e-2, True),             # everything + scaffold correction
])
def test_step_tail_matches_tree(grad_clip, momentum, weight_decay, with_c):
    spec = LocalSpec(n_steps=1, batch_size=1, lr=0.05, momentum=momentum,
                     weight_decay=weight_decay, grad_clip=grad_clip,
                     update_impl="fused_interpret")
    params = _random_tree(jax.random.PRNGKey(0))
    grads = _random_tree(jax.random.PRNGKey(1), scale=3.0)
    mom = _random_tree(jax.random.PRNGKey(2)) if momentum else ()
    c = _random_tree(jax.random.PRNGKey(3), scale=0.1) if with_c else None
    lr_scale = jnp.float32(0.7)

    want_p, want_m = tree_step_tail(spec, params, grads, mom, c, lr_scale)

    view = FlatView.of(params)
    m_bufs = view.flatten(mom) if momentum else {}
    got_p, got_m = fused_step_tail(
        spec, view.flatten(params), view.flatten(grads), m_bufs,
        view.flatten(c) if c is not None else None, lr_scale,
        interpret=True)
    _assert_tree_close(view.unflatten(got_p), want_p, 1e-6)
    if momentum:
        _assert_tree_close(view.unflatten(got_m), want_m, 1e-6)


# ---------------------------------------------------------------------------
# host engine: all four variants + both server optimizers
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def vision_setup():
    data = DATASETS.get("fashion-like")(n_clients=8, beta=0.5, seed=SEED,
                                        n_train=256, n_test=64)
    task = vision_task("mlp", n_classes=10, in_ch=data.x.shape[-1])
    return task, data


def _fl(**kw):
    kw.setdefault("rounds", 2)
    kw.setdefault("chunk_size", 2)
    return FLConfig(participation=0.25, local_steps=2, batch_size=8,
                    eval_every=0, seed=SEED, **kw)


@pytest.mark.parametrize("algorithm", ["fedavg", "fedprox", "scaffold",
                                       "moon"])
def test_host_variant_parity(vision_setup, algorithm):
    task, data = vision_setup
    cfg = _fl(algorithm=algorithm, momentum=0.9, weight_decay=1e-4,
              grad_clip=1.0)
    tree = run_federated(task, data, cfg)
    fused = run_federated(task, data,
                          dc.replace(cfg, update_impl="fused_interpret"))
    np.testing.assert_allclose([h["local_loss"] for h in tree.history],
                               [h["local_loss"] for h in fused.history],
                               atol=1e-5, rtol=1e-5)
    _assert_tree_close(tree.params, fused.params, 2e-5)


@pytest.mark.parametrize("server_opt,server_lr,tol",
                         [("momentum", 0.5, 2e-5), ("adam", 0.02, 1e-2)])
def test_host_server_opt_parity(vision_setup, server_opt, server_lr, tol):
    task, data = vision_setup
    cfg = _fl(algorithm="fedavg", rounds=3, server_opt=server_opt,
              server_lr=server_lr)
    tree = run_federated(task, data, cfg)
    fused = run_federated(task, data,
                          dc.replace(cfg, update_impl="fused_interpret"))
    _assert_tree_close(tree.params, fused.params, tol)


def test_relay_parity(vision_setup):
    from repro.core.cyclic import CyclicConfig, cyclic_pretrain
    task, data = vision_setup
    cfg = CyclicConfig(rounds=2, participation=0.25, local_steps=2,
                       batch_size=8, momentum=0.9, grad_clip=1.0,
                       eval_every=0, seed=SEED, chunk_size=2)
    tree = cyclic_pretrain(task, data, cfg)
    fused = cyclic_pretrain(task, data,
                            dc.replace(cfg, update_impl="fused_interpret"))
    np.testing.assert_allclose([h["local_loss"] for h in tree.history],
                               [h["local_loss"] for h in fused.history],
                               atol=1e-5, rtol=1e-5)
    _assert_tree_close(tree.params, fused.params, 2e-5)


def test_bad_update_impl_rejected():
    with pytest.raises(ValueError, match="update_impl"):
        LocalSpec(n_steps=1, batch_size=1, lr=0.1, update_impl="magic")


# ---------------------------------------------------------------------------
# pod backend: fused sequential delta accumulation + server moments
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def lm_setup():
    from repro.configs import get_reduced
    cfg = get_reduced("qwen1.5-0.5b")
    data = make_synthetic_tokenlm(n_clients=8, seq_len=16, n_seq_per_client=8,
                                  vocab=cfg.vocab_size, beta=0.5, seed=SEED)
    return lm_task(cfg), data


def _pod_sched(rounds=2, chunk=2):
    return RoundSchedule(rounds=rounds, lr_decay=1.0, eval_every=0, seed=SEED,
                         chunk_size=chunk, sampling="host",
                         host_rng_offset=HOST_RNG_OFFSET_P2)


@pytest.mark.parametrize("algorithm,server_opt,server_lr,tol", [
    ("fedavg", "none", 1.0, 2e-5),
    ("scaffold", "none", 1.0, 2e-5),
    ("fedavg", "momentum", 0.5, 2e-5),
    ("fedavg", "adam", 0.02, 1e-2),
])
def test_pod_fused_matches_tree(lm_setup, algorithm, server_opt, server_lr,
                                tol):
    from repro.fl.local import UPDATE_IMPLS  # noqa: F401 (doc pointer)
    from repro.fl.pod import PodAggregateStrategy
    from repro.launch.mesh import make_host_mesh

    task, data = lm_setup
    mesh = make_host_mesh()
    spec = LocalSpec(n_steps=2, batch_size=4, lr=0.01, momentum=0.9,
                     variant="scaffold" if algorithm == "scaffold"
                     else "plain")
    mk = lambda s: PodAggregateStrategy(         # noqa: E731
        spec=s, algorithm=algorithm, mesh=mesh, clients_per_round=2,
        server_opt=server_opt, server_lr=server_lr)
    tree = run_rounds(task, data, mk(spec), _pod_sched())
    fused = run_rounds(task, data,
                       mk(dc.replace(spec, update_impl="fused_interpret")),
                       _pod_sched())
    np.testing.assert_allclose([h["local_loss"] for h in tree.history],
                               [h["local_loss"] for h in fused.history],
                               atol=1e-5, rtol=1e-5)
    _assert_tree_close(tree.params, fused.params, tol)
