"""Flat-first fused update path vs the tree parity oracle.

``update_impl="fused_interpret"`` routes the client step tail, the
FedAvg aggregation and the server optimizers through the FlatView +
Pallas kernels (repro.kernels.fused_update, interpret mode on this
CPU container); ``"tree"`` is the per-leaf tree_math oracle.  These
tests pin numerical parity at three levels:

  - the step tail alone (fused_step_tail vs tree_step_tail, all term
    combinations incl. clip / correction / decay / momentum);
  - the flat-grad local contract (value_and_grad w.r.t. the buffers
    emits packed gradients identical to packing the tree gradients);
  - full host-engine runs for all four variants and both server
    optimizers (flat chunk carries + flat OptState);
  - full pod-backend runs (sequential fused delta accumulation +
    fused server moments over ShardedFlatOps);
  - (slow) a 16-fake-device subprocess run pinning fused == tree under
    a REAL sharded FSDP×TP layout, with the carry buckets actually
    sharded over their mesh-axis groups.

Adam comparisons carry the looser tolerance documented in
tests/test_eval_stream.py: its sign-like normalization amplifies fp
reduction-order differences on near-zero pseudo-gradient elements.
"""
import dataclasses as dc
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.synthetic import DATASETS, make_synthetic_tokenlm
from repro.fl import privacy
from repro.fl.engine import RoundSchedule, run_rounds
from repro.fl.local import (
    FlatParamOps,
    LocalSpec,
    fused_step_tail,
    tree_step_tail,
)
from repro.fl.simulation import HOST_RNG_OFFSET_P2, FLConfig, run_federated
from repro.fl.task import lm_task, vision_task
from repro.utils.flatten import FlatView

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                         # pragma: no cover
    HAVE_HYPOTHESIS = False

SEED = 0


def _leaves32(tree):
    return [np.asarray(x, np.float32) for x in jax.tree_util.tree_leaves(tree)]


def _assert_tree_close(a, b, tol):
    for x, y in zip(_leaves32(a), _leaves32(b)):
        np.testing.assert_allclose(x, y, atol=tol, rtol=tol)


# ---------------------------------------------------------------------------
# step tail: fused kernel vs tree oracle
# ---------------------------------------------------------------------------

def _random_tree(key, scale=1.0):
    ks = jax.random.split(key, 3)
    return {"w": jax.random.normal(ks[0], (17, 33)) * scale,
            "b": jax.random.normal(ks[1], (33,)) * scale,
            "head": {"w": jax.random.normal(ks[2], (33, 5)) * scale}}


@pytest.mark.parametrize("grad_clip,momentum,weight_decay,with_c", [
    (None, 0.0, 0.0, False),            # bare axpy
    (0.5, 0.0, 0.0, False),             # clip only
    (None, 0.9, 0.0, False),            # momentum only
    (None, 0.0, 1e-2, False),           # decay only
    (0.5, 0.9, 1e-2, True),             # everything + scaffold correction
])
def test_step_tail_matches_tree(grad_clip, momentum, weight_decay, with_c):
    spec = LocalSpec(n_steps=1, batch_size=1, lr=0.05, momentum=momentum,
                     weight_decay=weight_decay, grad_clip=grad_clip,
                     update_impl="fused_interpret")
    params = _random_tree(jax.random.PRNGKey(0))
    grads = _random_tree(jax.random.PRNGKey(1), scale=3.0)
    mom = _random_tree(jax.random.PRNGKey(2)) if momentum else ()
    c = _random_tree(jax.random.PRNGKey(3), scale=0.1) if with_c else None
    lr_scale = jnp.float32(0.7)

    want_p, want_m = tree_step_tail(spec, params, grads, mom, c, lr_scale)

    view = FlatView.of(params)
    fops = FlatParamOps(view=view, interpret=True)
    m_bufs = view.flatten(mom) if momentum else {}
    got_p, got_m = fused_step_tail(
        spec, fops, view.flatten(params), view.flatten(grads), m_bufs,
        view.flatten(c) if c is not None else None, lr_scale)
    _assert_tree_close(view.unflatten(got_p), want_p, 1e-6)
    if momentum:
        _assert_tree_close(view.unflatten(got_m), want_m, 1e-6)


# ---------------------------------------------------------------------------
# host engine: all four variants + both server optimizers
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def vision_setup():
    data = DATASETS.get("fashion-like")(n_clients=8, beta=0.5, seed=SEED,
                                        n_train=256, n_test=64)
    task = vision_task("mlp", n_classes=10, in_ch=data.x.shape[-1])
    return task, data


def _fl(**kw):
    kw.setdefault("rounds", 2)
    kw.setdefault("chunk_size", 2)
    return FLConfig(participation=0.25, local_steps=2, batch_size=8,
                    eval_every=0, seed=SEED, **kw)


@pytest.mark.parametrize("algorithm", ["fedavg", "fedprox", "scaffold",
                                       "moon"])
def test_host_variant_parity(vision_setup, algorithm):
    task, data = vision_setup
    cfg = _fl(algorithm=algorithm, momentum=0.9, weight_decay=1e-4,
              grad_clip=1.0)
    tree = run_federated(task, data, cfg)
    fused = run_federated(task, data,
                          dc.replace(cfg, update_impl="fused_interpret"))
    np.testing.assert_allclose([h["local_loss"] for h in tree.history],
                               [h["local_loss"] for h in fused.history],
                               atol=1e-5, rtol=1e-5)
    _assert_tree_close(tree.params, fused.params, 2e-5)


@pytest.mark.parametrize("server_opt,server_lr,tol",
                         [("momentum", 0.5, 2e-5), ("adam", 0.02, 1e-2)])
def test_host_server_opt_parity(vision_setup, server_opt, server_lr, tol):
    task, data = vision_setup
    cfg = _fl(algorithm="fedavg", rounds=3, server_opt=server_opt,
              server_lr=server_lr)
    tree = run_federated(task, data, cfg)
    fused = run_federated(task, data,
                          dc.replace(cfg, update_impl="fused_interpret"))
    _assert_tree_close(tree.params, fused.params, tol)


def test_relay_parity(vision_setup):
    from repro.core.cyclic import CyclicConfig, cyclic_pretrain
    task, data = vision_setup
    cfg = CyclicConfig(rounds=2, participation=0.25, local_steps=2,
                       batch_size=8, momentum=0.9, grad_clip=1.0,
                       eval_every=0, seed=SEED, chunk_size=2)
    tree = cyclic_pretrain(task, data, cfg)
    fused = cyclic_pretrain(task, data,
                            dc.replace(cfg, update_impl="fused_interpret"))
    np.testing.assert_allclose([h["local_loss"] for h in tree.history],
                               [h["local_loss"] for h in fused.history],
                               atol=1e-5, rtol=1e-5)
    _assert_tree_close(tree.params, fused.params, 2e-5)


def test_bad_update_impl_rejected():
    with pytest.raises(ValueError, match="update_impl"):
        LocalSpec(n_steps=1, batch_size=1, lr=0.1, update_impl="magic")


@pytest.mark.parametrize("make_config", [
    lambda: FLConfig(update_impl="fusde"),
    lambda: __import__("repro.core.cyclic", fromlist=["CyclicConfig"])
    .CyclicConfig(update_impl="magic"),
    lambda: __import__("repro.fl.pod", fromlist=["PodFLSpec"])
    .PodFLSpec(update_impl="Fused"),
])
def test_bad_update_impl_rejected_at_config_time(make_config):
    """A typo'd update_impl fails at CONFIG construction with the
    allowed values spelled out — not deep inside the engine."""
    with pytest.raises(ValueError, match=r"tree.*fused.*fused_interpret"):
        make_config()


def test_flat_place_never_aliases_the_callers_arrays():
    """flatten is a NO-OP for a bucket holding exactly one 1-D leaf
    (concatenate of one array returns the operand) — place() must copy
    such passthroughs, or the engine's donated carries would delete the
    caller's params (the P1→P2 handoff regression class)."""
    tree = {"v": jnp.arange(5, dtype=jnp.float32)}
    view = FlatView.of(tree)
    fops = FlatParamOps(view=view, interpret=True)
    bufs = view.flatten(tree)
    assert bufs["float32"] is tree["v"]          # the hazard is real
    placed = fops.place(bufs)
    assert placed["float32"] is not tree["v"]    # place de-aliases

    # pod flavor: (1, N)-shaped unsharded leaves pass straight through
    # the shard transform AND device_put on matching placement
    from jax.sharding import PartitionSpec as P
    from repro.fl.pod import ShardedFlatOps
    from repro.launch.mesh import make_host_mesh
    from repro.utils.flatten import ShardedFlatView

    mesh = make_host_mesh()
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    tree2 = {"row": jnp.arange(6, dtype=jnp.float32).reshape(1, 6)}
    sview = ShardedFlatView.of(tree2, {"row": P()}, sizes)
    sops = ShardedFlatOps(view=sview, interpret=True, mesh=mesh)
    placed2 = sops.place(sview.flatten(tree2))
    assert placed2["float32"] is not tree2["row"]


def test_flat_local_emits_packed_gradients(vision_setup):
    """The flat-grad contract: the fused local fn takes/returns flat
    buffers, and the params it trains match the tree local bit-for-bit
    tolerance — i.e. d(loss∘unflatten)/d(bufs) == flatten(dloss/dtree)."""
    from repro.fl.local import host_flat_ops, make_local_fn

    task, data = vision_setup
    spec = LocalSpec(n_steps=3, batch_size=8, lr=0.05, momentum=0.9,
                     weight_decay=1e-4, grad_clip=1.0)
    params = task.init(jax.random.PRNGKey(SEED))
    x_all, y_all, _ = data.device_arrays()
    key = jax.random.PRNGKey(7)

    w_tree, aux_tree = make_local_fn(task, spec)(
        key, params, {}, x_all[1], y_all[1], jnp.float32(1.0))

    fspec = dc.replace(spec, update_impl="fused_interpret")
    fops = host_flat_ops(task, True)
    p_end, aux_flat = make_local_fn(task, fspec)(
        key, fops.flatten(params), {}, x_all[1], y_all[1], jnp.float32(1.0))
    assert set(p_end) == set(fops.flatten(params))     # flat in, flat out
    np.testing.assert_allclose(float(aux_tree["loss"]),
                               float(aux_flat["loss"]), atol=1e-5, rtol=1e-5)
    _assert_tree_close(fops.unflatten(p_end), w_tree, 2e-5)


# ---------------------------------------------------------------------------
# pod backend: fused sequential delta accumulation + server moments
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def lm_setup():
    from repro.configs import get_reduced
    cfg = get_reduced("qwen1.5-0.5b")
    data = make_synthetic_tokenlm(n_clients=8, seq_len=16, n_seq_per_client=8,
                                  vocab=cfg.vocab_size, beta=0.5, seed=SEED)
    return lm_task(cfg), data


def _pod_sched(rounds=2, chunk=2):
    return RoundSchedule(rounds=rounds, lr_decay=1.0, eval_every=0, seed=SEED,
                         chunk_size=chunk, sampling="host",
                         host_rng_offset=HOST_RNG_OFFSET_P2)


@pytest.mark.parametrize("algorithm,server_opt,server_lr,tol", [
    ("fedavg", "none", 1.0, 2e-5),
    ("scaffold", "none", 1.0, 2e-5),
    ("fedavg", "momentum", 0.5, 2e-5),
    ("fedavg", "adam", 0.02, 1e-2),
])
def test_pod_fused_matches_tree(lm_setup, algorithm, server_opt, server_lr,
                                tol):
    from repro.fl.local import UPDATE_IMPLS  # noqa: F401 (doc pointer)
    from repro.fl.pod import PodAggregateStrategy
    from repro.launch.mesh import make_host_mesh

    task, data = lm_setup
    mesh = make_host_mesh()
    spec = LocalSpec(n_steps=2, batch_size=4, lr=0.01, momentum=0.9,
                     variant="scaffold" if algorithm == "scaffold"
                     else "plain")
    mk = lambda s: PodAggregateStrategy(         # noqa: E731
        spec=s, algorithm=algorithm, mesh=mesh, clients_per_round=2,
        server_opt=server_opt, server_lr=server_lr)
    tree = run_rounds(task, data, mk(spec), _pod_sched())
    fused = run_rounds(task, data,
                       mk(dc.replace(spec, update_impl="fused_interpret")),
                       _pod_sched())
    np.testing.assert_allclose([h["local_loss"] for h in tree.history],
                               [h["local_loss"] for h in fused.history],
                               atol=1e-5, rtol=1e-5)
    _assert_tree_close(tree.params, fused.params, tol)


# ---------------------------------------------------------------------------
# multi-device: fused == tree under a REAL sharded FSDP×TP layout
# ---------------------------------------------------------------------------

_SHARDED_PARITY_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    import dataclasses as dc
    import jax
    import numpy as np
    from repro.configs import get_reduced
    from repro.data.synthetic import make_synthetic_tokenlm
    from repro.fl.engine import RoundSchedule, run_rounds
    from repro.fl.local import LocalSpec
    from repro.fl.pod import PodAggregateStrategy, PodRelayStrategy
    from repro.fl.simulation import HOST_RNG_OFFSET_P2
    from repro.fl.task import lm_task

    mesh = jax.make_mesh((4, 4), ("data", "model"))
    cfg = get_reduced("qwen1.5-0.5b")
    task = lm_task(cfg)
    data = make_synthetic_tokenlm(n_clients=8, seq_len=16,
                                  n_seq_per_client=8,
                                  vocab=cfg.vocab_size, beta=0.5, seed=0)
    sched = lambda: RoundSchedule(rounds=2, lr_decay=1.0, eval_every=2,
                                  eval_batch=8, seed=0, chunk_size=2,
                                  sampling="host",
                                  host_rng_offset=HOST_RNG_OFFSET_P2)
    spec = LocalSpec(n_steps=2, batch_size=8, lr=0.01, momentum=0.9)
    mk = lambda s: PodAggregateStrategy(
        spec=s, algorithm="fedavg", mesh=mesh, clients_per_round=2,
        server_opt="adam", server_lr=0.02)
    fspec = dc.replace(spec, update_impl="fused_interpret")

    # the carry buckets really shard over their mesh-axis groups
    fops = mk(fspec).flat_ops(task)
    sh = fops.shardings()
    sharded = [n for n, s in sh.items() if any(ax is not None
                                               for ax in s.spec)]
    assert sharded, ("no sharded bucket", {n: s.spec for n, s in sh.items()})
    bufs = fops.place(fops.flatten(task.init(jax.random.PRNGKey(0))))
    for name in sharded:
        spec0 = bufs[name].sharding.spec
        assert spec0 and spec0[0] is not None, (name, spec0)

    tree = run_rounds(task, data, mk(spec), sched())
    fused = run_rounds(task, data, mk(fspec), sched())
    np.testing.assert_allclose([h["local_loss"] for h in tree.history],
                               [h["local_loss"] for h in fused.history],
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(tree.history[-1]["acc"],
                               fused.history[-1]["acc"], atol=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(tree.params),
                    jax.tree_util.tree_leaves(fused.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=1e-2, rtol=1e-2)

    # P1 relay parity on the same mesh (flat relay carry)
    mkr = lambda s: PodRelayStrategy(spec=s, mesh=mesh, clients_per_round=2)
    rsched = lambda: RoundSchedule(rounds=2, lr_decay=1.0, eval_every=0,
                                   seed=0, chunk_size=2, sampling="host",
                                   host_rng_offset=31)
    rt = run_rounds(task, data, mkr(spec), rsched())
    rf = run_rounds(task, data, mkr(fspec), rsched())
    np.testing.assert_allclose([h["local_loss"] for h in rt.history],
                               [h["local_loss"] for h in rf.history],
                               atol=1e-5, rtol=1e-5)
    print("FUSED_SHARDED_PARITY_OK")
""")


@pytest.mark.slow
def test_pod_fused_sharded_layout_parity_subprocess():
    """fused == tree on the pod backend under a 4×4 FSDP×TP mesh: the
    flat-first carries shard per mesh-axis bucket (no more fused/sharded
    mutual exclusion) and both aggregate + relay rounds agree with the
    tree oracle, in-program eval included."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", _SHARDED_PARITY_SCRIPT],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "FUSED_SHARDED_PARITY_OK" in out.stdout


# ---------------------------------------------------------------------------
# hypothesis sweep: fused DP aggregation == tree DP aggregation
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    def _dp_case(seed, n_leaves, k):
        """Deterministic (params, w_locals, weights) from a drawn seed."""
        rng = np.random.default_rng(seed)
        shapes = [tuple(rng.integers(1, 7, size=rng.integers(1, 3)))
                  for _ in range(n_leaves)]
        params = {f"p{i}": jnp.asarray(rng.normal(size=s), jnp.float32)
                  for i, s in enumerate(shapes)}
        w_locals = {f"p{i}": jnp.asarray(
            rng.normal(size=(k,) + s, scale=rng.uniform(0.01, 3.0)),
            jnp.float32) for i, s in enumerate(shapes)}
        weights = jnp.asarray(rng.uniform(0.5, 4.0, size=k), jnp.float32)
        ids = jnp.asarray(rng.choice(32, size=k, replace=False), jnp.int32)
        return params, w_locals, weights, ids

    @given(seed=st.integers(0, 2 ** 30),
           n_leaves=st.integers(1, 4),
           k=st.integers(1, 6),
           clip=st.one_of(st.none(),
                          st.floats(0.05, 20.0, allow_nan=False)),
           sigma=st.sampled_from([0.0, 0.05, 0.7]),
           secure_agg=st.booleans())
    @settings(max_examples=30, deadline=None)
    def test_fused_dp_aggregate_matches_tree_sweep(
            seed, n_leaves, k, clip, sigma, secure_agg):
        """For random (clip, sigma, K, shapes, secure-agg flag) the fused
        single-pass DP aggregate and the tree oracle agree: same clip
        scales, same noise/mask bits (per-leaf keyed draws), one kernel
        pass vs tree_map arithmetic."""
        if clip is None and sigma > 0.0:
            sigma = 0.0         # DPSpec: noise requires a finite clip
        dp = None if clip is None else privacy.DPSpec(clip, sigma)
        params, w_locals, weights, ids = _dp_case(seed, n_leaves, k)
        rk = jax.random.PRNGKey(seed % 997)

        ref = privacy.tree_dp_aggregate(dp, secure_agg, rk, ids, params,
                                        w_locals, weights)
        view = FlatView.of(params)
        fops = FlatParamOps(view=view, interpret=True)
        got = fops.unflatten(privacy.fused_dp_aggregate(
            dp, secure_agg, fops, rk, ids, fops.flatten(params),
            view.flatten_stacked(w_locals), weights))
        for a, b in zip(jax.tree_util.tree_leaves(ref),
                        jax.tree_util.tree_leaves(got)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=3e-5, rtol=3e-5)
