"""Property-based tests (hypothesis) on the system's invariants.

Targets the algebra the FL stack rests on — if any of these break, every
higher-level result is silently wrong:

  - Table IV closed forms == a step-by-step ledger simulation, for ALL
    (algorithm, K, T, X) — the accounting identity.
  - Dirichlet partitioning is a disjoint cover with min-size guarantee.
  - tree_math aggregation identities (FedAvg = convex combination).
  - optimizer algebra (SGD/AdamW step identities, clipping bound).
  - checkpoint save/load round-trips arbitrary nested pytrees.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="optional dev dep (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import comm_accounting as acc
from repro.core.comm_accounting import CommLedger
from repro.data.partition import dirichlet_partition, partition_stats
from repro.optim.optimizers import adamw, sgd
from repro.utils import tree_math as tm

# ---------------------------------------------------------------------------
# Table IV accounting identity
# ---------------------------------------------------------------------------

ALGOS = ("fedavg", "fedprox", "moon", "scaffold")


class _FakeParams:
    """Stands in for a params pytree of a given byte size."""

    def __init__(self, n_bytes):
        self.arr = np.zeros(n_bytes, dtype=np.uint8)

    def tree(self):
        return {"w": self.arr}


@given(algo=st.sampled_from(ALGOS),
       k_p1=st.integers(1, 64), t_cyc=st.integers(0, 40),
       k_p2=st.integers(1, 64), t_res=st.integers(0, 40),
       n_bytes=st.integers(1, 10_000))
@settings(max_examples=60, deadline=None)
def test_ledger_equals_closed_form(algo, k_p1, t_cyc, k_p2, t_res, n_bytes):
    params = _FakeParams(n_bytes).tree()
    led = CommLedger()
    for _ in range(t_cyc):
        led.record_cyclic_round(k_p1, params)
    for _ in range(t_res):
        led.record_round(algo, k_p2, params)
    want = acc.overhead_with_cyclic(algo, k_p1, t_cyc, k_p2, t_res, n_bytes)
    assert led.total_bytes == want
    # w/o-cyclic closed form as the t_cyc=0 special case
    assert acc.overhead_with_cyclic(algo, k_p1, 0, k_p2, t_res, n_bytes) == \
        acc.overhead_without_cyclic(algo, k_p2, t_res, n_bytes)


@given(algo=st.sampled_from(ALGOS), k_p1=st.integers(1, 32),
       t_cyc=st.integers(1, 32), k_p2=st.integers(1, 32),
       x=st.integers(1, 10_000))
@settings(max_examples=30, deadline=None)
def test_rounds_budget_equivalent_consistency(algo, k_p1, t_cyc, k_p2, x):
    """P1's cost expressed in P2 rounds must satisfy
    cost(P1) == equivalent_rounds * per-P2-round cost."""
    eq = acc.rounds_budget_equivalent(algo, k_p1, t_cyc, k_p2, x)
    per_round = acc.overhead_without_cyclic(algo, k_p2, 1, x)
    assert math.isclose(eq * per_round, 2 * k_p1 * t_cyc * x, rel_tol=1e-9)


# ---------------------------------------------------------------------------
# Dirichlet partitioning
# ---------------------------------------------------------------------------

@given(n=st.integers(60, 400), n_clients=st.integers(2, 12),
       n_classes=st.integers(2, 10),
       beta=st.floats(0.05, 5.0, allow_nan=False),
       seed=st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_dirichlet_partition_is_disjoint_cover(n, n_clients, n_classes, beta,
                                               seed):
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, n_classes, size=n)
    parts = dirichlet_partition(labels, n_clients, beta, rng,
                                min_per_client=2)
    assert len(parts) == n_clients
    allidx = np.concatenate(parts)
    # disjoint cover (up to the documented top-up fallback which may
    # duplicate a few indices): every original index is assigned
    assert set(allidx.tolist()) == set(range(n)) or len(allidx) >= n
    assert min(len(p) for p in parts) >= 2
    stats = partition_stats(labels, parts)
    assert stats["coverage"] == 1.0


@given(seed=st.integers(0, 1000))
@settings(max_examples=10, deadline=None)
def test_dirichlet_beta_monotone_heterogeneity(seed):
    """Smaller beta ⇒ more heterogeneous label distributions (on average)
    — the knob the paper's three non-IID scenarios turn."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 10, size=2000)
    tvs = []
    for beta in (0.1, 5.0):
        parts = dirichlet_partition(labels, 10, beta,
                                    np.random.default_rng(seed + 1))
        tvs.append(partition_stats(labels, parts)["mean_tv_from_global"])
    assert tvs[0] > tvs[1]


# ---------------------------------------------------------------------------
# tree_math aggregation algebra
# ---------------------------------------------------------------------------

def _tree_strategy(draw):
    shape = draw(st.sampled_from([(3,), (2, 4), (5, 1)]))
    n = draw(st.integers(2, 5))
    vals = draw(st.lists(
        st.floats(-10, 10, allow_nan=False, width=32),
        min_size=int(np.prod(shape)) * n,
        max_size=int(np.prod(shape)) * n))
    arrs = np.array(vals, np.float32).reshape((n,) + shape)
    return arrs


@given(data=st.data())
@settings(max_examples=30, deadline=None)
def test_weighted_mean_is_convex_combination(data):
    arrs = _tree_strategy(data.draw)
    n = arrs.shape[0]
    w = np.array(data.draw(st.lists(st.floats(0.1, 10, allow_nan=False),
                                    min_size=n, max_size=n)), np.float32)
    trees = [{"a": jnp.asarray(arrs[i])} for i in range(n)]
    out = tm.weighted_mean(trees, w)
    # must lie inside the convex hull elementwise
    stack = arrs
    assert np.all(np.asarray(out["a"]) <= stack.max(0) + 1e-4)
    assert np.all(np.asarray(out["a"]) >= stack.min(0) - 1e-4)
    # equal weights == plain mean
    eq = tm.weighted_mean(trees, np.ones(n, np.float32))
    np.testing.assert_allclose(np.asarray(eq["a"]), stack.mean(0), atol=1e-5)


@given(data=st.data())
@settings(max_examples=30, deadline=None)
def test_stacked_weighted_mean_matches_listwise(data):
    arrs = _tree_strategy(data.draw)
    n = arrs.shape[0]
    w = np.array(data.draw(st.lists(st.floats(0.1, 10, allow_nan=False),
                                    min_size=n, max_size=n)), np.float32)
    stacked = {"a": jnp.asarray(arrs)}
    listwise = tm.weighted_mean([{"a": jnp.asarray(arrs[i])} for i in range(n)],
                                w)
    out = tm.stacked_weighted_mean(stacked, jnp.asarray(w))
    np.testing.assert_allclose(np.asarray(out["a"]),
                               np.asarray(listwise["a"]), atol=1e-4)


@given(scale=st.floats(0.01, 100, allow_nan=False),
       max_norm=st.floats(0.1, 10, allow_nan=False))
@settings(max_examples=30, deadline=None)
def test_global_clip_bounds_norm(scale, max_norm):
    tree = {"a": jnp.full((4, 4), scale), "b": jnp.full((3,), -scale)}
    clipped = tm.global_clip(tree, max_norm)
    assert float(tm.norm(clipped)) <= max_norm * (1 + 1e-5)
    # no-op when already within bound
    if float(tm.norm(tree)) <= max_norm:
        np.testing.assert_allclose(np.asarray(clipped["a"]),
                                   np.asarray(tree["a"]))


def test_filter_normalize_matches_reference_norms():
    key = jax.random.PRNGKey(0)
    ref = {"w": jax.random.normal(key, (8, 8)), "b": jnp.ones((8,)) * 3}
    d = tm.random_like(jax.random.PRNGKey(1), ref)
    out = tm.filter_normalize(d, ref)
    for k in ref:
        np.testing.assert_allclose(
            float(jnp.linalg.norm(out[k].reshape(-1))),
            float(jnp.linalg.norm(ref[k].reshape(-1))), rtol=1e-5)


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------

@given(lr=st.floats(1e-4, 0.5, allow_nan=False),
       mom=st.sampled_from([0.0, 0.5, 0.9]),
       wd=st.sampled_from([0.0, 0.01]))
@settings(max_examples=20, deadline=None)
def test_sgd_first_step_identity(lr, mom, wd):
    """First SGD step: w1 = w0 − lr·(g + wd·w0) regardless of momentum
    (buffer starts at 0 and heavyball uses m=β·0+g)."""
    opt = sgd(lr, momentum=mom, weight_decay=wd)
    params = {"w": jnp.asarray([1.0, -2.0, 3.0])}
    grads = {"w": jnp.asarray([0.5, 0.5, -1.0])}
    state = opt.init(params)
    new, _ = opt.apply(grads, state, params)
    want = params["w"] - lr * (grads["w"] + wd * params["w"])
    np.testing.assert_allclose(np.asarray(new["w"]), np.asarray(want),
                               rtol=2e-5, atol=2e-6)


def test_sgd_converges_on_quadratic():
    opt = sgd(0.1, momentum=0.9)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = {"w": params["w"]}        # ∇(0.5||w||²)
        params, state = opt.apply(grads, state, params)
    assert float(tm.norm(params)) < 1e-3


def test_adamw_converges_on_quadratic():
    opt = adamw(0.05)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(300):
        grads = {"w": params["w"]}
        params, state = opt.apply(grads, state, params)
    assert float(tm.norm(params)) < 1e-2


# ---------------------------------------------------------------------------
# checkpoint round-trip
# ---------------------------------------------------------------------------

_leaf = st.sampled_from([
    np.zeros((2, 3), np.float32), np.arange(5, dtype=np.int32),
    np.ones((1,), np.float64), np.array(7, np.int64),
])


@st.composite
def _pytrees(draw, depth=2):
    if depth == 0:
        return draw(_leaf)
    kind = draw(st.sampled_from(["leaf", "dict", "list", "tuple"]))
    if kind == "leaf":
        return draw(_leaf)
    n = draw(st.integers(1, 3))
    if kind == "dict":
        keys = draw(st.lists(st.sampled_from("abcdef"), min_size=n, max_size=n,
                             unique=True))
        return {k: draw(_pytrees(depth=depth - 1)) for k in keys}
    seq = [draw(_pytrees(depth=depth - 1)) for _ in range(n)]
    return seq if kind == "list" else tuple(seq)


@given(tree=_pytrees())
@settings(max_examples=25, deadline=None)
def test_checkpoint_roundtrip(tree):
    import tempfile
    from repro.checkpoint.store import load_pytree, save_pytree
    with tempfile.TemporaryDirectory() as d:
        path = f"{d}/ckpt.npz"
        save_pytree(path, tree, metadata={"round": 3})
        back = load_pytree(path)
    a_leaves, a_def = jax.tree_util.tree_flatten(tree)
    b_leaves, b_def = jax.tree_util.tree_flatten(back)
    assert a_def == b_def
    for a, b in zip(a_leaves, b_leaves):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert np.asarray(a).dtype == np.asarray(b).dtype


def test_checkpoint_manager_gc_and_latest():
    import tempfile
    from repro.checkpoint.store import CheckpointManager
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2)
        for r in (1, 2, 3, 4):
            mgr.save(r, {"w": np.full((2,), r, np.float32)})
        assert mgr.latest().endswith("ckpt_4.npz")
        restored = mgr.restore()
        np.testing.assert_array_equal(restored["w"], np.full((2,), 4))
        assert len(mgr._rounds()) == 2  # gc keeps only 2
