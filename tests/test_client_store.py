"""SparseClientStateStore tests: the participation-indexed active-set
table against its dense oracle.

  - store-level gather/scatter round-trips over random id sequences with
    capacity < n_clients (hypothesis sweeps + a seeded long-run), with
    eviction → host spill → refill of cold clients across dispatches;
  - spill=False is the documented *forgetful* mode (evicted rows revert
    to the init template);
  - capacity smaller than one dispatch's distinct participants raises;
  - engine parity: sparse == dense for scaffold and moon on host (tree
    AND fused paths, host AND replayed device sampling) and on the pod
    backend, with capacity forcing evictions/refills across chunks;
  - hierarchical (two-level) pod aggregation matches the sequential
    scan within float reassociation tolerance, on both impls.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.federated import FederatedDataset
from repro.fl.engine import (
    DENSE_STORE,
    AggregateStrategy,
    DenseClientStateStore,
    RoundSchedule,
    SparseClientStateStore,
    run_rounds,
)
from repro.fl.local import LocalSpec
from repro.fl.pod import (
    PodAggregateStrategy,
    ShardedSparseClientStateStore,
)
from repro.fl.task import vision_task
from repro.launch.mesh import make_host_mesh

SEED = 0
N_CLIENTS = 8
CAPACITY = 4            # < N_CLIENTS and < chunk×K distinct worst case? no:
                        # chunk=2 × K=2 → ≤4 distinct per dispatch — tight fit


@pytest.fixture(scope="module")
def setup():
    task = vision_task("mlp", in_ch=1, seed_kwargs={"img": 8, "d_hidden": 16})
    rng = np.random.default_rng(SEED)
    per = 16
    x = rng.normal(size=(N_CLIENTS, per, 8, 8, 1)).astype(np.float32)
    y = rng.integers(0, 10, size=(N_CLIENTS, per)).astype(np.int32)
    data = FederatedDataset(x=x, y=y,
                            n_real=np.full((N_CLIENTS,), per, np.int32),
                            test_x=x[0], test_y=y[0], n_classes=10,
                            name="store-test")
    return task, data


def _template():
    return {"a": jnp.arange(3, dtype=jnp.float32),
            "b": jnp.zeros((2, 2), jnp.float32)}


def _rows_for(ids, scale):
    """Deterministic per-client rows so scatter payloads are recognizable."""
    ids = np.asarray(ids, np.float32)
    return {"a": jnp.asarray(scale * ids[:, None] + np.arange(3)[None, :],
                             jnp.float32),
            "b": jnp.asarray(np.broadcast_to(
                (scale * ids)[:, None, None], (len(ids), 2, 2))
                .astype(np.float32))}


def _drive(store, dispatches, n_clients):
    """Replay a sequence of dispatches through a store AND a dense dict
    reference; every dispatch gathers (checking residency brought the
    right rows in), rewrites the rows, and scatters back."""
    state = store.init(_template(), n_clients)
    reference = {}
    for t, ids in enumerate(dispatches):
        ids = np.asarray(sorted(ids), np.int32)
        if ids.size == 0:
            continue
        state = store.prepare_chunk(state, ids)
        got = store.gather(state, jnp.asarray(ids))
        for j, cid in enumerate(ids):
            want = reference.get(int(cid))
            if want is None:
                want = jax.tree_util.tree_map(np.asarray, _template())
            np.testing.assert_array_equal(np.asarray(got["a"][j]), want["a"])
            np.testing.assert_array_equal(np.asarray(got["b"][j]), want["b"])
        rows = _rows_for(ids, scale=float(t + 1))
        state = store.scatter(state, jnp.asarray(ids), rows)
        for j, cid in enumerate(ids):
            reference[int(cid)] = {"a": np.asarray(rows["a"][j]),
                                   "b": np.asarray(rows["b"][j])}
    return state, reference


def _check_dense_view(store, state, reference, n_clients):
    dense = store.to_dense(state)
    tmpl = jax.tree_util.tree_map(np.asarray, _template())
    for cid in range(n_clients):
        want = reference.get(cid, tmpl)
        np.testing.assert_array_equal(np.asarray(dense["a"][cid]), want["a"])
        np.testing.assert_array_equal(np.asarray(dense["b"][cid]), want["b"])


def test_gather_scatter_roundtrip_with_eviction_refill():
    """A client written in dispatch 0, evicted while others run, must
    come back with its written row (host spill) in a later dispatch."""
    store = SparseClientStateStore(capacity=3)
    dispatches = [[0, 1, 2], [3, 4, 5], [6, 7, 3], [0, 1, 5], [2, 4, 6]]
    state, reference = _drive(store, dispatches, n_clients=8)
    _check_dense_view(store, state, reference, n_clients=8)


def test_forgetful_mode_drops_evicted_rows():
    store = SparseClientStateStore(capacity=2, spill=False)
    state = store.init(_template(), 6)
    state = store.prepare_chunk(state, np.array([0, 1]))
    state = store.scatter(state, jnp.array([0, 1]), _rows_for([0, 1], 9.0))
    state = store.prepare_chunk(state, np.array([2, 3]))   # evicts 0 and 1
    state = store.prepare_chunk(state, np.array([0]))      # 0 refaults...
    got = store.gather(state, jnp.array([0]))
    tmpl = _template()                                     # ...as the template
    np.testing.assert_array_equal(np.asarray(got["a"][0]),
                                  np.asarray(tmpl["a"]))


def test_capacity_must_cover_one_dispatch():
    store = SparseClientStateStore(capacity=2)
    state = store.init(_template(), 8)
    with pytest.raises(ValueError, match="capacity"):
        store.prepare_chunk(state, np.array([0, 1, 2]))


def test_population_reports_n_clients_not_capacity():
    sparse = SparseClientStateStore(capacity=3)
    state = sparse.init(_template(), 11)
    assert sparse.population(state) == 11
    dense_state = DENSE_STORE.init(_template(), 11)
    assert DENSE_STORE.population(dense_state) == 11


def test_hypothesis_random_id_sequences():
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.settings(max_examples=25, deadline=None)
    @hyp.given(st.lists(
        st.sets(st.integers(min_value=0, max_value=9),
                min_size=0, max_size=4),
        min_size=1, max_size=8))
    def run(dispatches):
        store = SparseClientStateStore(capacity=4)
        state, reference = _drive(store, dispatches, n_clients=10)
        _check_dense_view(store, state, reference, n_clients=10)

    run()


# ---------------------------------------------------------------------------
# engine parity: sparse == dense
# ---------------------------------------------------------------------------

def _sched(sampling, rounds=6, chunk=2):
    return RoundSchedule(rounds=rounds, lr_decay=1.0, eval_every=0,
                         seed=SEED, chunk_size=chunk, sampling=sampling,
                         host_rng_offset=17)


def _host_run(task, data, algo, impl, store):
    spec = LocalSpec(n_steps=2, batch_size=4, lr=0.05, variant=algo,
                     update_impl=impl)
    strat = AggregateStrategy(spec=spec, algorithm=algo, participation=0.25,
                              state_store=store)
    return run_rounds(task, data, strat, _sched("host"))


def _assert_same(res_a, res_b, atol=0.0):
    np.testing.assert_allclose(
        [h["local_loss"] for h in res_a.history],
        [h["local_loss"] for h in res_b.history], atol=atol, rtol=0)
    for a, b in zip(jax.tree_util.tree_leaves(res_a.params),
                    jax.tree_util.tree_leaves(res_b.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=atol, rtol=0)


@pytest.mark.parametrize("algo", ["scaffold", "moon"])
@pytest.mark.parametrize("impl", ["tree", "fused_interpret"])
def test_host_sparse_matches_dense(setup, algo, impl):
    """Bitwise: residency management must be invisible to the math.
    capacity=4 with chunk=2 × K=2 drives eviction + spill-refill of
    revisited clients across the 3 dispatches."""
    task, data = setup
    dense = _host_run(task, data, algo, impl, DenseClientStateStore())
    sparse = _host_run(task, data, algo, impl,
                       SparseClientStateStore(capacity=CAPACITY))
    _assert_same(dense, sparse, atol=0.0)


@pytest.mark.parametrize("impl", ["tree", "fused_interpret"])
def test_device_sampling_replay_matches_dense(setup, impl):
    """sampling="device": the store's host-side replay of the in-program
    threefry draw faults the right rows in — still bitwise."""
    task, data = setup
    spec = LocalSpec(n_steps=2, batch_size=4, lr=0.05, variant="scaffold",
                     update_impl=impl)

    def run(store):
        strat = AggregateStrategy(spec=spec, algorithm="scaffold",
                                  participation=0.25, state_store=store)
        return run_rounds(task, data, strat, _sched("device", rounds=4))

    _assert_same(run(DenseClientStateStore()),
                 run(SparseClientStateStore(capacity=CAPACITY)), atol=0.0)


@pytest.mark.parametrize("algo", ["scaffold", "moon"])
def test_pod_sparse_matches_dense(setup, algo):
    task, data = setup
    mesh = make_host_mesh()
    spec = LocalSpec(n_steps=2, batch_size=4, lr=0.05, variant=algo,
                     update_impl="fused_interpret")

    def run(store):
        kwargs = {"state_store": store} if store is not None else {}
        strat = PodAggregateStrategy(spec=spec, algorithm=algo, mesh=mesh,
                                     clients_per_round=2, **kwargs)
        return run_rounds(task, data, strat, _sched("host"))

    sparse = ShardedSparseClientStateStore(capacity=CAPACITY, mesh=mesh)
    _assert_same(run(None), run(sparse), atol=0.0)


# ---------------------------------------------------------------------------
# hierarchical (two-level) aggregation == sequential
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl", ["tree", "fused_interpret"])
def test_pod_hierarchical_matches_sequential(setup, impl):
    """n_pods=2 on a 1-device mesh: per-pod partials + one cross-pod
    combine reassociate the weighted sum — equal within fp tolerance."""
    task, data = setup
    mesh = make_host_mesh()
    spec = LocalSpec(n_steps=2, batch_size=4, lr=0.05, variant="scaffold",
                     update_impl=impl)

    def run(aggregation):
        strat = PodAggregateStrategy(
            spec=spec, algorithm="scaffold", mesh=mesh, clients_per_round=4,
            aggregation=aggregation, n_pods=2,
            state_store=ShardedSparseClientStateStore(capacity=N_CLIENTS,
                                                      mesh=mesh))
        return run_rounds(task, data, strat, _sched("host", rounds=3))

    _assert_same(run("sequential"), run("hierarchical"), atol=2e-5)


def test_hierarchical_requires_divisible_pods(setup):
    task, data = setup
    mesh = make_host_mesh()
    spec = LocalSpec(n_steps=2, batch_size=4, lr=0.05)
    strat = PodAggregateStrategy(spec=spec, algorithm="fedavg", mesh=mesh,
                                 clients_per_round=3,
                                 aggregation="hierarchical", n_pods=2)
    with pytest.raises(ValueError, match="divisible"):
        run_rounds(task, data, strat, _sched("host", rounds=1, chunk=1))


def test_unknown_aggregation_rejected():
    mesh = make_host_mesh()
    with pytest.raises(ValueError, match="aggregation"):
        PodAggregateStrategy(spec=LocalSpec(n_steps=1, batch_size=2, lr=0.1),
                             mesh=mesh, aggregation="tiered")


def test_sparse_store_is_identity_hashed():
    """Mutable spill members force identity semantics — two stores must
    be two chunk-cache entries."""
    a = SparseClientStateStore(capacity=4)
    b = SparseClientStateStore(capacity=4)
    assert hash(a) != hash(b) or a is b
    assert a != b
    assert dataclasses.is_dataclass(a)
