"""Round-engine tests: chunk-size invariance, determinism, seed-driver
parity, and the declarative phase schedule.

The seed repo drove P1/P2 with per-round host loops (np.random client
sampling + one jit dispatch per round).  The engine must (a) reproduce
those semantics exactly in sampling="host" mode — asserted here against
step-by-step reference loops built from the kept single-round fns — and
(b) be invariant to how many rounds are fused into one XLA dispatch.
"""
import dataclasses as dc

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cyclic import (HOST_RNG_OFFSET_P1, CyclicConfig,
                               cyclic_pretrain, make_cyclic_round_fn)
from repro.core.pipeline import Phase, run_phase_schedule
from repro.core.switch import FixedRounds
from repro.data.synthetic import DATASETS
from repro.fl.simulation import (HOST_RNG_OFFSET_P2, FLConfig,
                                 init_server_state, make_round_fn,
                                 run_federated)
from repro.fl.task import vision_task

SEED = 0


@pytest.fixture(scope="module")
def setup():
    data = DATASETS.get("cifar10-like")(n_clients=8, beta=0.5, seed=SEED,
                                        n_train=512, n_test=256)
    task = vision_task("lenet5", n_classes=10, in_ch=3)
    return task, data


def _fl(algorithm="fedavg", rounds=4, **kw):
    return FLConfig(algorithm=algorithm, rounds=rounds, participation=0.25,
                    local_steps=4, eval_every=2, seed=SEED, **kw)


def _leaves32(tree):
    return [np.asarray(x, np.float32) for x in jax.tree_util.tree_leaves(tree)]


# ---------------------------------------------------------------------------
# chunk-size invariance (satellite: parity test)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algorithm", ["fedavg", "scaffold"])
def test_chunked_matches_per_round(setup, algorithm):
    """chunk=4 must produce the same history/params as chunk=1: the
    per-round key stream and lr schedule are chunk-independent."""
    task, data = setup
    r1 = run_federated(task, data, _fl(algorithm, chunk_size=1))
    r4 = run_federated(task, data, _fl(algorithm, chunk_size=4))
    assert len(r1.history) == len(r4.history)
    for a, b in zip(r1.history, r4.history):
        assert a["round"] == b["round"] and a["phase"] == b["phase"]
        assert abs(a["local_loss"] - b["local_loss"]) <= 1e-5
        assert abs(a.get("acc", 0.0) - b.get("acc", 0.0)) <= 1e-5
    for a, b in zip(_leaves32(r1.params), _leaves32(r4.params)):
        np.testing.assert_allclose(a, b, atol=1e-5, rtol=1e-5)


def test_chunked_relay_matches_per_round(setup):
    task, data = setup
    cfg1 = CyclicConfig(rounds=4, participation=0.25, local_steps=4,
                        eval_every=2, seed=SEED, chunk_size=1)
    r1 = cyclic_pretrain(task, data, cfg1)
    r4 = cyclic_pretrain(task, data, dc.replace(cfg1, chunk_size=4))
    for a, b in zip(r1.history, r4.history):
        assert abs(a["local_loss"] - b["local_loss"]) <= 1e-5
    for a, b in zip(_leaves32(r1.params), _leaves32(r4.params)):
        np.testing.assert_allclose(a, b, atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# determinism (on-device keyed sampling replaces host RNG state)
# ---------------------------------------------------------------------------

def test_engine_runs_are_deterministic(setup):
    task, data = setup
    a = run_federated(task, data, _fl("fedavg"))
    b = run_federated(task, data, _fl("fedavg"))
    assert a.history == b.history
    for x, y in zip(_leaves32(a.params), _leaves32(b.params)):
        np.testing.assert_array_equal(x, y)


def test_relay_runs_are_deterministic(setup):
    task, data = setup
    cfg = CyclicConfig(rounds=3, participation=0.25, local_steps=4,
                       eval_every=1, seed=SEED)
    assert cyclic_pretrain(task, data, cfg).history == \
        cyclic_pretrain(task, data, cfg).history


# ---------------------------------------------------------------------------
# seed-driver parity (sampling="host" reproduces the pre-engine loops)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("chunk", [1, 4])
def test_host_sampling_matches_seed_fl_loop(setup, chunk):
    """Reference reimplementation of the seed run_federated host loop
    (np rng(seed+17) sampling, one dispatch per round) vs the engine."""
    task, data = setup
    cfg = _fl("fedavg", rounds=4, chunk_size=chunk, sampling="host")

    rng = np.random.default_rng(cfg.seed + HOST_RNG_OFFSET_P2)
    key = jax.random.PRNGKey(cfg.seed)
    params = init_server_state(task, cfg, data.n_clients, None, key).params
    round_fn = make_round_fn(task, cfg)
    x_all, y_all, n_real = data.device_arrays()
    K = cfg.n_selected(data.n_clients)
    ref_losses = []
    for rnd in range(cfg.rounds):
        ids = jnp.asarray(rng.choice(data.n_clients, size=K, replace=False))
        weights = n_real[ids].astype(jnp.float32)
        lr_scale = jnp.asarray(cfg.lr_decay ** rnd, jnp.float32)
        key, rk = jax.random.split(key)
        params, _, metrics = round_fn(rk, params, x_all, y_all, ids, weights,
                                      lr_scale, {})
        ref_losses.append(float(metrics["local_loss"]))

    res = run_federated(task, data, cfg)
    got_losses = [h["local_loss"] for h in res.history]
    np.testing.assert_allclose(got_losses, ref_losses, atol=1e-5, rtol=1e-5)
    for a, b in zip(_leaves32(res.params), _leaves32(params)):
        np.testing.assert_allclose(a, b, atol=1e-5, rtol=1e-5)


def test_host_sampling_matches_seed_cyclic_loop(setup):
    """Same for P1: np rng(seed+31) sampling + per-round relay dispatch."""
    task, data = setup
    cfg = CyclicConfig(rounds=3, participation=0.25, local_steps=4,
                       eval_every=1, seed=SEED, chunk_size=4, sampling="host")

    rng = np.random.default_rng(cfg.seed + HOST_RNG_OFFSET_P1)
    key = jax.random.PRNGKey(cfg.seed)
    params = task.init(key)
    round_fn = make_cyclic_round_fn(task, cfg)
    x_all, y_all, _ = data.device_arrays()
    K = cfg.n_selected(data.n_clients)
    ref_losses = []
    for rnd in range(cfg.rounds):
        ids = jnp.asarray(rng.choice(data.n_clients, size=K, replace=False))
        lr_scale = jnp.asarray(cfg.lr_decay ** rnd, jnp.float32)
        key, rk = jax.random.split(key)
        params, metrics = round_fn(rk, params, x_all, y_all, ids, lr_scale)
        ref_losses.append(float(metrics["local_loss"]))

    res = cyclic_pretrain(task, data, cfg)
    got_losses = [h["local_loss"] for h in res.history]
    np.testing.assert_allclose(got_losses, ref_losses, atol=1e-5, rtol=1e-5)
    for a, b in zip(_leaves32(res.params), _leaves32(params)):
        np.testing.assert_allclose(a, b, atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# engine-owned plumbing
# ---------------------------------------------------------------------------

def test_init_params_buffer_survives_engine_donation(setup):
    """The engine donates its carries; the caller's init_params must not
    be invalidated (the pipeline reuses P1 params after P2 starts)."""
    task, data = setup
    w0 = task.init(jax.random.PRNGKey(SEED))
    run_federated(task, data, _fl("fedavg", rounds=2), init_params=w0)
    for leaf in jax.tree_util.tree_leaves(w0):
        assert np.isfinite(np.asarray(leaf)).all()


def test_switch_policy_applies_to_aggregate_phase(setup):
    """Policies now gate ANY phase boundary, not just P1."""
    task, data = setup
    res = run_federated(task, data, _fl("fedavg", rounds=6),
                        switch_policy=FixedRounds(t_cyc=2))
    assert len(res.history) == 2


def test_phase_schedule_alternation(setup):
    """Multi-cycle P1↔P2 alternation through one ledger — the scenario
    the declarative schedule unlocks."""
    task, data = setup
    cyc = CyclicConfig(rounds=2, participation=0.25, local_steps=4,
                       eval_every=1, seed=SEED)
    fl = _fl("fedavg", rounds=2)
    sched = run_phase_schedule(task, data, [
        Phase("P1", cyc), Phase("P2", fl),
        Phase("P1'", cyc), Phase("P2'", fl),
    ])
    hist = sched.history
    assert [h["phase"] for h in hist] == ["P1"] * 2 + ["P2"] * 2 + \
        ["P1'"] * 2 + ["P2'"] * 2
    assert [h["round"] for h in hist] == list(range(8))
    led = sched.ledger.summary()
    assert led["p1_rounds"] == 4 and led["p2_rounds"] == 4
    assert np.isfinite(hist[-1]["local_loss"])
