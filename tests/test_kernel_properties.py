"""Hypothesis shape/dtype sweeps for the Pallas kernels (interpret mode)
against the pure-jnp oracles — beyond the fixed grids in
test_kernels.py, these explore the padding/blocking edge space.

Examples are bounded small (interpret mode executes the kernel body in
Python) and deadlines disabled.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="optional dev dep (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ssd_scan import ssd_scan


@given(
    B=st.integers(1, 2),
    S=st.sampled_from([64, 96, 128, 160]),       # incl. non-block multiples
    KH=st.sampled_from([1, 2, 4]),
    G=st.integers(1, 3),                          # heads per kv head
    hd=st.sampled_from([32, 64]),
    seed=st.integers(0, 2**30),
)
@settings(max_examples=12, deadline=None)
def test_flash_attention_shape_sweep(B, S, KH, G, hd, seed):
    # the kernel is causal-only by design (decoder-only archs)
    H = KH * G
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KH, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KH, hd), jnp.float32)
    out = flash_attention(q, k, v, causal=True, block_q=64, block_k=64,
                          interpret=True)
    want = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=3e-5, rtol=3e-5)


@given(
    S=st.sampled_from([32, 48, 64, 96]),          # padding path at 48/96
    H=st.sampled_from([1, 2, 4]),
    P=st.sampled_from([16, 32]),
    N=st.sampled_from([8, 16]),
    chunk=st.sampled_from([16, 32]),
    seed=st.integers(0, 2**30),
)
@settings(max_examples=12, deadline=None)
def test_ssd_scan_shape_sweep(S, H, P, N, chunk, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    B = 1
    x = jax.random.normal(ks[0], (B, S, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (B, S, 1, N), jnp.float32)
    C = jax.random.normal(ks[4], (B, S, 1, N), jnp.float32)
    y, final = ssd_scan(x, dt, A, Bm, C, chunk=chunk, interpret=True)
    y_ref, final_ref = ref.ssd_ref(x, dt, A, Bm, C)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=3e-3, rtol=3e-3)
    np.testing.assert_allclose(np.asarray(final), np.asarray(final_ref),
                               atol=3e-3, rtol=3e-3)


@given(seed=st.integers(0, 2**30), q_offset=st.integers(0, 100))
@settings(max_examples=8, deadline=None)
def test_flash_attention_decode_offset_sweep(seed, q_offset):
    """Single-query decode against a 128-cache at arbitrary offsets."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (1, 1, 4, 32), jnp.float32)
    k = jax.random.normal(ks[1], (1, 128, 2, 32), jnp.float32)
    v = jax.random.normal(ks[2], (1, 128, 2, 32), jnp.float32)
    out = flash_attention(q, k, v, causal=True, q_offset=jnp.int32(q_offset),
                          block_q=64, block_k=64, interpret=True)
    want = ref.attention_ref(q, k, v, causal=True, q_offset=q_offset)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=3e-5, rtol=3e-5)
