"""Unit tests for the roofline-term extraction (HLO text parsing).

These are pure-text tests — the parser is the §Roofline data source, so
its byte accounting must be exact on synthetic HLO snippets.
"""
import numpy as np

from repro.launch.hlo_analysis import (
    RooflineTerms, _shape_bytes, collective_bytes_by_op,
    total_collective_bytes,
)

HLO = """
HloModule jit_step

ENTRY %main {
  %p0 = bf16[64,128]{1,0} parameter(0)
  %ag = bf16[64,2048]{1,0} all-gather(%p0), dimensions={1}
  %ar = f32[256]{0} all-reduce(%x), to_apply=%sum
  %rs = f32[16,16]{1,0} reduce-scatter(%y), dimensions={0}
  %a2a = bf16[8,32]{1,0} all-to-all(%z), dimensions={0}
  %cp = u8[1024]{0} collective-permute(%w), source_target_pairs={{0,1}}
  %ags = (bf16[64,2048]{1,0}, bf16[64,128]{1,0}) all-gather-start(%p0)
  %agd = bf16[64,2048]{1,0} all-gather-done(%ags)
  %dot = f32[64,64]{1,0} dot(%a, %b)
}
"""


def test_shape_bytes():
    assert _shape_bytes("bf16[64,128]") == 64 * 128 * 2
    assert _shape_bytes("f32[256]") == 1024
    assert _shape_bytes("(bf16[2,2], f32[3])") == 8 + 12
    assert _shape_bytes("pred[8]") == 8
    assert _shape_bytes("u8[]") == 0 or _shape_bytes("u8[]") == 1  # scalar


def test_collective_bytes_by_op():
    d = collective_bytes_by_op(HLO)
    assert d["all-gather"] == 64 * 2048 * 2 + (64 * 2048 * 2 + 64 * 128 * 2)
    assert d["all-reduce"] == 256 * 4
    assert d["reduce-scatter"] == 16 * 16 * 4
    assert d["all-to-all"] == 8 * 32 * 2
    assert d["collective-permute"] == 1024
    counts = d["_counts"]
    assert counts["all-gather"] == 2          # plain + start, done skipped
    total = total_collective_bytes(HLO)
    assert total == sum(v for k, v in d.items() if not k.startswith("_"))


def test_roofline_terms_bottleneck():
    t = RooflineTerms(arch="a", shape="s", mesh="m", n_chips=256,
                      hlo_flops=197e12,          # exactly 1s of compute
                      hlo_bytes=819e9 * 2,       # 2s of memory
                      collective_bytes=int(50e9 * 3),  # 3s of collective
                      collective_detail={}, model_flops=197e12 * 256)
    assert abs(t.t_compute - 1.0) < 1e-9
    assert abs(t.t_memory - 2.0) < 1e-9
    assert abs(t.t_collective - 3.0) < 1e-9
    assert t.bottleneck == "collective"
    np.testing.assert_allclose(t.useful_flops_ratio, 1.0)
    d = t.to_dict()
    assert d["bottleneck"] == "collective"
