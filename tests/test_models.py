"""Model-level invariants: causality, decode-path consistency, masking,
MoE routing, MTP, VLM prefix handling."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_text_batch
from repro.configs import get_reduced
from repro.models.transformer import (
    decode_step, init_lm, lm_forward, lm_loss, prefill,
)

DECODE_ARCHS = ["qwen2-1.5b", "tinyllama-1.1b", "deepseek-v3-671b",
                "mamba2-1.3b", "hymba-1.5b", "musicgen-medium",
                "internvl2-1b"]


def test_causality_dense():
    """Perturbing a future token must not change past logits."""
    cfg = get_reduced("qwen2-1.5b")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 32), 0, cfg.vocab_size)
    l1, _, _ = lm_forward(params, cfg, {"tokens": toks})
    toks2 = toks.at[0, 20].set((toks[0, 20] + 1) % cfg.vocab_size)
    l2, _, _ = lm_forward(params, cfg, {"tokens": toks2})
    np.testing.assert_allclose(l1[:, :20], l2[:, :20], atol=1e-5)
    assert float(jnp.max(jnp.abs(l1[:, 20:] - l2[:, 20:]))) > 1e-6


def test_causality_ssm():
    cfg = get_reduced("mamba2-1.3b")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 64), 0, cfg.vocab_size)
    l1, _, _ = lm_forward(params, cfg, {"tokens": toks})
    toks2 = toks.at[0, 40].set((toks[0, 40] + 1) % cfg.vocab_size)
    l2, _, _ = lm_forward(params, cfg, {"tokens": toks2})
    np.testing.assert_allclose(l1[:, :40], l2[:, :40], atol=1e-4)


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_prefill_decode_matches_full_forward(arch):
    """prefill(S) + decode_step == full forward at position S.

    This is THE serving-correctness invariant: the incremental path must
    produce the same next-token logits as the parallel path.
    """
    cfg = get_reduced(arch)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    B, S = 2, 48
    batch = make_text_batch(cfg, B=B, S=S + 1)
    max_len = S + 8

    if cfg.input_mode == "tokens":
        full = {"tokens": batch["tokens"]}
        pre = {"tokens": batch["tokens"][:, :S]}
        nxt = batch["tokens"][:, S:S + 1]
    elif cfg.input_mode == "vlm":
        full = {"patch_embeds": batch["patch_embeds"], "tokens": batch["tokens"]}
        pre = {"patch_embeds": batch["patch_embeds"],
               "tokens": batch["tokens"][:, : S - cfg.n_prefix_tokens]}
        nxt = batch["tokens"][:, S - cfg.n_prefix_tokens:
                              S - cfg.n_prefix_tokens + 1]
    else:  # embeddings
        full = {"frame_embeds": batch["frame_embeds"]}
        pre = {"frame_embeds": batch["frame_embeds"][:, :S]}
        nxt = batch["frame_embeds"][:, S:S + 1]

    logits_full, _, _ = lm_forward(params, cfg, full)
    _, cache, plen = prefill(params, cfg, pre, max_len=max_len)
    logits_dec, _ = decode_step(params, cfg, nxt, cache, jnp.int32(S))

    want = logits_full[:, S]
    got = logits_dec[:, 0]
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=2e-3, rtol=2e-3)


def test_prefill_last_logits_match_forward():
    cfg = get_reduced("tinyllama-1.1b")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 32), 0, cfg.vocab_size)
    logits_full, _, _ = lm_forward(params, cfg, {"tokens": toks})
    logits_pre, _, _ = prefill(params, cfg, {"tokens": toks}, max_len=40)
    np.testing.assert_allclose(np.asarray(logits_pre[:, 0]),
                               np.asarray(logits_full[:, -1]),
                               atol=1e-4, rtol=1e-4)


def test_multi_step_decode_consistency():
    """Greedy-decode 4 tokens incrementally vs re-running the full forward."""
    cfg = get_reduced("qwen2-1.5b")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    S0, n_new = 16, 4
    toks = jax.random.randint(jax.random.PRNGKey(3), (1, S0), 0, cfg.vocab_size)
    logits, cache, _ = prefill(params, cfg, {"tokens": toks}, max_len=S0 + n_new)
    seq = toks
    for i in range(n_new):
        nxt = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        seq = jnp.concatenate([seq, nxt], axis=1)
        logits, cache = decode_step(params, cfg, nxt, cache,
                                    jnp.int32(S0 + i))
    full_logits, _, _ = lm_forward(params, cfg, {"tokens": seq})
    # greedy argmax path must agree everywhere we decoded
    inc = jnp.argmax(logits[:, 0], axis=-1)
    par = jnp.argmax(full_logits[:, -1], axis=-1)
    np.testing.assert_array_equal(np.asarray(inc), np.asarray(par))


def test_label_mask_ignore():
    """-1 labels are excluded from the loss."""
    cfg = get_reduced("tinyllama-1.1b")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(4), (2, 16), 0, cfg.vocab_size)
    labels = jnp.roll(toks, -1, axis=1)
    l_all, _ = lm_loss(params, cfg, {"tokens": toks, "labels": labels})
    # mask the second half; loss must equal loss computed on first half only
    labels_masked = labels.at[:, 8:].set(-1)
    l_masked, _ = lm_loss(params, cfg, {"tokens": toks, "labels": labels_masked})
    logits, _, _ = lm_forward(params, cfg, {"tokens": toks})
    lg = logits[:, :8].astype(jnp.float32)
    manual = jnp.mean(jax.nn.logsumexp(lg, -1) - jnp.take_along_axis(
        lg, labels[:, :8, None], -1)[..., 0])
    np.testing.assert_allclose(float(l_masked), float(manual), rtol=1e-5)
    assert abs(float(l_all) - float(l_masked)) > 1e-6


def test_moe_aux_loss_positive_and_router_balance():
    cfg = get_reduced("deepseek-v2-lite-16b")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    batch = make_text_batch(cfg, B=2, S=64)
    _, metrics = lm_loss(params, cfg, batch)
    assert float(metrics["aux"]) >= 0.0
    assert bool(jnp.isfinite(metrics["aux"]))


def test_mtp_adds_loss_term():
    cfg = get_reduced("deepseek-v3-671b")
    assert cfg.mtp
    params = init_lm(jax.random.PRNGKey(0), cfg)
    batch = make_text_batch(cfg, B=2, S=32)
    total, metrics = lm_loss(params, cfg, batch)
    assert "mtp" in metrics and bool(jnp.isfinite(metrics["mtp"]))
    # total = xent + aux + w*mtp
    np.testing.assert_allclose(
        float(total),
        float(metrics["xent"] + metrics["aux"]
              + cfg.mtp_loss_weight * metrics["mtp"]), rtol=1e-5)


def test_vlm_prefix_excluded_from_loss():
    cfg = get_reduced("internvl2-1b")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    batch = make_text_batch(cfg, B=2, S=32)
    logits, _, _ = lm_forward(params, cfg, batch)
    P = cfg.n_prefix_tokens
    assert logits.shape[1] == 32            # prefix + text positions
    loss, _ = lm_loss(params, cfg, batch)
    assert bool(jnp.isfinite(loss))
    # changing the labels of text tokens changes the loss; the prefix has
    # no labels at all (shape check)
    assert batch["labels"].shape[1] == 32 - P


def test_swa_variant_restricts_context():
    """tinyllama-1.1b-swa: with window w, logits at position t only see
    the last w tokens — verify by perturbing a token outside the window."""
    cfg = get_reduced("tinyllama-1.1b-swa")
    assert cfg.window is not None
    w = cfg.window
    params = init_lm(jax.random.PRNGKey(0), cfg)
    # Stacked SWA compounds the receptive field: after L layers position t
    # depends on inputs back to ~t - L*w, so perturbing token 0 can reach
    # positions up to L*w.  Everything beyond must be bit-identical.
    rf = cfg.n_layers * w
    S = rf + 24
    toks = jax.random.randint(jax.random.PRNGKey(5), (1, S), 0, cfg.vocab_size)
    l1, _, _ = lm_forward(params, cfg, {"tokens": toks})
    toks2 = toks.at[0, 0].set((toks[0, 0] + 1) % cfg.vocab_size)
    l2, _, _ = lm_forward(params, cfg, {"tokens": toks2})
    if not cfg.global_attn_layers:
        tail = slice(rf + 1, None)
        np.testing.assert_allclose(l1[:, tail], l2[:, tail], atol=1e-5)
        # ...and within a single window the perturbation IS visible early on
        assert float(jnp.max(jnp.abs(l1[:, :w] - l2[:, :w]))) > 1e-6
