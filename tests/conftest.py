"""Shared test fixtures.  NOTE: no XLA_FLAGS here — tests must see the
single real CPU device (the 512-device override belongs ONLY to
repro.launch.dryrun)."""
import jax
import jax.numpy as jnp
import pytest


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


def make_text_batch(cfg, B=2, S=32, key=None):
    """Random token batch (with labels) for a reduced config."""
    key = key if key is not None else jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    if cfg.input_mode == "tokens":
        toks = jax.random.randint(k1, (B, S), 0, cfg.vocab_size)
        return {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    if cfg.input_mode == "vlm":
        P = cfg.n_prefix_tokens
        toks = jax.random.randint(k1, (B, S - P), 0, cfg.vocab_size)
        return {
            "patch_embeds": jax.random.normal(k2, (B, P, cfg.d_model), cfg.dtype),
            "tokens": toks,
            "labels": jnp.roll(toks, -1, axis=1),
        }
    # embeddings (audio)
    lbl_shape = (B, S, cfg.n_codebooks) if cfg.n_codebooks > 1 else (B, S)
    return {
        "frame_embeds": jax.random.normal(k2, (B, S, cfg.d_model), cfg.dtype),
        "labels": jax.random.randint(k1, lbl_shape, 0, cfg.vocab_size),
    }
