"""Per-architecture smoke tests (assignment requirement).

Each assigned architecture instantiates its REDUCED variant (≤2 layers,
d_model ≤ 512, ≤4 experts) and runs one forward + one train step on CPU,
asserting output shapes and the absence of NaNs.  Full configs are only
exercised by the dry-run (ShapeDtypeStruct, no allocation).
"""
import jax
import jax.numpy as jnp
import pytest

from conftest import make_text_batch
from repro.configs import ASSIGNED, get_config, get_reduced, param_count
from repro.launch.steps import TrainSpec, init_momentum, make_train_step
from repro.models.transformer import init_lm, lm_forward, lm_loss

B, S = 2, 64


@pytest.fixture(scope="module", params=ASSIGNED)
def arch(request):
    return request.param


def _reduced_and_batch(arch_name):
    cfg = get_reduced(arch_name)
    assert cfg.n_layers <= 2 and cfg.d_model <= 512
    if cfg.is_moe:
        assert cfg.n_experts <= 4
    params = init_lm(jax.random.PRNGKey(0), cfg)
    batch = make_text_batch(cfg, B=B, S=S)
    return cfg, params, batch


def test_forward_shapes_and_finite(arch):
    cfg, params, batch = _reduced_and_batch(arch)
    logits, aux, hidden = lm_forward(params, cfg, batch)
    n_pos = S if cfg.input_mode != "vlm" else S
    if cfg.n_codebooks > 1:
        assert logits.shape == (B, n_pos, cfg.n_codebooks, cfg.vocab_size)
    else:
        assert logits.shape == (B, n_pos, cfg.vocab_size)
    assert hidden.shape == (B, n_pos, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


def test_one_train_step_decreases_or_finite(arch):
    cfg, params, batch = _reduced_and_batch(arch)
    step = make_train_step(cfg, TrainSpec(lr=1e-2))
    mom = init_momentum(params)
    loss0, _ = lm_loss(params, cfg, batch)
    params2, mom2, metrics = jax.jit(step)(params, mom, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    # a second step on the SAME batch must not explode, and repeated steps
    # on one batch should reduce its loss (overfit sanity)
    for _ in range(5):
        params2, mom2, metrics = jax.jit(step)(params2, mom2, batch)
    loss5 = metrics["loss"]
    assert bool(jnp.isfinite(loss5))
    assert float(loss5) < float(loss0), (arch, float(loss0), float(loss5))


def test_microbatch_accumulation_matches_single(arch):
    """n_micro=2 must equal n_micro=1 up to numerics (same effective
    gradient: mean over microbatches)."""
    cfg, params, batch = _reduced_and_batch(arch)
    mom = init_momentum(params)
    p1, m1, _ = jax.jit(make_train_step(cfg, TrainSpec(lr=1e-2, n_micro=1)))(
        params, mom, batch)
    p2, m2, _ = jax.jit(make_train_step(cfg, TrainSpec(lr=1e-2, n_micro=2)))(
        params, mom, batch)
    # MoE routing / aux losses are batch-composition dependent: tolerance
    tol = 5e-2 if (cfg.is_moe or cfg.arch_type == "hybrid") else 2e-2
    for a, b in zip(jax.tree_util.tree_leaves(p1), jax.tree_util.tree_leaves(p2)):
        diff = jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))
        scale = jnp.max(jnp.abs(a.astype(jnp.float32))) + 1e-6
        assert float(diff / scale) < tol


def test_full_config_matches_assignment(arch):
    """The FULL config (never allocated) carries the exact assigned dims."""
    cfg = get_config(arch)
    expected = {
        "qwen3-32b": (64, 5120, 64, 8, 25600, 151936),
        "qwen1.5-0.5b": (24, 1024, 16, 16, 2816, 151936),
        "deepseek-v2-lite-16b": (27, 2048, 16, 16, 1408, 102400),
        "internvl2-1b": (24, 896, 14, 2, 4864, 151655),
        "qwen2-1.5b": (28, 1536, 12, 2, 8960, 151936),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
        "deepseek-v3-671b": (61, 7168, 128, 128, None, 129280),
        "mamba2-1.3b": (48, 2048, None, None, 0, 50280),
        "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
        "tinyllama-1.1b": (22, 2048, 32, 4, 5632, 32000),
    }[arch]
    L, d, H, KH, ff, V = expected
    assert cfg.n_layers == L and cfg.d_model == d and cfg.vocab_size == V
    if H is not None:
        assert cfg.n_heads == H
    if KH is not None:
        assert cfg.n_kv_heads == KH
    if ff not in (None,):
        assert cfg.d_ff == ff or cfg.d_ff_expert == ff


@pytest.mark.parametrize("arch_name,lo,hi", [
    ("tinyllama-1.1b", 0.9e9, 1.3e9),
    ("qwen1.5-0.5b", 0.4e9, 0.7e9),
    ("qwen2-1.5b", 1.2e9, 1.8e9),
    ("qwen3-32b", 29e9, 36e9),
    ("mamba2-1.3b", 1.0e9, 1.6e9),
    ("deepseek-v3-671b", 630e9, 700e9),
    ("deepseek-v2-lite-16b", 13e9, 18e9),
])
def test_param_count_magnitude(arch_name, lo, hi):
    """Full-config parameter counts land near the literature value
    (abstract eval_shape — no allocation)."""
    n = param_count(get_config(arch_name))
    assert lo <= n <= hi, (arch_name, n)
