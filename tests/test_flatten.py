"""FlatView / ShardedFlatView pack/unpack tests — deterministic
invariants plus hypothesis property sweeps.

The fused update path is only correct if flatten/unflatten is a perfect
bijection over arbitrary parameter pytrees — mixed dtypes, scalar
leaves, empty subtrees, any nesting.  For ShardedFlatView the bijection
must additionally commute with the mesh decomposition: leaves bucket
per (dtype × mesh-axis group), per-shard offsets are static, and
device_put with the bucket shardings round-trips exactly.  The
deterministic tests below always run; the hypothesis sweeps (random
tree shapes/dtypes/nesting/pspecs) skip cleanly when the optional dev
dep is absent (requirements-dev.txt), same policy as
tests/test_properties.py.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.utils.flatten import FlatView, ShardedFlatView

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                      # optional dev dep
    HAVE_HYPOTHESIS = False


def _assert_trees_equal(a, b):
    la, ta = jax.tree_util.tree_flatten(a)
    lb, tb = jax.tree_util.tree_flatten(b)
    assert ta == tb
    for x, y in zip(la, lb):
        assert x.dtype == y.dtype and x.shape == y.shape
        np.testing.assert_array_equal(np.asarray(x, np.float32),
                                      np.asarray(y, np.float32))


# ---------------------------------------------------------------------------
# deterministic invariants (always run)
# ---------------------------------------------------------------------------

MIXED_TREE = {
    "emb": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
    "blk": [{"w": jnp.ones((2, 2), jnp.bfloat16),
             "step": jnp.int32(7)},
            {"w": jnp.full((2, 2), 2.0, jnp.bfloat16),
             "step": jnp.int32(9)}],
    "scalar": jnp.float32(1.5),
    "empty": {},
}


def test_mixed_dtype_roundtrip():
    view = FlatView.of(MIXED_TREE)
    bufs = view.flatten(MIXED_TREE)
    assert set(bufs) == {"float32", "bfloat16", "int32"}
    assert view.buffer_sizes == {"float32": 13, "bfloat16": 8, "int32": 2}
    for name, buf in bufs.items():
        assert buf.ndim == 1 and jnp.dtype(buf.dtype).name == name
    _assert_trees_equal(view.unflatten(bufs), MIXED_TREE)


def test_slots_are_contiguous_per_buffer():
    view = FlatView.of(MIXED_TREE)
    cursor = {}
    total = 0
    for s in view.slots:
        assert s.offset == cursor.get(s.buffer, 0)
        assert s.size == int(np.prod(s.shape, dtype=np.int64))
        cursor[s.buffer] = s.offset + s.size
        total += s.size
    assert cursor == view.buffer_sizes
    assert total == view.total_size == 23


def test_stacked_roundtrip():
    base = {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": jnp.arange(3, dtype=jnp.float32)}
    stacked = jax.tree_util.tree_map(
        lambda x: jnp.stack([x, x + 1, x + 2]), base)
    view = FlatView.of(base)
    bufs = view.flatten_stacked(stacked)
    assert bufs["float32"].shape == (3, 9)
    _assert_trees_equal(view.unflatten_stacked(bufs), stacked)
    # row i of the stacked buffer is the flat packing of element i
    _assert_trees_equal(view.unflatten({"float32": bufs["float32"][1]}),
                        jax.tree_util.tree_map(lambda x: x[1], stacked))


def test_empty_tree():
    for empty in ({}, (), [], {"a": {}, "b": ()}):
        view = FlatView.of(empty)
        assert view.slots == () and view.flatten(empty) == {}
        back = view.unflatten({})
        assert jax.tree_util.tree_structure(back) == \
            jax.tree_util.tree_structure(empty)


def test_scalar_leaves_occupy_one_element():
    tree = {"s": jnp.float32(3.5), "v": jnp.arange(4, dtype=jnp.float32)}
    view = FlatView.of(tree)
    assert view.buffer_sizes == {"float32": 5}
    back = view.unflatten(view.flatten(tree))
    assert back["s"].shape == () and float(back["s"]) == 3.5


def test_structure_mismatch_raises():
    view = FlatView.of({"a": jnp.zeros(3)})
    with pytest.raises(ValueError, match="structure mismatch"):
        view.flatten({"b": jnp.zeros(3)})


def test_zeros_and_dtype_override():
    tree = {"a": jnp.zeros((2, 2), jnp.bfloat16), "b": jnp.zeros(3)}
    view = FlatView.of(tree)
    z = view.zeros()
    assert z["bfloat16"].dtype == jnp.bfloat16 and z["float32"].shape == (3,)
    z32 = view.zeros(jnp.float32)
    assert all(b.dtype == jnp.float32 for b in z32.values())


def test_of_works_on_shape_structs_and_tracers():
    specs = {"w": jax.ShapeDtypeStruct((4, 4), jnp.float32),
             "b": jax.ShapeDtypeStruct((4,), jnp.float32)}
    view = FlatView.of(specs)
    assert view.buffer_sizes == {"float32": 20}

    @jax.jit
    def roundtrip(tree):
        v = FlatView.of(tree)          # leaves are tracers here
        return v.unflatten(v.flatten(tree))

    tree = {"w": jnp.ones((4, 4)), "b": jnp.arange(4, dtype=jnp.float32)}
    _assert_trees_equal(roundtrip(tree), tree)


def test_view_is_hashable_and_stable():
    t1 = {"a": jnp.zeros(3), "b": jnp.ones((2, 2))}
    t2 = {"a": jnp.full(3, 7.0), "b": jnp.zeros((2, 2))}
    assert FlatView.of(t1) == FlatView.of(t2)
    assert hash(FlatView.of(t1)) == hash(FlatView.of(t2))


# ---------------------------------------------------------------------------
# ShardedFlatView — deterministic invariants
# ---------------------------------------------------------------------------

AXIS_SIZES = {"pod": 1, "data": 2, "model": 2}

SHARDED_TREE = {
    "embed": jnp.arange(48, dtype=jnp.float32).reshape(8, 6),
    "wo": {"w": jnp.arange(24, dtype=jnp.bfloat16).reshape(4, 6),
           "b": jnp.arange(6, dtype=jnp.float32)},
    "gate": jnp.arange(32, dtype=jnp.float32).reshape(4, 8),
    "scale": jnp.float32(2.0),
}
SHARDED_PSPECS = {
    "embed": P("model", "data"),
    "wo": {"w": P("model", "data"), "b": P(None)},
    "gate": P("data", "model"),
    "scale": P(),
}


def _sharded_view():
    return ShardedFlatView.of(SHARDED_TREE, SHARDED_PSPECS, AXIS_SIZES)


def test_sharded_roundtrip_and_buckets():
    view = _sharded_view()
    bufs = view.flatten(SHARDED_TREE)
    # leaves bucket per (dtype, mesh-axis group); size-1 axes drop out
    assert set(bufs) == {"float32@data+model", "bfloat16@data+model",
                         "float32"}
    assert view.buffer_shapes == {"float32@data+model": (4, 20),
                                  "bfloat16@data+model": (4, 6),
                                  "float32": (1, 7)}
    _assert_trees_equal(view.unflatten(bufs), SHARDED_TREE)


def test_sharded_offsets_are_static_and_contiguous():
    view = _sharded_view()
    cursor = {}
    for s in view.slots:
        assert s.offset == cursor.get(s.buffer, 0)
        n_shards = view.group_map[s.buffer].n_shards
        assert s.size * n_shards == int(np.prod(s.shape, dtype=np.int64))
        cursor[s.buffer] = s.offset + s.size
    assert cursor == {g.name: g.size for g in view.groups}


def test_sharded_rows_are_the_device_tiles():
    """Row k of a bucket must be exactly the tile device k would hold
    under the leaf's NamedSharding — shard-major in canonical (mesh)
    axis order, so sharding axis 0 over (data, model) is a no-comms
    relabel of the per-leaf layout."""
    view = _sharded_view()
    bufs = view.flatten(SHARDED_TREE)
    emb = np.arange(48, dtype=np.float32).reshape(8, 6)
    for di in range(2):
        for mi in range(2):
            tile = emb[mi * 4:(mi + 1) * 4, di * 3:(di + 1) * 3].reshape(-1)
            np.testing.assert_array_equal(
                np.asarray(bufs["float32@data+model"][di * 2 + mi, :12]),
                tile)


def test_sharded_roundtrip_under_named_sharding():
    """device_put with the bucket shardings (n_shards axis over the
    group's axes) then unflatten reproduces the tree exactly."""
    from jax.sharding import Mesh, NamedSharding
    from repro.sharding.rules import flat_buffer_pspec

    n = jax.device_count()
    mesh = Mesh(np.array(jax.devices()).reshape(1, 1, n),
                ("pod", "data", "model"))
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    view = ShardedFlatView.of(SHARDED_TREE, SHARDED_PSPECS, sizes)
    bufs = view.flatten(SHARDED_TREE)
    placed = {g.name: jax.device_put(
        bufs[g.name], NamedSharding(mesh, flat_buffer_pspec(g)))
        for g in view.groups}
    _assert_trees_equal(view.unflatten(placed), SHARDED_TREE)


def test_sharded_divisibility_rejected():
    with pytest.raises(ValueError, match="divisible"):
        ShardedFlatView.of({"w": jnp.zeros((3, 4))}, {"w": P("data", None)},
                           AXIS_SIZES)


def test_sharded_zeros_and_dtype_override():
    view = _sharded_view()
    z = view.zeros()
    assert z["bfloat16@data+model"].dtype == jnp.bfloat16
    z32 = view.zeros(jnp.float32)
    assert all(b.dtype == jnp.float32 for b in z32.values())
    assert {k: b.shape for k, b in z32.items()} == view.buffer_shapes


def test_sharded_view_hashable_and_jit_compatible():
    assert hash(_sharded_view()) == hash(_sharded_view())

    @jax.jit
    def roundtrip(tree):
        v = ShardedFlatView.of(tree, SHARDED_PSPECS, AXIS_SIZES)
        return v.unflatten(v.flatten(tree))

    _assert_trees_equal(roundtrip(SHARDED_TREE), SHARDED_TREE)


def test_sharded_single_device_collapses_to_one_bucket_per_dtype():
    """All axes size 1 → no sharding survives, one (1, total) bucket
    per dtype — the host-mesh degeneration the parity tests rely on."""
    view = ShardedFlatView.of(SHARDED_TREE, SHARDED_PSPECS,
                              {"pod": 1, "data": 1, "model": 1})
    assert set(view.buffer_shapes) == {"float32", "bfloat16"}
    assert all(shape[0] == 1 for shape in view.buffer_shapes.values())
    _assert_trees_equal(view.unflatten(view.flatten(SHARDED_TREE)),
                        SHARDED_TREE)


# ---------------------------------------------------------------------------
# hypothesis property sweeps (optional dev dep)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    DTYPES = ["float32", "bfloat16", "int32"]

    @st.composite
    def leaf_arrays(draw, max_dims=3, max_side=5):
        shape = tuple(draw(st.lists(st.integers(1, max_side), min_size=0,
                                    max_size=max_dims)))
        dtype = draw(st.sampled_from(DTYPES))
        seed = draw(st.integers(0, 2 ** 30))
        rng = np.random.default_rng(seed)
        if dtype == "int32":
            return jnp.asarray(rng.integers(-100, 100, size=shape), jnp.int32)
        return jnp.asarray(rng.normal(size=shape), dtype)

    @st.composite
    def pytrees(draw, depth=2):
        """Nested dict/list/tuple trees of arrays, incl. empty subtrees
        and scalar (0-d) leaves."""
        if depth == 0:
            return draw(leaf_arrays())
        branch = draw(st.sampled_from(["leaf", "dict", "list", "tuple",
                                       "empty"]))
        if branch == "leaf":
            return draw(leaf_arrays())
        if branch == "empty":
            return draw(st.sampled_from([{}, (), []]))
        children = draw(st.lists(pytrees(depth=depth - 1), min_size=1,
                                 max_size=3))
        if branch == "dict":
            return {f"k{i}": c for i, c in enumerate(children)}
        return children if branch == "list" else tuple(children)

    @given(tree=pytrees())
    @settings(max_examples=40, deadline=None)
    def test_flatten_roundtrip_sweep(tree):
        view = FlatView.of(tree)
        bufs = view.flatten(tree)
        assert set(bufs) == set(view.buffer_sizes)
        for name, buf in bufs.items():
            assert buf.ndim == 1 and buf.shape[0] == view.buffer_sizes[name]
            assert jnp.dtype(buf.dtype).name == name
        _assert_trees_equal(view.unflatten(bufs), tree)

    @given(tree=pytrees(), k=st.integers(1, 4))
    @settings(max_examples=25, deadline=None)
    def test_flatten_stacked_roundtrip_sweep(tree, k):
        stacked = jax.tree_util.tree_map(
            lambda x: jnp.stack([x] * k), tree)
        view = FlatView.of(tree)
        bufs = view.flatten_stacked(stacked)
        for buf in bufs.values():
            assert buf.ndim == 2 and buf.shape[0] == k
        _assert_trees_equal(view.unflatten_stacked(bufs), stacked)

    @given(tree=pytrees())
    @settings(max_examples=25, deadline=None)
    def test_slot_invariants_sweep(tree):
        view = FlatView.of(tree)
        cursor = {}
        for s in view.slots:
            assert s.offset == cursor.get(s.buffer, 0)
            assert s.size == int(np.prod(s.shape, dtype=np.int64))
            cursor[s.buffer] = s.offset + s.size
        assert cursor == view.buffer_sizes

    # -- ShardedFlatView sweeps --------------------------------------------

    SWEEP_AXES = {"data": 2, "model": 3}

    def _random_pspecs(tree, seed):
        """A valid pspec tree for ``tree``: per dim, maybe shard over an
        unused axis that divides it (mirrors the rules' degradation)."""
        rng = np.random.default_rng(seed)
        entries = [None, "data", "model", ("data", "model")]

        def leaf_spec(leaf):
            used, spec = set(), []
            for dim in leaf.shape:
                e = entries[rng.integers(0, len(entries))]
                axes = (e,) if isinstance(e, str) else (e or ())
                n = int(np.prod([SWEEP_AXES[a] for a in axes] or [1]))
                if e is None or used & set(axes) or dim % n or dim < n:
                    spec.append(None)
                else:
                    used |= set(axes)
                    spec.append(e)
            return P(*spec)

        return jax.tree_util.tree_map(leaf_spec, tree)

    @given(tree=pytrees(), seed=st.integers(0, 2 ** 16))
    @settings(max_examples=40, deadline=None)
    def test_sharded_roundtrip_sweep(tree, seed):
        pspecs = _random_pspecs(tree, seed)
        view = ShardedFlatView.of(tree, pspecs, SWEEP_AXES)
        bufs = view.flatten(tree)
        for g in view.groups:
            buf = bufs[g.name]
            assert buf.shape == (g.n_shards, g.size)
            assert jnp.dtype(buf.dtype).name == g.dtype
            assert g.n_shards == int(np.prod(
                [SWEEP_AXES[a] for a in g.axes] or [1]))
        _assert_trees_equal(view.unflatten(bufs), tree)

    @given(tree=pytrees(), seed=st.integers(0, 2 ** 16))
    @settings(max_examples=25, deadline=None)
    def test_sharded_slot_invariants_sweep(tree, seed):
        """Per-shard offsets are static and contiguous per bucket, and
        every leaf's per-shard size × n_shards recovers its element
        count (no padding, no overlap)."""
        view = ShardedFlatView.of(tree, _random_pspecs(tree, seed),
                                  SWEEP_AXES)
        cursor = {}
        for s in view.slots:
            assert s.offset == cursor.get(s.buffer, 0)
            n_shards = view.group_map[s.buffer].n_shards
            assert s.size * n_shards == int(np.prod(s.shape,
                                                    dtype=np.int64))
            cursor[s.buffer] = s.offset + s.size
        assert cursor == {g.name: g.size for g in view.groups}
        assert view.total_size == sum(
            int(np.prod(s.shape, dtype=np.int64)) for s in view.slots)
