"""Sharding-rule tests.

Host-mesh (1×1) checks run in-process; multi-device layout checks run in
a subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=16 so
the main test process keeps its single-device view (the dry-run rule:
never set the flag globally).
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_reduced
from repro.launch.mesh import make_host_mesh
from repro.models.transformer import init_lm
from repro.sharding import rules


def test_host_mesh_pspecs_are_valid():
    cfg = get_reduced("qwen2-1.5b")
    params = jax.eval_shape(lambda k: init_lm(k, cfg), jax.random.PRNGKey(0))
    mesh = make_host_mesh()
    specs = rules.param_pspecs(params, mesh)
    # on a 1×1 mesh every axis must have been dropped (nothing divides >1)
    for s in jax.tree_util.tree_leaves(specs, is_leaf=lambda x: isinstance(
            x, jax.sharding.PartitionSpec)):
        assert all(a is None for a in s), s


def test_batch_pspec_layouts_host():
    mesh = make_host_mesh()
    batch = {"tokens": jax.ShapeDtypeStruct((8, 16), jnp.int32)}
    for layout in rules.LAYOUTS:
        specs = rules.batch_pspecs(batch, mesh, layout)
        assert isinstance(specs["tokens"], jax.sharding.PartitionSpec)


_SUBPROCESS_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.configs import get_reduced
    from repro.models.transformer import init_lm
    from repro.sharding import rules

    mesh = jax.make_mesh((4, 4), ("data", "model"))
    cfg = get_reduced("tinyllama-1.1b")
    params = jax.eval_shape(lambda k: init_lm(k, cfg), jax.random.PRNGKey(0))

    # fsdp_tp: at least one leaf sharded on 'model' and one on 'data'
    specs = rules.param_pspecs(params, mesh, "fsdp_tp")
    leaves = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P))
    axes = {a for s in leaves for a in s if a is not None}
    flat_axes = set()
    for a in axes:
        if isinstance(a, tuple): flat_axes.update(a)
        else: flat_axes.add(a)
    assert "model" in flat_axes and "data" in flat_axes, flat_axes

    # fsdp_only: NO pure 'model' entries — only combined-axis sharding
    specs2 = rules.param_pspecs(params, mesh, "fsdp_only")
    leaves2 = jax.tree_util.tree_leaves(
        specs2, is_leaf=lambda x: isinstance(x, P))
    for s in leaves2:
        for a in s:
            assert a is None or isinstance(a, tuple), (s,)

    # batch: fsdp_only shards batch over BOTH axes
    batch = {"tokens": jax.ShapeDtypeStruct((32, 16), jnp.int32)}
    bs = rules.batch_pspecs(batch, mesh, "fsdp_only")["tokens"]
    assert bs[0] == ("data", "model"), bs

    # end-to-end: a loss lowers under both layouts on the 4x4 mesh
    from repro.models.transformer import lm_loss
    toks = jax.ShapeDtypeStruct((32, 16), jnp.int32)
    for layout in rules.LAYOUTS:
        p_sh = rules.param_shardings(params, mesh, layout)
        b_sh = rules.batch_shardings({"tokens": toks, "labels": toks},
                                     mesh, layout)
        with mesh:
            f = jax.jit(lambda p, b: lm_loss(p, cfg, b)[0],
                        in_shardings=(p_sh, b_sh))
            f.lower(params, {"tokens": toks, "labels": toks}).compile()
    print("SUBPROCESS_OK")
""")


@pytest.mark.slow
def test_multi_device_layouts_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", _SUBPROCESS_SCRIPT],
                         capture_output=True, text=True, env=env,
                         timeout=420)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "SUBPROCESS_OK" in out.stdout
