"""Pod-backend engine tests: one RoundStrategy stack from laptop CPU to
sharded mesh.

Covers the PR-2 contract:
  - fl_batch_pspec/fl_batch_shardings layout logic (rank<3 leaves,
    pod+data vs data-only meshes) without needing real multi-device
    meshes (the pspec helpers only read axis names/sizes);
  - host↔pod engine parity: same seed + sampling="host" produce
    identical loss histories on a 1-device mesh (relay bitwise, fedavg
    up to fp reduction order — scan-delta vs vmap-weighted-mean);
  - chunk-size invariance on the pod backend (chunk>1 = one XLA
    dispatch per chunk on the mesh);
  - scaffold/moon on the pod backend through the ShardedClientStateStore;
  - the _local_sgd ↔ fl.local clip-then-decay order parity;
  - run_pod_training driving both phases through run_phase_schedule,
    with the in-program eval stream (default accuracy + custom metric);
  - (slow) a 16-fake-device subprocess run asserting the client-state
    stack AND the server-optimizer moments actually shard over the
    mesh, with in-program eval keeping one dispatch per chunk.
"""
import dataclasses
import os
import subprocess
import sys
import textwrap
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_reduced
from repro.data.synthetic import make_synthetic_tokenlm
from repro.fl.engine import AggregateStrategy, RelayStrategy, RoundSchedule, run_rounds
from repro.fl.local import LocalSpec, make_local_fn
from repro.fl.pod import (
    HOST_RNG_OFFSET_P1,
    HOST_RNG_OFFSET_P2,
    PodAggregateStrategy,
    PodCyclicConfig,
    PodFLConfig,
    PodFLSpec,
    PodRelayStrategy,
    ShardedClientStateStore,
)
from repro.fl.task import lm_task
from repro.launch.mesh import make_host_mesh
from repro.sharding import rules

SEED = 0


def _mesh_stub(shape, axes):
    """Duck-typed mesh for the pure pspec helpers (axis names + sizes
    only) — lets the layout logic be tested at >1 axis sizes without
    real devices."""
    return SimpleNamespace(axis_names=axes, devices=np.empty(shape))


@pytest.fixture(scope="module")
def setup():
    cfg = get_reduced("qwen1.5-0.5b")
    data = make_synthetic_tokenlm(n_clients=8, seq_len=16,
                                  n_seq_per_client=8,
                                  vocab=cfg.vocab_size, beta=0.5, seed=SEED)
    return cfg, lm_task(cfg), data


def _leaves32(tree):
    return [np.asarray(x, np.float32) for x in jax.tree_util.tree_leaves(tree)]


# ---------------------------------------------------------------------------
# fl_batch_pspec / fl_batch_shardings layout logic
# ---------------------------------------------------------------------------

def test_fl_batch_pspec_data_only_mesh():
    mesh = _mesh_stub((4, 4), ("data", "model"))
    assert rules.fl_batch_pspec(mesh, 4) == P(None, None, "data", None)
    assert rules.fl_batch_pspec(mesh, 3, batch_axis=1) == P(None, "data", None)


def test_fl_batch_pspec_pod_data_mesh():
    mesh = _mesh_stub((2, 4, 4), ("pod", "data", "model"))
    assert rules.fl_batch_pspec(mesh, 4) == P(None, None, ("pod", "data"), None)
    assert rules.fl_batch_pspec(mesh, 3, batch_axis=1) == \
        P(None, ("pod", "data"), None)


def test_fl_batch_pspec_small_rank_leaves():
    """rank <= batch_axis leaves have no batch dim to shard."""
    mesh = _mesh_stub((4, 4), ("data", "model"))
    assert rules.fl_batch_pspec(mesh, 2) == P(None, None)
    assert rules.fl_batch_pspec(mesh, 1) == P(None)
    assert rules.fl_batch_pspec(mesh, 1, batch_axis=1) == P(None)


def test_fl_batch_shardings_on_host_mesh():
    mesh = make_host_mesh()
    tree = {"tokens": jax.ShapeDtypeStruct((4, 2, 8, 16), jnp.int32),
            "weights": jax.ShapeDtypeStruct((4,), jnp.float32)}
    sh = rules.fl_batch_shardings(tree, mesh)
    assert sh["tokens"].spec == P(None, None, "data", None)
    assert sh["weights"].spec == P(None)


def test_client_axis_pspec_divisibility():
    mesh = _mesh_stub((4, 4), ("data", "model"))
    assert rules.client_axis_pspec(mesh, 3, 8) == P("data", None, None)
    assert rules.client_axis_pspec(mesh, 3, 6) == P(None, None, None)  # 6 % 4
    one = _mesh_stub((1, 1), ("data", "model"))
    assert rules.client_axis_pspec(one, 2, 8) == P(None, None)


# ---------------------------------------------------------------------------
# host ↔ pod engine parity (1-device mesh)
# ---------------------------------------------------------------------------

def _schedule(rounds, chunk, sampling, offset):
    return RoundSchedule(rounds=rounds, lr_decay=1.0, eval_every=0,
                         seed=SEED, chunk_size=chunk, sampling=sampling,
                         host_rng_offset=offset)


def test_host_pod_relay_parity(setup):
    """Same seed + sampling="host": pod relay == host relay, bit-for-bit
    (identical round bodies, the pod adds only layout pins)."""
    cfg, task, data = setup
    spec = LocalSpec(n_steps=2, batch_size=4, lr=0.05)
    host = run_rounds(task, data, RelayStrategy(spec=spec, participation=0.25),
                      _schedule(3, 2, "host", HOST_RNG_OFFSET_P1))
    pod = run_rounds(task, data,
                     PodRelayStrategy(spec=spec, mesh=make_host_mesh(),
                                      clients_per_round=2),
                     _schedule(3, 2, "host", HOST_RNG_OFFSET_P1))
    np.testing.assert_allclose([h["local_loss"] for h in host.history],
                               [h["local_loss"] for h in pod.history],
                               atol=1e-6, rtol=1e-6)
    for a, b in zip(_leaves32(host.params), _leaves32(pod.params)):
        np.testing.assert_allclose(a, b, atol=1e-6, rtol=1e-6)


@pytest.mark.parametrize("algorithm", ["fedavg", "scaffold"])
def test_host_pod_aggregate_parity(setup, algorithm):
    """Pod P2 (sequential scan + delta accumulation) matches the host
    vmap backend round-for-round: same keys, same batches, the FedAvg
    identity w_avg = w + Σ wᵢ/W·(wᵢ − w)."""
    cfg, task, data = setup
    spec = LocalSpec(n_steps=2, batch_size=4, lr=0.05, variant={
        "fedavg": "plain", "scaffold": "scaffold"}[algorithm])
    host = run_rounds(task, data,
                      AggregateStrategy(spec=spec, algorithm=algorithm,
                                        participation=0.25),
                      _schedule(3, 2, "host", HOST_RNG_OFFSET_P2))
    pod = run_rounds(task, data,
                     PodAggregateStrategy(spec=spec, algorithm=algorithm,
                                          mesh=make_host_mesh(),
                                          clients_per_round=2),
                     _schedule(3, 2, "host", HOST_RNG_OFFSET_P2))
    np.testing.assert_allclose([h["local_loss"] for h in host.history],
                               [h["local_loss"] for h in pod.history],
                               atol=1e-5, rtol=1e-5)
    for a, b in zip(_leaves32(host.params), _leaves32(pod.params)):
        np.testing.assert_allclose(a, b, atol=1e-4, rtol=1e-4)


def test_pod_chunked_matches_per_round(setup):
    """chunk=4 (one mesh dispatch) == chunk=1 on the pod backend."""
    cfg, task, data = setup
    mesh = make_host_mesh()
    spec = LocalSpec(n_steps=2, batch_size=4, lr=0.05)

    def run(chunk):
        return run_rounds(task, data,
                          PodRelayStrategy(spec=spec, mesh=mesh,
                                           clients_per_round=2),
                          _schedule(4, chunk, "device", 0))

    r1, r4 = run(1), run(4)
    np.testing.assert_allclose([h["local_loss"] for h in r1.history],
                               [h["local_loss"] for h in r4.history],
                               atol=1e-6, rtol=1e-6)
    for a, b in zip(_leaves32(r1.params), _leaves32(r4.params)):
        np.testing.assert_allclose(a, b, atol=1e-6, rtol=1e-6)


# ---------------------------------------------------------------------------
# sharded client state (scaffold / moon on the pod backend)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algorithm", ["scaffold", "moon"])
def test_stateful_algorithms_run_on_pod_backend(setup, algorithm):
    cfg, task, data = setup
    spec = LocalSpec(n_steps=2, batch_size=4, lr=0.05, variant=algorithm,
                     mu=0.1)
    strat = PodAggregateStrategy(spec=spec, algorithm=algorithm,
                                 mesh=make_host_mesh(), clients_per_round=3)
    assert isinstance(strat.state_store, ShardedClientStateStore)
    res = run_rounds(task, data, strat, _schedule(2, 2, "device", 0))
    assert len(res.history) == 2
    assert all(np.isfinite(h["local_loss"]) for h in res.history)
    state_key = "c_clients" if algorithm == "scaffold" else "w_prev"
    lead = jax.tree_util.tree_leaves(res.algo_state[state_key])[0]
    assert lead.shape[0] == data.n_clients


def test_sharded_store_gather_scatter_roundtrip():
    store = ShardedClientStateStore(make_host_mesh())
    template = {"w": jnp.arange(6.0).reshape(2, 3)}
    state = store.init(template, 4)
    assert jax.tree_util.tree_leaves(state)[0].shape == (4, 2, 3)
    ids = jnp.asarray([1, 3])
    rows = store.gather(state, ids)
    rows = jax.tree_util.tree_map(lambda r: r + 1.0, rows)
    out = store.scatter(state, ids, rows)
    np.testing.assert_allclose(np.asarray(out["w"][1]),
                               np.asarray(template["w"]) + 1.0)
    np.testing.assert_allclose(np.asarray(out["w"][0]),
                               np.asarray(template["w"]))


# ---------------------------------------------------------------------------
# clip-then-decay parity (satellite: _local_sgd vs fl.local order)
# ---------------------------------------------------------------------------

def test_local_sgd_clip_decay_order_matches_fl_local(setup):
    """Feed _local_sgd the exact batches make_local_fn samples; with
    grad_clip AND weight_decay active the end params must match — only
    true if both apply clip(raw grad) THEN decay."""
    from repro.launch.train import _local_sgd

    cfg, task, data = setup
    pod_spec = PodFLSpec(local_steps=3, batch_size=4, lr=0.1,
                         weight_decay=0.1, grad_clip=0.05)
    local_spec = pod_spec.local_spec("plain")
    params = task.init(jax.random.PRNGKey(SEED))
    x_all, y_all, _ = data.device_arrays()
    cx, cy = x_all[0], y_all[0]
    key = jax.random.PRNGKey(5)

    w_host, _ = make_local_fn(task, local_spec)(
        key, params, {}, cx, cy, jnp.float32(1.0))

    # replicate fl.local's per-step sampling stream
    keys = jax.random.split(key, pod_spec.local_steps)
    bidx = jnp.stack([
        jax.random.randint(k, (pod_spec.batch_size,), 0, cx.shape[0])
        for k in keys])
    batches = {"tokens": cx[bidx], "labels": cy[bidx]}
    w_pod, _ = _local_sgd(cfg, pod_spec)(params, batches, jnp.float32(1.0),
                                         None)

    for a, b in zip(_leaves32(w_host), _leaves32(w_pod)):
        np.testing.assert_allclose(a, b, atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# run_pod_training through the declarative schedule
# ---------------------------------------------------------------------------

def test_run_pod_training_eval_rows_and_phases(setup):
    """A custom traceable metric streams through the in-program eval:
    every round carries an ``eval`` row even with chunked dispatch
    (eval_fn no longer forces eval_every=1 → per-round dispatch)."""
    from repro.launch.train import run_pod_training

    cfg, task, data = setup

    def eval_fn(params, bx, by):            # per-sample contract: (B,)
        return jnp.full((bx.shape[0],), 7.0, jnp.float32)

    res = run_pod_training(cfg, data, cyclic_rounds=1, fl_rounds=2,
                           clients_per_round=2,
                           spec=PodFLSpec(local_steps=2, batch_size=4,
                                          lr=0.05),
                           seed=SEED, eval_fn=eval_fn, chunk_size=2)
    assert [h["phase"] for h in res.history] == ["P1", "P2", "P2"]
    assert [h["round"] for h in res.history] == [0, 1, 2]
    assert all("eval" in h for h in res.history)
    assert all(abs(h["eval"] - 7.0) < 1e-6 for h in res.history)


def test_run_pod_training_default_eval_cadence(setup):
    """eval_every without a custom metric scores test accuracy on the
    cadence (plus the final round), computed inside the chunk."""
    from repro.launch.train import run_pod_training

    cfg, task, data = setup
    res = run_pod_training(cfg, data, cyclic_rounds=0, fl_rounds=3,
                           clients_per_round=2,
                           spec=PodFLSpec(local_steps=2, batch_size=4,
                                          lr=0.05),
                           seed=SEED, eval_every=2, chunk_size=3)
    assert [("eval" in h) for h in res.history] == [False, True, True]
    assert all(0.0 <= h["eval"] <= 1.0 for h in res.history if "eval" in h)


def test_run_pod_training_zero_rounds_returns_init(setup):
    from repro.launch.train import run_pod_training
    from repro.models.transformer import init_lm

    cfg, task, data = setup
    res = run_pod_training(cfg, data, cyclic_rounds=0, fl_rounds=0,
                           seed=SEED)
    assert res.history == []
    want = init_lm(jax.random.PRNGKey(SEED), cfg)
    for a, b in zip(_leaves32(res.params), _leaves32(want)):
        np.testing.assert_array_equal(a, b)


def test_pod_phase_params_survive_next_phase_donation(setup):
    """device_put is a no-op on an already-matching placement, so phase
    2's place_params must COPY phase 1's result before the donated
    carries delete it — earlier phases' params stay readable."""
    from repro.core.pipeline import Phase, run_phase_schedule

    cfg, task, data = setup
    mesh = make_host_mesh()
    spec = PodFLSpec(local_steps=2, batch_size=4, lr=0.05)
    kw = dict(mesh=mesh, rounds=1, clients_per_round=2, spec=spec,
              seed=SEED, chunk_size=1)
    sched = run_phase_schedule(task, data, [
        Phase("P1", PodCyclicConfig(**kw)),
        Phase("P2", PodFLConfig(**kw)),
    ])
    for leaf in jax.tree_util.tree_leaves(sched.phases[0].result.params):
        assert np.isfinite(np.asarray(leaf, np.float32)).all()


def test_pod_phase_schedule_alternation(setup):
    """Multi-cycle P1↔P2 on the POD backend — what run_phase_schedule
    unlocks for the sharded path."""
    from repro.core.pipeline import Phase, run_phase_schedule

    cfg, task, data = setup
    mesh = make_host_mesh()
    spec = PodFLSpec(local_steps=2, batch_size=4, lr=0.05)
    kw = dict(mesh=mesh, rounds=1, clients_per_round=2, spec=spec,
              seed=SEED, chunk_size=2)
    sched = run_phase_schedule(task, data, [
        Phase("P1", PodCyclicConfig(**kw)),
        Phase("P2", PodFLConfig(**kw)),
        Phase("P1'", PodCyclicConfig(**kw)),
        Phase("P2'", PodFLConfig(**kw)),
    ])
    hist = sched.history
    assert [h["phase"] for h in hist] == ["P1", "P2", "P1'", "P2'"]
    assert [h["round"] for h in hist] == [0, 1, 2, 3]
    led = sched.ledger.summary()
    assert led["p1_rounds"] == 2 and led["p2_rounds"] == 2


# ---------------------------------------------------------------------------
# multi-device: client state really shards over the data axis
# ---------------------------------------------------------------------------

_SUBPROCESS_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    import jax
    import numpy as np
    from repro.configs import get_reduced
    from repro.data.synthetic import make_synthetic_tokenlm
    from repro.fl.engine import RoundSchedule, run_rounds
    from repro.fl.local import LocalSpec
    from repro.fl.pod import PodAggregateStrategy
    from repro.fl.task import lm_task

    mesh = jax.make_mesh((4, 4), ("data", "model"))
    cfg = get_reduced("qwen1.5-0.5b")
    task = lm_task(cfg)
    data = make_synthetic_tokenlm(n_clients=8, seq_len=16,
                                  n_seq_per_client=8,
                                  vocab=cfg.vocab_size, beta=0.5, seed=0)
    strat = PodAggregateStrategy(
        spec=LocalSpec(n_steps=2, batch_size=8, lr=0.05, variant="scaffold"),
        algorithm="scaffold", mesh=mesh, clients_per_round=2,
        server_opt="momentum", server_lr=0.5)
    res = run_rounds(task, data, strat,
                     RoundSchedule(rounds=2, eval_every=2, eval_batch=8,
                                   seed=0, chunk_size=2))
    assert np.isfinite(res.history[-1]["local_loss"])
    assert 0.0 <= res.history[-1]["acc"] <= 1.0   # in-program eval on mesh
    assert res.dispatches == 1                    # eval did not split chunks
    leaf = jax.tree_util.tree_leaves(res.algo_state["c_clients"])[0]
    spec = leaf.sharding.spec
    assert spec and spec[0] == "data", ("c_clients not data-sharded", spec)
    # server-optimizer moments shard like the params they mirror
    mom = jax.tree_util.tree_leaves(res.server_state.inner)
    assert mom and any(
        any(ax is not None for ax in m.sharding.spec) for m in mom
        if m.ndim >= 2), "server momentum not sharded"
    print("POD_SUBPROCESS_OK")
""")


@pytest.mark.slow
def test_pod_scaffold_shards_client_state_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", _SUBPROCESS_SCRIPT],
                         capture_output=True, text=True, env=env,
                         timeout=420)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "POD_SUBPROCESS_OK" in out.stdout


# ---------------------------------------------------------------------------
# multi-device: hierarchical two-level combine on a real 4×4 mesh
# ---------------------------------------------------------------------------

_HIER_SUBPROCESS_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    import jax
    import numpy as np
    from repro.configs import get_reduced
    from repro.data.synthetic import make_synthetic_tokenlm
    from repro.fl.engine import RoundSchedule, run_rounds
    from repro.fl.local import LocalSpec
    from repro.fl.pod import PodAggregateStrategy, ShardedSparseClientStateStore
    from repro.fl.task import lm_task

    mesh = jax.make_mesh((4, 4), ("data", "model"))
    cfg = get_reduced("qwen1.5-0.5b")
    task = lm_task(cfg)
    data = make_synthetic_tokenlm(n_clients=8, seq_len=16,
                                  n_seq_per_client=8,
                                  vocab=cfg.vocab_size, beta=0.5, seed=0)
    sched = RoundSchedule(rounds=2, eval_every=0, seed=0, chunk_size=2,
                          sampling="host", host_rng_offset=17)

    def run(aggregation, store=None):
        kw = {"state_store": store} if store is not None else {}
        strat = PodAggregateStrategy(
            spec=LocalSpec(n_steps=2, batch_size=8, lr=0.05,
                           variant="scaffold"),
            algorithm="scaffold", mesh=mesh, clients_per_round=4,
            aggregation=aggregation, **kw)
        return run_rounds(task, data, strat, sched)

    seq = run("sequential")
    hier = run("hierarchical",                       # G=4 from the data axis
               ShardedSparseClientStateStore(capacity=8, mesh=mesh))
    # two-level combine only reassociates the weighted sum
    for a, b in zip(jax.tree_util.tree_leaves(seq.params),
                    jax.tree_util.tree_leaves(hier.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-5, rtol=0)
    np.testing.assert_allclose(
        [h["local_loss"] for h in seq.history],
        [h["local_loss"] for h in hier.history], atol=5e-5, rtol=0)
    # sparse table is data-sharded at its bounded capacity, not n_clients
    table = jax.tree_util.tree_leaves(hier.algo_state["c_clients"]["table"])[0]
    assert table.shape[0] == 8, table.shape
    spec = table.sharding.spec
    assert spec and spec[0] == "data", ("sparse table not data-sharded", spec)
    print("POD_HIER_SUBPROCESS_OK")
""")


@pytest.mark.slow
def test_pod_hierarchical_combine_16dev_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", _HIER_SUBPROCESS_SCRIPT],
                         capture_output=True, text=True, env=env,
                         timeout=420)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "POD_HIER_SUBPROCESS_OK" in out.stdout


# ---------------------------------------------------------------------------
# multi-device: the sharded-lane hierarchical combine IS one psum over
# `data` — asserted on the lowered HLO, plus fused end-to-end parity
# ---------------------------------------------------------------------------

_PSUM_SUBPROCESS_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    import re
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.data.federated import FederatedDataset
    from repro.fl.engine import RoundSchedule, run_rounds
    from repro.fl.local import LocalSpec
    from repro.fl.pod import (PodAggregateStrategy,
                              ShardedSparseClientStateStore,
                              _sharded_flat_ops)
    from repro.fl.task import vision_task
    from repro.sharding import rules

    mesh = jax.make_mesh((4, 4), ("data", "model"))
    task = vision_task("mlp", in_ch=1, seed_kwargs={"img": 8, "d_hidden": 16})
    fops = _sharded_flat_ops(task, mesh, "fsdp_tp", True)
    G = fops.lane_count()
    assert G == 4, G

    # -- HLO: the cross-pod combine lowers to EXACTLY ONE all-reduce
    # whose replica groups are the mesh `data` columns — no host gather,
    # no all-gather/all-to-all
    rng = np.random.default_rng(0)
    acc = fops.lane_zeros(G)
    acc = fops.lane_accum(
        acc,
        {k: jnp.asarray(rng.normal(size=(G,) + v.shape[1:], scale=0.1)
                        .astype(np.float32)) for k, v in acc.items()},
        jnp.asarray(rng.random(G).astype(np.float32)))
    hlo = jax.jit(fops.lane_combine).lower(acc).compile().as_text()
    n_ar = len(re.findall(r"all-reduce(?:-start)?\\(", hlo))
    assert n_ar == 1, f"expected exactly one psum, found {n_ar}"
    assert "all-gather" not in hlo and "all-to-all" not in hlo, hlo[-2000:]
    # the data axis strides the (4, 4) device grid by 4: columns
    want = "{{0,4,8,12},{1,5,9,13},{2,6,10,14},{3,7,11,15}}"
    m = re.search(r"replica_groups=(\\{\\{[0-9,{}]*\\}\\})", hlo)
    assert m and m.group(1) == want, (m and m.group(1), want)

    # -- numerics: combine(accum(...)) == the plain weighted sum
    comb = fops.lane_combine(acc)
    for k, v in comb.items():
        assert v.shape == fops.lane_zeros(G)[k].shape[1:]

    # -- end-to-end: fused hierarchical (sharded lanes + psum) matches
    # the sequential scan, sparse store refills landing per shard
    N, per = 8, 16
    x = rng.normal(size=(N, per, 8, 8, 1)).astype(np.float32)
    y = rng.integers(0, 10, size=(N, per)).astype(np.int32)
    data = FederatedDataset(x=x, y=y, n_real=np.full((N,), per, np.int32),
                            test_x=x[0], test_y=y[0], n_classes=10,
                            name="psum-test")
    spec = LocalSpec(n_steps=2, batch_size=4, lr=0.05, variant="scaffold",
                     update_impl="fused_interpret")

    def run(aggregation, overlap):
        strat = PodAggregateStrategy(
            spec=spec, algorithm="scaffold", mesh=mesh, clients_per_round=4,
            aggregation=aggregation, n_pods=4,
            state_store=ShardedSparseClientStateStore(capacity=8, mesh=mesh))
        return run_rounds(task, data, strat,
                          RoundSchedule(rounds=4, lr_decay=1.0, eval_every=0,
                                        seed=0, chunk_size=2, sampling="host",
                                        host_rng_offset=17, overlap=overlap))

    seq = run("sequential", False)
    hier = run("hierarchical", False)
    hier_ovl = run("hierarchical", True)
    np.testing.assert_allclose(
        [h["local_loss"] for h in seq.history],
        [h["local_loss"] for h in hier.history], atol=5e-5, rtol=0)
    for a, b in zip(jax.tree_util.tree_leaves(seq.params),
                    jax.tree_util.tree_leaves(hier.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-5, rtol=0)
    # overlapped pipeline == synchronous, BITWISE, on the pod
    np.testing.assert_array_equal(
        [h["local_loss"] for h in hier.history],
        [h["local_loss"] for h in hier_ovl.history])
    for a, b in zip(jax.tree_util.tree_leaves(hier.params),
                    jax.tree_util.tree_leaves(hier_ovl.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert hier_ovl.dispatches == hier.dispatches == 2
    print("POD_PSUM_SUBPROCESS_OK")
""")


@pytest.mark.slow
def test_pod_hierarchical_psum_lowering_16dev_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", _PSUM_SUBPROCESS_SCRIPT],
                         capture_output=True, text=True, env=env,
                         timeout=420)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "POD_PSUM_SUBPROCESS_OK" in out.stdout
