"""Trainable-slice / PEFT execution (repro.fl.local + utils.flatten +
sharding.rules + models.layers LoRA).

The contract under test, layer by layer:

  - LoRA layer: B zero-init makes the adapted forward equal the base
    forward BITWISE at init (and the base ``w`` draw is unchanged by
    adding adapters); ``merge_lora`` folds ``W + (α/r)·B A`` so the
    merged plain model matches the adapter model's forward;
  - filter partition: an all-matching filter == filter=None bitwise
    through a full engine run (the filtered program with zero frozen
    leaves IS the current program — the rest of the suite is the
    filter=None oracle);
  - frozen residency: across multi-round host AND pod runs every
    frozen leaf comes back bitwise-identical to its init value while
    every trainable leaf moves; host == pod round-for-round;
  - wire accounting: the P2 upload payload is the dtype-aware byte
    count of the trainable slice, EXACTLY (ledger == closed form), and
    a lossy spec compresses the slice (ratios compose);
  - invalid configs fail loudly AT CONSTRUCTION with actionable
    messages (unknown peft spec, rank ≤ 0, tree impl, zero-leaf
    filter, peft on the P1 relay);
  - (slow) a 16-fake-device subprocess run: the trainable buckets keep
    their sharded (dtype × axes) decomposition, the frozen buckets get
    their own sharded groups, and frozen invariance holds on a real
    4×4 mesh.
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced, with_peft
from repro.core import comm_accounting as acc
from repro.core.comm_accounting import CommLedger
from repro.data.synthetic import make_synthetic_tokenlm
from repro.fl.compression import CompressionSpec
from repro.fl import compression as comp
from repro.fl.engine import AggregateStrategy, RelayStrategy, RoundSchedule, run_rounds
from repro.fl.local import (
    LocalSpec,
    effective_trainable_filter,
    host_flat_ops,
    parse_peft,
    validate_peft,
)
from repro.fl.pod import PodAggregateStrategy, PodFLSpec, PodRelayStrategy
from repro.fl.task import lm_task
from repro.launch.mesh import make_host_mesh
from repro.models import layers
from repro.models.transformer import init_lm, lm_forward
from repro.sharding import rules

SEED = 0


# ---------------------------------------------------------------------------
# knob parsing / construction-time validation
# ---------------------------------------------------------------------------

def test_parse_peft():
    assert parse_peft("lora:8") == ("lora", 8)
    with pytest.raises(ValueError, match="unknown peft spec"):
        parse_peft("adapters:8")
    with pytest.raises(ValueError, match="unknown peft spec"):
        parse_peft("lora")
    with pytest.raises(ValueError, match="positive integer"):
        parse_peft("lora:0")
    with pytest.raises(ValueError, match="positive integer"):
        parse_peft("lora:-3")


def test_validate_peft_rejects_tree_impl():
    with pytest.raises(ValueError, match="fused flat path"):
        validate_peft("lora:8", update_impl="tree")
    with pytest.raises(ValueError, match="fused flat path"):
        LocalSpec(2, 4, 0.05, peft="lora:8")            # default impl is tree
    with pytest.raises(ValueError, match="fused flat path"):
        PodFLSpec(peft="lora:8")
    # filter alone needs the flat partition too
    with pytest.raises(ValueError, match="fused flat path"):
        LocalSpec(2, 4, 0.05, trainable_filter="head")


def test_effective_trainable_filter():
    assert effective_trainable_filter(
        LocalSpec(2, 4, 0.05, update_impl="fused", peft="lora:4")) == "lora"
    assert effective_trainable_filter(
        LocalSpec(2, 4, 0.05, update_impl="fused", trainable_filter="head")) == "head"
    assert effective_trainable_filter(LocalSpec(2, 4, 0.05)) is None


def test_zero_leaf_filter_raises_at_construction():
    cfg = get_reduced("qwen1.5-0.5b")       # no adapters built
    p_specs = jax.eval_shape(lambda k: init_lm(k, cfg), jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="matches zero leaves"):
        rules.trainable_mask(p_specs, "lora")


def test_relay_rejects_peft():
    spec = LocalSpec(2, 4, 0.05, update_impl="fused_interpret", peft="lora:4")
    with pytest.raises(ValueError, match="P2 rounds only"):
        RelayStrategy(spec=spec)
    with pytest.raises(ValueError, match="P2 rounds only"):
        PodRelayStrategy(spec=spec, mesh=make_host_mesh())


def test_lora_rank_validation():
    with pytest.raises(ValueError, match="positive integer"):
        layers.init_lora_linear(jax.random.PRNGKey(0), 8, 8, rank=0)


# ---------------------------------------------------------------------------
# LoRA layer semantics
# ---------------------------------------------------------------------------

def test_lora_zero_init_is_base_forward_bitwise():
    key = jax.random.PRNGKey(SEED)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16))
    base = layers.init_linear(key, 16, 8)
    lora = layers.init_lora_linear(key, 16, 8, rank=4)
    # adding adapters does not redraw the base weight
    np.testing.assert_array_equal(np.asarray(base["w"]),
                                  np.asarray(lora["w"]))
    np.testing.assert_array_equal(np.asarray(layers.linear(base, x)),
                                  np.asarray(layers.linear(lora, x)))


def test_lora_merge_forward_parity():
    key = jax.random.PRNGKey(SEED)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16))
    p = layers.init_lora_linear(key, 16, 8, rank=4)
    # perturb B so the adapter actually contributes
    p["lora_b"] = jax.random.normal(jax.random.PRNGKey(2), p["lora_b"].shape,
                                    p["lora_b"].dtype) * 0.1
    merged = layers.merge_lora(p)
    assert "lora_a" not in merged and "lora_b" not in merged
    np.testing.assert_allclose(np.asarray(layers.linear(merged, x)),
                               np.asarray(layers.linear(p, x)),
                               atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# engine runs: filter partition + frozen residency (host and pod)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def lora_setup():
    cfg = with_peft(get_reduced("qwen1.5-0.5b"), "lora:4")
    task = lm_task(cfg)
    data = make_synthetic_tokenlm(n_clients=8, seq_len=16,
                                  n_seq_per_client=8,
                                  vocab=cfg.vocab_size, beta=0.5, seed=SEED)
    return cfg, task, data


def _sched(rounds=4, chunk=2):
    return RoundSchedule(rounds=rounds, lr_decay=1.0, eval_every=0,
                         seed=SEED, chunk_size=chunk, sampling="host",
                         host_rng_offset=17)


def _run(task, data, *, peft=None, trainable_filter=None, backend="host",
         rounds=4, ledger=None, compression=None):
    spec = LocalSpec(n_steps=2, batch_size=4, lr=0.05,
                     update_impl="fused_interpret", peft=peft,
                     trainable_filter=trainable_filter,
                     compression=compression)
    if backend == "host":
        strat = AggregateStrategy(spec=spec, participation=0.25)
    else:
        strat = PodAggregateStrategy(spec=spec, mesh=make_host_mesh(),
                                     clients_per_round=2)
    return run_rounds(task, data, strat, _sched(rounds), ledger=ledger)


def test_all_matching_filter_equals_unfiltered_bitwise(lora_setup):
    """A filter selecting EVERY leaf partitions nothing — it must
    compile to the exact unfiltered program (the suite's oracle)."""
    cfg, task, data = lora_setup
    base = _run(task, data)
    allf = _run(task, data, trainable_filter=r".")      # matches all paths
    np.testing.assert_array_equal([h["local_loss"] for h in base.history],
                                  [h["local_loss"] for h in allf.history])
    for a, b in zip(jax.tree_util.tree_leaves(base.params),
                    jax.tree_util.tree_leaves(allf.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("backend", ["host", "pod"])
def test_frozen_leaves_bitwise_invariant(lora_setup, backend):
    """Multi-round LoRA run: every frozen leaf returns bitwise-equal to
    its init value, every adapter leaf moves."""
    cfg, task, data = lora_setup
    p0 = task.init(jax.random.PRNGKey(SEED))
    mask = rules.trainable_mask(p0, "lora")
    res = _run(task, data, peft="lora:4", backend=backend)
    moved = 0
    for (pa, a), b, m in zip(jax.tree_util.tree_leaves_with_path(p0),
                             jax.tree_util.tree_leaves(res.params), mask):
        if m:
            moved += int(not np.array_equal(np.asarray(a), np.asarray(b)))
        else:
            np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b),
                err_msg=f"frozen leaf changed: {pa}")
    assert moved == sum(mask)               # every adapter leaf trained


def test_host_pod_lora_parity(lora_setup):
    cfg, task, data = lora_setup
    host = _run(task, data, peft="lora:4", backend="host")
    pod = _run(task, data, peft="lora:4", backend="pod")
    np.testing.assert_allclose([h["local_loss"] for h in host.history],
                               [h["local_loss"] for h in pod.history],
                               atol=1e-5, rtol=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(host.params),
                    jax.tree_util.tree_leaves(pod.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=1e-4, rtol=1e-4)


def test_lora_merge_model_forward_parity(lora_setup):
    """Merging the TRAINED adapters into the base weights reproduces the
    adapter model's forward — the deployment path."""
    cfg, task, data = lora_setup
    res = _run(task, data, peft="lora:4", rounds=2)
    params = jax.device_get(res.params)
    merged = layers.merge_lora(params)
    assert not any("lora" in str(p)
                   for p, _ in jax.tree_util.tree_leaves_with_path(merged))
    toks = {"tokens": jnp.asarray(data.x[0][:2])}
    plain_cfg = dataclasses.replace(cfg, lora_rank=0)
    out_adapter, _, _ = lm_forward(params, cfg, toks)
    out_merged, _, _ = lm_forward(merged, plain_cfg, toks)
    np.testing.assert_allclose(np.asarray(out_adapter),
                               np.asarray(out_merged),
                               atol=2e-4, rtol=2e-4)


# ---------------------------------------------------------------------------
# wire accounting: the upload is the trainable slice
# ---------------------------------------------------------------------------

def _trainable_bytes(task, filter_spec):
    p_specs = jax.eval_shape(task.init, jax.random.PRNGKey(0))
    mask = rules.trainable_mask(p_specs, filter_spec)
    leaves = jax.tree_util.tree_leaves(p_specs)
    return int(sum(np.dtype(l.dtype).itemsize * np.prod(l.shape)
                   for l, m in zip(leaves, mask) if m))


def test_ledger_counts_trainable_slice_only(lora_setup):
    cfg, task, data = lora_setup
    led = CommLedger()
    rounds = 2
    _run(task, data, peft="lora:4", rounds=rounds, ledger=led)
    payload = _trainable_bytes(task, "lora")
    x = led.summary()["model_bytes"]
    k = 2                                   # participation 0.25 of 8
    assert led.p2_upload_bytes == rounds * k * payload
    assert led.p2_bytes == rounds * acc.compressed_round_bytes(
        "fedavg", k, x, payload)            # downloads still ship X
    assert led.summary()["payload_ratio"] == x / payload
    assert led.summary()["payload_ratio"] > 5


def test_peft_composes_with_compression(lora_setup):
    """A lossy wire spec compresses the SLICE: payload_bytes over the
    trainable buffer sizes — the two ratios multiply."""
    cfg, task, data = lora_setup
    spec = CompressionSpec(bits=8)
    led = CommLedger()
    rounds = 2
    _run(task, data, peft="lora:4", rounds=rounds, ledger=led,
         compression=spec)
    sizes = tuple(host_flat_ops(task, True, "lora").view
                  .buffer_sizes.values())
    payload = comp.payload_bytes(spec, sizes)
    assert payload < _trainable_bytes(task, "lora")
    assert led.p2_upload_bytes == rounds * 2 * payload


# ---------------------------------------------------------------------------
# (slow) pod: sharded trainable/frozen buckets on a 16-device mesh
# ---------------------------------------------------------------------------

_PEFT_SUBPROCESS_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    import jax
    import numpy as np
    from repro.configs import get_reduced, with_peft
    from repro.data.synthetic import make_synthetic_tokenlm
    from repro.fl.engine import RoundSchedule, run_rounds
    from repro.fl.local import LocalSpec
    from repro.fl.pod import PodAggregateStrategy
    from repro.fl.task import lm_task
    from repro.sharding import rules
    from repro.utils.flatten import is_frozen_bucket

    mesh = jax.make_mesh((4, 4), ("data", "model"))
    cfg = with_peft(get_reduced("qwen1.5-0.5b"), "lora:4")
    task = lm_task(cfg)
    data = make_synthetic_tokenlm(n_clients=8, seq_len=16,
                                  n_seq_per_client=8,
                                  vocab=cfg.vocab_size, beta=0.5, seed=0)
    spec = LocalSpec(n_steps=2, batch_size=4, lr=0.05,
                     update_impl="fused_interpret", peft="lora:4")
    strat = PodAggregateStrategy(spec=spec, mesh=mesh, clients_per_round=4)
    fops = strat.flat_ops(task)

    # the partition split buckets: trainable AND frozen groups exist,
    # with disjoint names, and the frozen groups carry their own
    # sharded (dtype x axes) decomposition
    t_names = {g.name for g in fops.view.trainable_groups}
    f_names = {g.name for g in fops.view.frozen_groups}
    assert t_names and f_names and not (t_names & f_names), (t_names, f_names)
    assert all(is_frozen_bucket(n) for n in f_names)
    fz_sh = rules.frozen_flat_shardings(fops.view, mesh)
    assert set(fz_sh) == f_names
    # at least one frozen bucket actually shards over the mesh (the big
    # frozen base must not replicate)
    assert any(sh.spec != jax.sharding.PartitionSpec(None, None)
               for sh in fz_sh.values()), {n: s.spec for n, s in fz_sh.items()}

    p0 = task.init(jax.random.PRNGKey(0))
    mask = rules.trainable_mask(p0, "lora")
    res = run_rounds(task, data, strat,
                     RoundSchedule(rounds=2, lr_decay=1.0, eval_every=0,
                                   seed=0, chunk_size=2, sampling="host",
                                   host_rng_offset=17))
    moved = 0
    for a, b, m in zip(jax.tree_util.tree_leaves(p0),
                       jax.tree_util.tree_leaves(res.params), mask):
        if m:
            moved += int(not np.array_equal(np.asarray(a), np.asarray(b)))
        else:
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert moved == sum(mask), (moved, sum(mask))
    print("POD_PEFT_SUBPROCESS_OK")
""")


@pytest.mark.slow
def test_pod_peft_sharded_buckets_16dev_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", _PEFT_SUBPROCESS_SCRIPT],
                         capture_output=True, text=True, env=env,
                         timeout=420)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "POD_PEFT_SUBPROCESS_OK" in out.stdout
