"""DP-FedAvg privacy primitives (repro.fl.privacy).

Three property families, each pinned on the tree oracle AND the fused
flat path, host and pod:

  - clipping bounds every client's aggregated contribution by C
    (scale = min(1, C/‖δ‖) folded into the aggregation coefficients);
  - the identity spec ``DPSpec(clip=inf, sigma=0)`` is BITWISE the
    baseline program on the fused path — the privacy switches are
    static, so turning DP "on but neutral" changes nothing;
  - aggregated noise has the calibrated variance σ²C²/K (zero-delta
    aggregate isolates the noise term; fixed seed, the bound is ~13
    standard errors wide so the test cannot flake).

Cross-backend (host vmap vs pod scan) DP runs match tightly for one
round — identical threefry noise bits by construction — and only
loosely after several (noise-perturbed trajectories amplify fp
reassociation chaotically), so the parity assertions here are
single-round.
"""
import dataclasses as dc

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.federated import FederatedDataset
from repro.fl import privacy
from repro.fl.engine import AggregateStrategy, RoundSchedule, run_rounds
from repro.fl.local import FlatParamOps, LocalSpec
from repro.fl.pod import PodAggregateStrategy
from repro.fl.privacy import DPSpec
from repro.fl.simulation import FLConfig, run_federated
from repro.fl.task import vision_task
from repro.utils.flatten import FlatView

SEED = 0


# ---------------------------------------------------------------------------
# spec validation + static switches
# ---------------------------------------------------------------------------

def test_dpspec_validation():
    assert DPSpec(1.0, 0.1).clips and DPSpec(1.0, 0.1).noised
    ident = DPSpec(float("inf"), 0.0)
    assert not ident.clips and not ident.noised
    with pytest.raises(ValueError):
        DPSpec(0.0)                     # clip must be positive
    with pytest.raises(ValueError):
        DPSpec(-1.0)
    with pytest.raises(ValueError):
        DPSpec(1.0, -0.5)               # sigma must be >= 0
    with pytest.raises(ValueError):
        DPSpec(float("inf"), 0.5)       # noise needs a finite bound


def test_relay_rejects_privacy():
    from repro.fl.engine import RelayStrategy
    with pytest.raises(ValueError):
        RelayStrategy(spec=LocalSpec(n_steps=1, batch_size=1, lr=0.1,
                                     dp=DPSpec(1.0)))
    with pytest.raises(ValueError):
        RelayStrategy(spec=LocalSpec(n_steps=1, batch_size=1, lr=0.1,
                                     secure_agg=True))


# ---------------------------------------------------------------------------
# leaf-keyed draws: tree oracle == FlatView buffers bit-for-bit
# ---------------------------------------------------------------------------

def _mixed_tree(key):
    ks = jax.random.split(key, 3)
    return {"w": jax.random.normal(ks[0], (9, 33)),
            "b": jax.random.normal(ks[1], (33,), jnp.float32),
            "head": {"w": jax.random.normal(ks[2], (33, 5))},
            "step": jnp.int32(3)}


def test_tree_normal_matches_flat_normal():
    tree = _mixed_tree(jax.random.PRNGKey(1))
    key = jax.random.PRNGKey(7)
    want = privacy.tree_normal(key, tree)
    view = FlatView.of(tree)
    bufs = view.normal(key)
    leaves = jax.tree_util.tree_leaves(want)
    for slot, leaf in zip(view.slots, leaves):
        got = bufs[slot.buffer][slot.offset:slot.offset + slot.size]
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(leaf).reshape(-1))
    # non-inexact source leaves draw zeros, so they perturb nothing
    int_draws = [draw for src, draw in
                 zip(jax.tree_util.tree_leaves(tree), leaves)
                 if not np.issubdtype(np.asarray(src).dtype, np.inexact)]
    assert int_draws and not np.asarray(int_draws[0]).any()


# ---------------------------------------------------------------------------
# clipping bounds the per-client contribution
# ---------------------------------------------------------------------------

def test_clip_bounds_every_client_tree_and_fused():
    clip = 0.5
    dp = DPSpec(clip)
    key = jax.random.PRNGKey(2)
    params = _mixed_tree(key)
    K = 3
    # client deltas of very different magnitudes: tiny (unclipped),
    # moderate, huge (heavily clipped)
    w_locals = jax.tree_util.tree_map(
        lambda p: jnp.stack([p + s * jax.random.normal(
            jax.random.fold_in(key, int(s * 100)), p.shape, jnp.float32)
            .astype(p.dtype) if jnp.issubdtype(p.dtype, jnp.inexact) else p
            for s in (0.01, 1.0, 30.0)]), params)
    weights = jnp.asarray([1.0, 2.0, 1.0])
    ids = jnp.arange(K)
    rk = jax.random.PRNGKey(3)

    scales = privacy.stacked_clip_scales(
        dp, jax.tree_util.tree_leaves(params),
        jax.tree_util.tree_leaves(w_locals))
    norms = np.sqrt(np.asarray(sum(
        jnp.sum((wl.astype(jnp.float32) - p.astype(jnp.float32)[None]) ** 2,
                axis=tuple(range(1, wl.ndim)))
        for p, wl in zip(jax.tree_util.tree_leaves(params),
                         jax.tree_util.tree_leaves(w_locals)))))
    # every clipped norm obeys the bound; the tiny client is untouched
    clipped = norms * np.asarray(scales)
    assert (clipped <= clip * (1 + 1e-5)).all(), (norms, clipped)
    assert np.isclose(scales[0], 1.0), scales
    assert scales[2] < 0.1

    # the aggregates implement exactly Σ w̄ᵢ·scaleᵢ·δᵢ
    got_tree = privacy.tree_dp_aggregate(dp, False, rk, ids, params,
                                         w_locals, weights)
    wbar = np.asarray(weights / jnp.sum(weights), np.float32)
    for p, wl, g in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(w_locals),
                        jax.tree_util.tree_leaves(got_tree)):
        p32 = np.asarray(p, np.float32)
        d = np.tensordot(wbar * np.asarray(scales),
                         np.asarray(wl, np.float32) - p32[None], axes=1)
        np.testing.assert_allclose(np.asarray(g, np.float32), p32 + d,
                                   atol=1e-5, rtol=1e-5)

    view = FlatView.of(params)
    fops = FlatParamOps(view=view, interpret=True)
    got_fused = fops.unflatten(privacy.fused_dp_aggregate(
        dp, False, fops, rk, ids, fops.flatten(params),
        view.flatten_stacked(w_locals), weights))
    for a, b in zip(jax.tree_util.tree_leaves(got_tree),
                    jax.tree_util.tree_leaves(got_fused)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=1e-5, rtol=1e-5)


def test_dp_clip_noise_kernel_matches_reference():
    # the standalone one-pass upload kernel: clip_scale·d (+ ns·z)
    tree = _mixed_tree(jax.random.PRNGKey(4))
    view = FlatView.of(tree)
    fops = FlatParamOps(view=view, interpret=True)
    d = fops.pad(view.normal(jax.random.PRNGKey(5)))
    z = fops.normal(jax.random.PRNGKey(6))
    out = fops.dp_clip_noise(d, z, jnp.float32(0.25), jnp.float32(0.1))
    for name, o in out.items():
        want = 0.25 * np.asarray(d[name]) + 0.1 * np.asarray(z[name])
        np.testing.assert_allclose(np.asarray(o), want, atol=1e-6, rtol=1e-6)
    out_nz = fops.dp_clip_noise(d, None, jnp.float32(0.25), jnp.float32(0.0))
    for name, o in out_nz.items():
        np.testing.assert_allclose(np.asarray(o), 0.25 * np.asarray(d[name]),
                                   atol=1e-6, rtol=1e-6)


# ---------------------------------------------------------------------------
# engine runs: identity spec bitwise, DP-on host/pod parity
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def vision_setup():
    rng = np.random.default_rng(SEED)
    N, per = 8, 16
    x = rng.normal(size=(N, per, 8, 8, 1)).astype(np.float32)
    y = rng.integers(0, 10, size=(N, per)).astype(np.int32)
    data = FederatedDataset(x=x, y=y, n_real=np.full((N,), per, np.int32),
                            test_x=x[0], test_y=y[0], n_classes=10,
                            name="privacy-test")
    task = vision_task("mlp", in_ch=1, seed_kwargs={"img": 8, "d_hidden": 16})
    return task, data


def _host_cfg(**kw):
    kw.setdefault("update_impl", "fused_interpret")
    return FLConfig(rounds=2, chunk_size=2, participation=0.5, local_steps=2,
                    batch_size=8, lr=0.05, eval_every=0, seed=SEED, **kw)


def test_identity_dpspec_bitwise_host_fused(vision_setup):
    task, data = vision_setup
    base = run_federated(task, data, _host_cfg())
    ident = run_federated(task, data,
                          _host_cfg(dp=DPSpec(float("inf"), 0.0)))
    for a, b in zip(jax.tree_util.tree_leaves(base.params),
                    jax.tree_util.tree_leaves(ident.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(
        [h["local_loss"] for h in base.history],
        [h["local_loss"] for h in ident.history])


def _pod_run(task, data, mesh, rounds, **spec_kw):
    spec_kw.setdefault("update_impl", "fused_interpret")
    strat = PodAggregateStrategy(
        spec=LocalSpec(n_steps=2, batch_size=8, lr=0.05, **spec_kw),
        algorithm="fedavg", mesh=mesh, clients_per_round=4)
    return run_rounds(task, data, strat,
                      RoundSchedule(rounds=rounds, eval_every=0, seed=SEED,
                                    chunk_size=rounds, sampling="host",
                                    host_rng_offset=17))


def _host_run(task, data, rounds, **spec_kw):
    spec_kw.setdefault("update_impl", "fused_interpret")
    strat = AggregateStrategy(
        spec=LocalSpec(n_steps=2, batch_size=8, lr=0.05, **spec_kw),
        algorithm="fedavg", participation=0.5)
    return run_rounds(task, data, strat,
                      RoundSchedule(rounds=rounds, eval_every=0, seed=SEED,
                                    chunk_size=rounds, sampling="host",
                                    host_rng_offset=17))


def test_identity_dpspec_bitwise_pod_fused(vision_setup):
    task, data = vision_setup
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    base = _pod_run(task, data, mesh, 2)
    ident = _pod_run(task, data, mesh, 2, dp=DPSpec(float("inf"), 0.0))
    for a, b in zip(jax.tree_util.tree_leaves(base.params),
                    jax.tree_util.tree_leaves(ident.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("spec_kw", [
    {"dp": DPSpec(0.5, 0.3)},
    {"dp": DPSpec(0.5, 0.0)},           # clip only
    {"dp": DPSpec(0.5, 0.3), "secure_agg": True},
])
def test_dp_round_host_pod_parity(vision_setup, spec_kw):
    # one round: host vmap aggregate and pod scan draw IDENTICAL noise
    # bits from the same round key, so they match to reduction-order fp
    task, data = vision_setup
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    host = _host_run(task, data, 1, **spec_kw)
    pod = _pod_run(task, data, mesh, 1, **spec_kw)
    for a, b in zip(jax.tree_util.tree_leaves(host.params),
                    jax.tree_util.tree_leaves(pod.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=2e-6, rtol=2e-6)


def test_dp_clip_changes_params_noise_reproducible(vision_setup):
    task, data = vision_setup
    cfg = _host_cfg(dp=DPSpec(0.5, 0.3))
    a = run_federated(task, data, cfg)
    b = run_federated(task, data, cfg)
    base = run_federated(task, data, _host_cfg())
    # same seed -> identical noisy run; noise -> differs from baseline
    for x, y in zip(jax.tree_util.tree_leaves(a.params),
                    jax.tree_util.tree_leaves(b.params)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    diffs = [np.abs(np.asarray(x, np.float32) - np.asarray(y, np.float32)).max()
             for x, y in zip(jax.tree_util.tree_leaves(a.params),
                             jax.tree_util.tree_leaves(base.params))]
    assert max(diffs) > 1e-3, diffs


# ---------------------------------------------------------------------------
# the calibrated noise variance: σ²C²/K on a zero-delta aggregate
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl", ["tree", "fused"])
def test_noise_variance_sigma2_c2_over_k(impl):
    sigma, clip, K, n = 1.0, 0.1, 8, 1 << 17
    dp = DPSpec(clip, sigma)
    params = {"w": jnp.zeros((n,), jnp.float32)}
    w_locals = {"w": jnp.zeros((K, n), jnp.float32)}   # δᵢ = 0
    weights = jnp.ones((K,))
    ids = jnp.arange(K)
    rk = jax.random.PRNGKey(123)
    if impl == "tree":
        new_p = privacy.tree_dp_aggregate(dp, False, rk, ids, params,
                                          w_locals, weights)
    else:
        view = FlatView.of(params)
        fops = FlatParamOps(view=view, interpret=True)
        new_p = fops.unflatten(privacy.fused_dp_aggregate(
            dp, False, fops, rk, ids, fops.flatten(params),
            view.flatten_stacked(w_locals), weights))
    noise = np.asarray(new_p["w"], np.float64)
    want_var = sigma ** 2 * clip ** 2 / K
    # sample-variance standard error is var·sqrt(2/n) ≈ 0.4% — the 5%
    # bound is ~13 standard errors, deterministic seed, cannot flake
    assert abs(np.var(noise) / want_var - 1.0) < 0.05, np.var(noise)
    assert abs(noise.mean()) < 5e-4


def test_fused_and_tree_noise_bits_identical():
    # same round key -> the extra term matches bit-for-bit across reprs
    sigma, clip, K, n = 0.7, 0.2, 4, 4096
    dp = DPSpec(clip, sigma)
    params = {"w": jnp.zeros((n,), jnp.float32)}
    w_locals = {"w": jnp.zeros((K, n), jnp.float32)}
    weights = jnp.asarray([1.0, 2.0, 3.0, 4.0])
    ids = jnp.asarray([5, 1, 3, 2])
    rk = jax.random.PRNGKey(9)
    tree_p = privacy.tree_dp_aggregate(dp, False, rk, ids, params,
                                       w_locals, weights)
    view = FlatView.of(params)
    fops = FlatParamOps(view=view, interpret=True)
    fused_p = fops.unflatten(privacy.fused_dp_aggregate(
        dp, False, fops, rk, ids, fops.flatten(params),
        view.flatten_stacked(w_locals), weights))
    np.testing.assert_array_equal(np.asarray(tree_p["w"]),
                                  np.asarray(fused_p["w"]))
