"""Secure-aggregation wire accounting (repro.core.comm_accounting).

The pairwise-mask simulation adds NO model-payload bytes — masks hide
inside the uploads they perturb — but each round the K participants run
a Bonawitz-style seed agreement: one SEED_BYTES seed per ordered pair,
K(K−1)·SEED_BYTES per round, tracked in ``CommLedger.mask_bytes``.
The Table IV closed forms (tests/test_properties.py) are untouched:
with ``secure_agg=False`` every pre-existing ledger total is identical.
"""
import numpy as np
import pytest

from repro.core import comm_accounting as acc
from repro.core.comm_accounting import SEED_BYTES, CommLedger


def _params(n_bytes=64):
    return {"w": np.zeros(n_bytes, dtype=np.uint8)}


@pytest.mark.parametrize("k", [1, 2, 5, 32])
def test_mask_bytes_closed_form(k):
    assert acc.secure_agg_mask_bytes(k) == k * (k - 1) * SEED_BYTES


def test_mask_bytes_zero_for_single_client():
    # one participant has nobody to pair with
    assert acc.secure_agg_mask_bytes(1) == 0


@pytest.mark.parametrize("algo", ["fedavg", "fedprox", "moon", "scaffold"])
def test_ledger_accumulates_mask_bytes(algo):
    params = _params()
    k, rounds = 6, 3
    led = CommLedger()
    for _ in range(rounds):
        led.record_round(algo, k, params, secure_agg=True)
    want_mask = rounds * acc.secure_agg_mask_bytes(k)
    assert led.mask_bytes == want_mask
    assert led.total_bytes == led.p2_bytes + want_mask
    s = led.summary()
    assert s["mask_bytes"] == want_mask
    assert s["total_bytes"] == led.total_bytes


def test_secure_agg_off_is_the_existing_ledger():
    params = _params()
    base, off = CommLedger(), CommLedger()
    for _ in range(4):
        base.record_round("fedavg", 5, params)
        off.record_round("fedavg", 5, params, secure_agg=False)
    assert off.mask_bytes == 0
    assert off.summary() == base.summary()
    assert off.total_bytes == off.p1_bytes + off.p2_bytes


def test_mask_bytes_independent_of_model_size():
    # seed agreement scales with K only, never with X
    small, big = CommLedger(), CommLedger()
    small.record_round("fedavg", 8, _params(16), secure_agg=True)
    big.record_round("fedavg", 8, _params(16_384), secure_agg=True)
    assert small.mask_bytes == big.mask_bytes == acc.secure_agg_mask_bytes(8)
    assert small.p2_bytes < big.p2_bytes
