"""Secure-aggregation wire accounting (repro.core.comm_accounting).

The pairwise-mask simulation adds NO model-payload bytes — masks hide
inside the uploads they perturb — but each round the K participants run
a Bonawitz-style seed agreement: one SEED_BYTES seed per ordered pair,
K(K−1)·SEED_BYTES per round, tracked in ``CommLedger.mask_bytes``.
The Table IV closed forms (tests/test_properties.py) are untouched:
with ``secure_agg=False`` every pre-existing ledger total is identical.
"""
import numpy as np
import pytest

from repro.core import comm_accounting as acc
from repro.core.comm_accounting import SEED_BYTES, CommLedger


def _params(n_bytes=64):
    return {"w": np.zeros(n_bytes, dtype=np.uint8)}


@pytest.mark.parametrize("k", [1, 2, 5, 32])
def test_mask_bytes_closed_form(k):
    assert acc.secure_agg_mask_bytes(k) == k * (k - 1) * SEED_BYTES


def test_mask_bytes_zero_for_single_client():
    # one participant has nobody to pair with
    assert acc.secure_agg_mask_bytes(1) == 0


@pytest.mark.parametrize("algo", ["fedavg", "fedprox", "moon", "scaffold"])
def test_ledger_accumulates_mask_bytes(algo):
    params = _params()
    k, rounds = 6, 3
    led = CommLedger()
    for _ in range(rounds):
        led.record_round(algo, k, params, secure_agg=True)
    want_mask = rounds * acc.secure_agg_mask_bytes(k)
    assert led.mask_bytes == want_mask
    assert led.total_bytes == led.p2_bytes + want_mask
    s = led.summary()
    assert s["mask_bytes"] == want_mask
    assert s["total_bytes"] == led.total_bytes


def test_secure_agg_off_is_the_existing_ledger():
    params = _params()
    base, off = CommLedger(), CommLedger()
    for _ in range(4):
        base.record_round("fedavg", 5, params)
        off.record_round("fedavg", 5, params, secure_agg=False)
    assert off.mask_bytes == 0
    assert off.summary() == base.summary()
    assert off.total_bytes == off.p1_bytes + off.p2_bytes


def test_mask_bytes_independent_of_model_size():
    # seed agreement scales with K only, never with X
    small, big = CommLedger(), CommLedger()
    small.record_round("fedavg", 8, _params(16), secure_agg=True)
    big.record_round("fedavg", 8, _params(16_384), secure_agg=True)
    assert small.mask_bytes == big.mask_bytes == acc.secure_agg_mask_bytes(8)
    assert small.p2_bytes < big.p2_bytes


# ---------------------------------------------------------------------------
# capacity recompute + compressed-payload accounting
# ---------------------------------------------------------------------------

def test_capacity_recomputed_per_record_not_latched():
    """Regression: the ledger used to latch the first record's model
    bytes forever — later records with a DIFFERENT capacity (P1 relay vs
    a resized P2 model, or an explicit override) were mis-billed."""
    led = CommLedger()
    led.record_round("fedavg", 1, _params(100))
    led.record_round("fedavg", 1, _params(300))
    assert led.p2_bytes == 2 * (100 + 300)      # legs=1, down+up per round
    # first-seen capacity is REPORTING only, never the billing basis
    assert led.summary()["model_bytes"] == 100


def test_explicit_x_bytes_override_wins_over_params():
    led = CommLedger()
    led.record_round("fedavg", 2, _params(64), x_bytes=1000)
    assert led.p2_bytes == 2 * 2 * 1000
    led2 = CommLedger()
    led2.record_cyclic_round(3, _params(64), x_bytes=500)
    assert led2.p1_bytes == 2 * 3 * 500
    assert led2.summary()["model_bytes"] == 500


@pytest.mark.parametrize("algo", ["fedavg", "scaffold"])
def test_compressed_round_accounting(algo):
    """payload_bytes splits the legs: downloads still ship full X,
    uploads ship the compressed payload — ledger == closed form."""
    x, payload, k, rounds = 4000, 1016, 5, 3
    led = CommLedger()
    for _ in range(rounds):
        led.record_round(algo, k, _params(x), payload_bytes=payload)
    legs = acc._PER_ROUND_FACTOR[algo] // 2
    assert led.p2_bytes == rounds * acc.compressed_round_bytes(
        algo, k, x, payload)
    assert led.p2_bytes == rounds * k * legs * (x + payload)
    assert led.p2_upload_bytes == rounds * k * legs * payload
    assert led.p2_upload_full_bytes == rounds * k * legs * x
    s = led.summary()
    assert s["payload_ratio"] == x / payload
    assert s["p2_upload_bytes"] == led.p2_upload_bytes


def test_payload_ratio_is_one_without_compression():
    led = CommLedger()
    led.record_round("fedavg", 4, _params(256))
    assert led.payload_ratio == 1.0
    empty = CommLedger()
    assert empty.payload_ratio == 1.0


def test_mixed_compressed_and_full_rounds_blend_the_ratio():
    led = CommLedger()
    led.record_round("fedavg", 1, _params(1000))                    # full
    led.record_round("fedavg", 1, _params(1000), payload_bytes=250)
    assert led.p2_upload_bytes == 1000 + 250
    assert led.p2_upload_full_bytes == 2000
    assert led.payload_ratio == 2000 / 1250
