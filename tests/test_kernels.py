"""Pallas-kernel correctness sweeps (interpret mode) vs ref.py oracles.

Per the assignment: for each kernel, sweep shapes/dtypes and
assert_allclose against the pure-jnp oracle.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ssd_scan import ssd_scan


def _qkv(key, B, S, T, H, KH, hd, dtype):
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, S, H, hd), dtype)
    k = jax.random.normal(kk, (B, T, KH, hd), dtype)
    v = jax.random.normal(kv, (B, T, KH, hd), dtype)
    return q, k, v


TOL = {jnp.float32: dict(atol=2e-5, rtol=2e-5),
       jnp.bfloat16: dict(atol=2e-2, rtol=2e-2)}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,KH,hd", [
    (1, 128, 4, 4, 64),     # MHA
    (2, 256, 8, 2, 64),     # GQA 4:1
    (1, 192, 6, 1, 32),     # MQA, non-multiple-of-block seq (padding path)
    (1, 128, 4, 2, 128),    # hd = 128 (MXU tile)
])
def test_flash_attention_causal(dtype, B, S, H, KH, hd):
    q, k, v = _qkv(jax.random.PRNGKey(0), B, S, S, H, KH, hd, dtype)
    out = flash_attention(q, k, v, causal=True, block_q=64, block_k=64,
                          interpret=True)
    want = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


@pytest.mark.parametrize("window", [16, 64, 1 << 30])
def test_flash_attention_sliding_window(window):
    q, k, v = _qkv(jax.random.PRNGKey(1), 2, 128, 128, 4, 2, 64, jnp.float32)
    out = flash_attention(q, k, v, causal=True, window=jnp.int32(window),
                          block_q=64, block_k=64, interpret=True)
    want = ref.attention_ref(q, k, v, causal=True,
                             window=window if window < 1 << 29 else None)
    np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("cache_len", [17, 64, 100])
def test_flash_attention_decode_offset(cache_len):
    """Decode: one query against cache_len keys (q_offset = cache_len)."""
    T = 128
    q, k, v = _qkv(jax.random.PRNGKey(2), 2, 1, T, 4, 2, 64, jnp.float32)
    # zero out keys beyond cache_len the way a real cache would be stale:
    # the kernel must mask kpos > q_offset anyway (causality).
    out = flash_attention(q, k, v, causal=True, q_offset=jnp.int32(cache_len),
                          block_q=64, block_k=64, interpret=True)
    want = ref.attention_ref(q, k, v, causal=True, q_offset=cache_len)
    np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)


def test_flash_attention_matches_traced_window():
    """window as a traced scalar (hybrid per-layer SWA/global flag)."""
    q, k, v = _qkv(jax.random.PRNGKey(3), 1, 128, 128, 4, 4, 64, jnp.float32)

    @jax.jit
    def run(w):
        return flash_attention(q, k, v, causal=True, window=w,
                               block_q=64, block_k=64, interpret=True)

    np.testing.assert_allclose(run(jnp.int32(32)),
                               ref.attention_ref(q, k, v, window=32),
                               atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(run(jnp.int32(1 << 30)),
                               ref.attention_ref(q, k, v),
                               atol=2e-5, rtol=2e-5)


def _ssd_inputs(key, B, S, H, P, N, G, dtype):
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, S, H, P), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H))).astype(dtype)
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (B, S, G, N), dtype)
    C = jax.random.normal(ks[4], (B, S, G, N), dtype)
    return x, dt, A, Bm, C


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,P,N,G,chunk", [
    (1, 128, 2, 32, 16, 1, 32),
    (2, 256, 4, 64, 16, 1, 64),
    (1, 96, 2, 32, 8, 2, 32),     # grouped B/C + padding path (96 % 32 == 0? yes) — use 80
    (1, 80, 2, 32, 8, 1, 32),     # padding path: 80 -> 96
])
def test_ssd_scan_vs_ref(dtype, B, S, H, P, N, G, chunk):
    x, dt, A, Bm, C = _ssd_inputs(jax.random.PRNGKey(0), B, S, H, P, N, G, dtype)
    y, final = ssd_scan(x, dt, A, Bm, C, chunk=chunk, interpret=True)
    y_ref, final_ref = ref.ssd_ref(x, dt, A, Bm, C)
    tol = dict(atol=2e-3, rtol=2e-3) if dtype == jnp.float32 else dict(atol=8e-2, rtol=8e-2)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32), **tol)
    np.testing.assert_allclose(np.asarray(final), np.asarray(final_ref),
                               atol=2e-3, rtol=2e-3)


def test_ssd_chunk_invariance():
    """The chunked algorithm is exact: chunk size must not matter."""
    x, dt, A, Bm, C = _ssd_inputs(jax.random.PRNGKey(1), 1, 128, 2, 32, 16, 1,
                                  jnp.float32)
    y32, f32_ = ssd_scan(x, dt, A, Bm, C, chunk=32, interpret=True)
    y64, f64_ = ssd_scan(x, dt, A, Bm, C, chunk=64, interpret=True)
    np.testing.assert_allclose(y32, y64, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(f32_, f64_, atol=1e-4, rtol=1e-4)


def test_ops_wrappers_jit():
    """Public jit'd wrappers route through and stay allclose."""
    q, k, v = _qkv(jax.random.PRNGKey(4), 1, 128, 128, 4, 2, 64, jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True, interpret=True)
    np.testing.assert_allclose(out, ref.attention_ref(q, k, v),
                               atol=2e-5, rtol=2e-5)
    x, dt, A, Bm, C = _ssd_inputs(jax.random.PRNGKey(5), 1, 64, 2, 32, 16, 1,
                                  jnp.float32)
    y = ops.ssd(x, dt, A, Bm, C, chunk=32, interpret=True)
    y_ref, _ = ref.ssd_ref(x, dt, A, Bm, C)
    np.testing.assert_allclose(y, y_ref, atol=2e-3, rtol=2e-3)
