"""Overlapped round pipeline: ``RoundSchedule(overlap=True)`` must be
bitwise-identical to the synchronous path.

The pipeline only reorders WHEN host residency planning happens (chunk
N+1 is staged while dispatch N runs on device) — never WHAT is planned:
the host rng / device-sampling replay streams advance in execution
order either way, and ``commit_chunk`` splices staged rows against the
latest slot table.  These tests pin that contract:

  - host engine, sparse store with capacity forcing eviction + spill +
    refill across dispatch boundaries (scaffold AND moon, host AND
    replayed device sampling): bitwise;
  - dense store (residency is a no-op, the pipeline still prefetches
    plans): bitwise;
  - pod backend on the 1-device host mesh with the sharded store:
    bitwise;
  - a pathologically slow ``stage_chunk`` degrades throughput only —
    results stay bitwise and the dispatch count is exact;
  - ``EngineResult.timing`` carries the pipeline breakdown;
  - a switch policy forces the pipeline off (chunk=1 probing) without
    changing results.
"""
import time

import jax
import numpy as np
import pytest

from repro.data.federated import FederatedDataset
from repro.fl.engine import (
    AggregateStrategy,
    DenseClientStateStore,
    RoundSchedule,
    SparseClientStateStore,
    run_rounds,
)
from repro.fl.local import LocalSpec
from repro.fl.pod import PodAggregateStrategy, ShardedSparseClientStateStore
from repro.fl.task import vision_task
from repro.launch.mesh import make_host_mesh

SEED = 0
N_CLIENTS = 8
CAPACITY = 4


@pytest.fixture(scope="module")
def setup():
    task = vision_task("mlp", in_ch=1, seed_kwargs={"img": 8, "d_hidden": 16})
    rng = np.random.default_rng(SEED)
    per = 16
    x = rng.normal(size=(N_CLIENTS, per, 8, 8, 1)).astype(np.float32)
    y = rng.integers(0, 10, size=(N_CLIENTS, per)).astype(np.int32)
    data = FederatedDataset(x=x, y=y,
                            n_real=np.full((N_CLIENTS,), per, np.int32),
                            test_x=x[0], test_y=y[0], n_classes=10,
                            name="overlap-test")
    return task, data


def _sched(sampling, *, overlap, rounds=6, chunk=2):
    return RoundSchedule(rounds=rounds, lr_decay=1.0, eval_every=0,
                         seed=SEED, chunk_size=chunk, sampling=sampling,
                         host_rng_offset=17, overlap=overlap)


def _spec(algo="scaffold"):
    return LocalSpec(n_steps=2, batch_size=4, lr=0.05, variant=algo,
                     update_impl="fused_interpret")


def _host_run(task, data, store, sched, algo="scaffold"):
    strat = AggregateStrategy(spec=_spec(algo), algorithm=algo,
                              participation=0.25, state_store=store)
    return run_rounds(task, data, strat, sched)


def _assert_bitwise(res_a, res_b):
    np.testing.assert_array_equal(
        [h["local_loss"] for h in res_a.history],
        [h["local_loss"] for h in res_b.history])
    for a, b in zip(jax.tree_util.tree_leaves(res_a.params),
                    jax.tree_util.tree_leaves(res_b.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree_util.tree_leaves(res_a.algo_state),
                    jax.tree_util.tree_leaves(res_b.algo_state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert res_a.dispatches == res_b.dispatches


@pytest.mark.parametrize("algo", ["scaffold", "moon"])
@pytest.mark.parametrize("sampling", ["host", "device"])
def test_overlap_matches_sync_sparse_host(setup, algo, sampling):
    """capacity=4 with chunk=2 × K=2 evicts, spills and refaults rows
    across every dispatch boundary — the exact window where a stale
    slot table or an early/late replay draw would diverge."""
    task, data = setup
    sync = _host_run(task, data, SparseClientStateStore(capacity=CAPACITY),
                     _sched(sampling, overlap=False), algo)
    ovl = _host_run(task, data, SparseClientStateStore(capacity=CAPACITY),
                    _sched(sampling, overlap=True), algo)
    _assert_bitwise(sync, ovl)


def test_overlap_matches_sync_dense(setup):
    """Dense store: no residency to pipeline, but plan prefetch still
    reorders host rng consumption relative to dispatch — must not."""
    task, data = setup
    sync = _host_run(task, data, DenseClientStateStore(),
                     _sched("host", overlap=False))
    ovl = _host_run(task, data, DenseClientStateStore(),
                    _sched("host", overlap=True))
    _assert_bitwise(sync, ovl)


def test_overlap_matches_sync_pod(setup):
    task, data = setup
    mesh = make_host_mesh()

    def run(overlap):
        strat = PodAggregateStrategy(
            spec=_spec(), algorithm="scaffold", mesh=mesh,
            clients_per_round=2,
            state_store=ShardedSparseClientStateStore(capacity=CAPACITY,
                                                      mesh=mesh))
        return run_rounds(task, data, strat, _sched("host", overlap=overlap))

    _assert_bitwise(run(False), run(True))


class _SlowStageStore(SparseClientStateStore):
    """Host planning slower than device compute: the pipeline's stage
    step becomes the bottleneck.  Overlap must degrade to sync-like
    throughput without reordering any observable effect."""

    def stage_chunk(self, ids_block):
        time.sleep(0.02)
        return super().stage_chunk(ids_block)


def test_slow_host_prep_degrades_gracefully(setup):
    task, data = setup
    sync = _host_run(task, data, _SlowStageStore(capacity=CAPACITY),
                     _sched("host", overlap=False))
    ovl = _host_run(task, data, _SlowStageStore(capacity=CAPACITY),
                    _sched("host", overlap=True))
    _assert_bitwise(sync, ovl)
    assert ovl.dispatches == 3          # ceil(6 rounds / chunk 2)


def test_timing_breakdown_populated(setup):
    task, data = setup
    res = _host_run(task, data, SparseClientStateStore(capacity=CAPACITY),
                    _sched("host", overlap=True))
    assert res.timing is not None
    for key in ("host_residency_ms", "staged_transfer_ms",
                "dispatch_enqueue_ms", "device_wait_ms",
                "spill_materialize_ms"):
        assert key in res.timing and res.timing[key] >= 0.0, res.timing
    # the sparse path really moved staged bytes through device_put
    assert res.timing["staged_transfer_ms"] > 0.0


def test_refault_burst_is_bitwise_and_spill_time_is_surfaced(setup):
    """capacity=4 against 8 clients with K=2 × chunk=2 turns every
    dispatch boundary into an eviction burst: rows spill to host numpy,
    then refault on the next appearance of the same client.  The burst
    must be invisible in results (sparse == dense == sync, bitwise) and
    the background spill→numpy conversion time must be surfaced in
    ``EngineResult.timing`` — it runs OFF the critical path, so the
    engine reports it separately instead of folding it into
    ``host_residency_ms``."""
    task, data = setup
    rounds = 10
    dense = _host_run(task, data, DenseClientStateStore(),
                      _sched("host", overlap=False, rounds=rounds))
    sync = _host_run(task, data, SparseClientStateStore(capacity=CAPACITY),
                     _sched("host", overlap=False, rounds=rounds))
    ovl = _host_run(task, data, SparseClientStateStore(capacity=CAPACITY),
                    _sched("host", overlap=True, rounds=rounds))
    _assert_bitwise(sync, ovl)
    # residency (evict → spill → refault) never leaks into the results
    np.testing.assert_array_equal([h["local_loss"] for h in dense.history],
                                  [h["local_loss"] for h in ovl.history])
    for a, b in zip(jax.tree_util.tree_leaves(dense.params),
                    jax.tree_util.tree_leaves(ovl.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # the burst really spilled, and the eager background conversion was
    # accounted — both pipelined and synchronous runs surface it
    assert ovl.timing["spill_materialize_ms"] > 0.0, ovl.timing
    assert sync.timing["spill_materialize_ms"] > 0.0, sync.timing


def test_switch_policy_forces_overlap_off(setup):
    """Probing policies need per-round history before planning the next
    round, so the engine silently drops to the synchronous chunk=1
    path — results must equal an explicit sync run."""
    task, data = setup

    class _NeverSwitch:
        def should_switch(self, rnd, history):
            return False

    def run(overlap, policy):
        strat = AggregateStrategy(spec=_spec(), algorithm="scaffold",
                                  participation=0.25,
                                  state_store=SparseClientStateStore(
                                      capacity=CAPACITY))
        return run_rounds(task, data, strat,
                          _sched("host", overlap=overlap, rounds=4),
                          switch_policy=policy)

    _assert_bitwise(run(False, _NeverSwitch()), run(True, _NeverSwitch()))
