"""Batched serving example: one engine, mixed request shapes, all three
input modalities (text, VLM, audio) through the same decode program.

    PYTHONPATH=src python examples/serve_batch.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import get_reduced
from repro.launch.serve import Engine


def demo(arch: str, new_tokens: int = 8):
    cfg = get_reduced(arch)
    eng = Engine(cfg, seed=0)
    key = jax.random.PRNGKey(1)
    B, S = 4, 24
    if cfg.input_mode == "tokens":
        batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    elif cfg.input_mode == "vlm":
        batch = {
            "patch_embeds": jax.random.normal(
                key, (B, cfg.n_prefix_tokens, cfg.d_model), cfg.dtype),
            "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
        }
    else:  # audio
        batch = {"frame_embeds": jax.random.normal(
            key, (B, S, cfg.d_model), cfg.dtype)}
    toks, stats = eng.generate(batch, new_tokens)
    print(f"[{arch:16s}] mode={cfg.input_mode:10s} prefill={stats.prefill_s * 1e3:5.0f}ms "
          f"decode={stats.tok_per_s:6.1f} tok/s out_shape={tuple(toks.shape)}")


def main():
    t0 = time.time()
    for arch in ("qwen2-1.5b",        # dense GQA
                 "mamba2-1.3b",       # SSM (O(1)-state decode)
                 "internvl2-1b",      # VLM backbone (patch-embed prefix)
                 "musicgen-medium"):  # audio decoder (4 codebooks)
        demo(arch)
    print(f"total {time.time() - t0:.0f}s")


if __name__ == "__main__":
    main()
