"""Quickstart — CyclicFL in ~60 seconds on CPU.

Runs the paper's headline pipeline at toy scale: cyclic pre-training
(P1) on Dirichlet-non-IID synthetic vision data, then FedAvg (P2) from
the pre-trained model, and compares against FedAvg from random init
under the SAME total round budget.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

from repro.core.cyclic import CyclicConfig
from repro.core.pipeline import run_cyclic_then_federated
from repro.data.synthetic import DATASETS
from repro.fl.simulation import FLConfig
from repro.fl.task import vision_task


def main():
    t0 = time.time()
    # 16 clients, strongly non-IID (Dirichlet beta=0.1)
    data = DATASETS.get("cifar10-like")(n_clients=16, beta=0.1, seed=0,
                                        n_train=2048, n_test=512)
    task = vision_task("lenet5", n_classes=10, in_ch=3)

    cyc = CyclicConfig(rounds=4, participation=0.25, local_steps=10,
                       eval_every=2, seed=0)
    fed = FLConfig(algorithm="fedavg", rounds=8, participation=0.25,
                   local_steps=10, eval_every=2, seed=0)

    print("== Cyclic+FedAvg (P1: 4 rounds relay, P2: 8 rounds FedAvg) ==")
    with_cyclic = run_cyclic_then_federated(task, data, cyc, fed, verbose=True)

    print("== FedAvg from random init (12 rounds, same total budget) ==")
    baseline = run_cyclic_then_federated(
        task, data, None,
        FLConfig(algorithm="fedavg", rounds=12, participation=0.25,
                 local_steps=10, eval_every=2, seed=0),
        verbose=True)

    a, b = with_cyclic.best_acc(), baseline.best_acc()
    print(f"\nCyclic+FedAvg best acc : {a.get('acc', 0):.4f} "
          f"(round {a.get('round')})")
    print(f"FedAvg        best acc : {b.get('acc', 0):.4f} "
          f"(round {b.get('round')})")
    print(f"communication (bytes)  : cyclic={with_cyclic.ledger.total_bytes:.2e} "
          f"baseline={baseline.ledger.total_bytes:.2e}")
    print(f"total {time.time() - t0:.0f}s")


if __name__ == "__main__":
    main()
