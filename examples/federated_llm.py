"""End-to-end driver: CyclicFL federated next-token training of a ~100M
transformer through the POD driver (the production code path: sharded
round programs, P1 relay then P2 FedAvg).

The model is a width/depth-reduced TinyLlama-family config scaled to
~100M parameters; data is the synthetic federated token stream
(Dirichlet topic mixture over clients → natural non-IID).

    PYTHONPATH=src python examples/federated_llm.py            # ~100M, slow on CPU
    PYTHONPATH=src python examples/federated_llm.py --tiny     # seconds-scale
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_reduced
from repro.data.synthetic import make_synthetic_tokenlm
from repro.launch.train import PodFLSpec, run_pod_training
from repro.models.transformer import lm_forward
from repro.configs.common import param_count


def model_100m():
    """~100M-param llama-family config (tinyllama reduced in depth/width)."""
    base = get_config("tinyllama-1.1b")
    return dataclasses.replace(
        base, name="tinyllama-100m", n_layers=6, d_model=768, n_heads=12,
        n_kv_heads=4, head_dim=64, d_ff=2048, vocab_size=32000,
        dtype=jnp.float32, param_dtype=jnp.float32, remat=False)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true",
                    help="reduced config (CI-friendly)")
    ap.add_argument("--cyclic-rounds", type=int, default=2)
    ap.add_argument("--fl-rounds", type=int, default=3)
    ap.add_argument("--local-steps", type=int, default=10)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_reduced("tinyllama-1.1b") if args.tiny else model_100m()
    n_params = param_count(cfg)
    print(f"[llm] {cfg.name}: {n_params / 1e6:.1f}M params")

    data = make_synthetic_tokenlm(
        n_clients=16, seq_len=args.seq, n_seq_per_client=32,
        vocab=cfg.vocab_size, beta=0.5, seed=args.seed)

    # eval: per-sequence next-token loss, streamed through the engine's
    # in-program eval (traceable per-sample contract — the engine
    # evaluates the whole test set inside the chunked round program, so
    # evaluating every round still costs one dispatch per chunk).  The
    # metric must be PER-SAMPLE — (B,) values, not a broadcast batch
    # mean — so the engine's pad weighting stays exact for any
    # eval_batch / test-set size combination
    def eval_loss(params, bx, by):
        logits, _, _ = lm_forward(params, cfg, {"tokens": bx})
        logits = logits.astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(by, 0)[..., None], axis=-1)[..., 0]
        valid = (by >= 0).astype(jnp.float32)
        per_tok = (logz - gold) * valid
        return per_tok.sum(axis=-1) / jnp.maximum(valid.sum(axis=-1), 1.0)

    spec = PodFLSpec(local_steps=args.local_steps, lr=0.03)
    t0 = time.time()
    res = run_pod_training(
        cfg, data, cyclic_rounds=args.cyclic_rounds, fl_rounds=args.fl_rounds,
        clients_per_round=4, spec=spec, seed=args.seed,
        eval_fn=eval_loss, eval_batch=16, verbose=True)
    print(f"[llm] eval loss trajectory: "
          f"{[round(h['eval'], 4) for h in res.history]}")
    first, last = res.history[0]["eval"], res.history[-1]["eval"]
    print(f"[llm] eval loss {first:.4f} -> {last:.4f} "
          f"({time.time() - t0:.0f}s)  improved={last < first}")


if __name__ == "__main__":
    main()
