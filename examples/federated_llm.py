"""End-to-end driver: CyclicFL federated next-token training of a ~100M
transformer through the POD driver (the production code path: sharded
round programs, P1 relay then P2 FedAvg).

The model is a width/depth-reduced TinyLlama-family config scaled to
~100M parameters; data is the synthetic federated token stream
(Dirichlet topic mixture over clients → natural non-IID).

The DEFAULT run is parameter-efficient: ``--peft lora:8`` builds the
model with rank-8 LoRA adapters and P2 trains ONLY them — frozen
leaves never enter the kernels, the donated round carry or the upload
(repro.fl.local / repro.utils.flatten), so the client "upload" is the
adapter slice (~1% of the model here).  ``--peft none`` asks for full
fine-tuning, which this example refuses with a clear message when the
estimated round working set does not fit in host memory.

    PYTHONPATH=src python examples/federated_llm.py            # ~100M LoRA smoke
    PYTHONPATH=src python examples/federated_llm.py --tiny     # seconds-scale
    PYTHONPATH=src python examples/federated_llm.py --peft none --fl-rounds 3
"""
import argparse
import dataclasses
import os
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_reduced, with_peft
from repro.configs.common import param_count
from repro.data.synthetic import make_synthetic_tokenlm
from repro.launch.train import PodFLSpec, run_pod_training
from repro.models.transformer import init_lm, lm_forward
from repro.sharding import rules


def model_100m():
    """~100M-param llama-family config (tinyllama reduced in depth/width)."""
    base = get_config("tinyllama-1.1b")
    return dataclasses.replace(
        base, name="tinyllama-100m", n_layers=6, d_model=768, n_heads=12,
        n_kv_heads=4, head_dim=64, d_ff=2048, vocab_size=32000,
        dtype=jnp.float32, param_dtype=jnp.float32, remat=False)


def host_memory_bytes() -> int:
    try:
        return os.sysconf("SC_PAGE_SIZE") * os.sysconf("SC_PHYS_PAGES")
    except (ValueError, OSError):
        return 1 << 34                          # unknown platform: assume 16 GiB


def check_fits(cfg, peft) -> None:
    """Refuse a full fine-tune that will not fit.  The P2 round program
    holds the params, the donated next-params, the f32 delta accumulator
    and one client's gradients/activations live at once — ~6× the param
    bytes is the honest floor.  With a trainable filter only the slice
    pays that multiplier; the frozen constant is held once."""
    n_params = param_count(cfg)
    if peft is not None:
        return
    need = 6 * n_params * 4
    have = host_memory_bytes()
    if need > 0.8 * have:
        sys.exit(
            f"[llm] full fine-tune of {cfg.name} needs ~{need / 1e9:.1f} GB "
            f"of round working set (~6x {n_params / 1e6:.0f}M f32 params) "
            f"but this host has {have / 1e9:.1f} GB — run the default "
            f"--peft lora:8 (trains the adapter slice only) or --tiny")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true",
                    help="reduced config (CI-friendly)")
    ap.add_argument("--peft", default="lora:8", metavar="lora:<r>|none",
                    help="P2 trainable slice: rank-r LoRA adapters "
                         "(default lora:8) or 'none' for full fine-tuning")
    ap.add_argument("--cyclic-rounds", type=int, default=1)
    ap.add_argument("--fl-rounds", type=int, default=1)
    ap.add_argument("--local-steps", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    peft = None if args.peft in ("none", "") else args.peft
    cfg = with_peft(get_reduced("tinyllama-1.1b") if args.tiny
                    else model_100m(), peft)
    check_fits(cfg, peft)
    n_params = param_count(cfg)
    msg = f"[llm] {cfg.name}: {n_params / 1e6:.1f}M params"
    if peft is not None:
        p_specs = jax.eval_shape(lambda k: init_lm(k, cfg),
                                 jax.random.PRNGKey(0))
        mask = rules.trainable_mask(p_specs, "lora")
        leaves = jax.tree_util.tree_leaves(p_specs)
        n_train = sum(int(l.size) for l, m in zip(leaves, mask) if m)
        msg += (f", {n_train / 1e6:.2f}M trainable ({peft}) — "
                f"{n_params / n_train:.0f}x smaller uploads")
    print(msg)

    data = make_synthetic_tokenlm(
        n_clients=16, seq_len=args.seq, n_seq_per_client=32,
        vocab=cfg.vocab_size, beta=0.5, seed=args.seed)

    # eval: per-sequence next-token loss, streamed through the engine's
    # in-program eval (traceable per-sample contract — the engine
    # evaluates the whole test set inside the chunked round program, so
    # evaluating every round still costs one dispatch per chunk).  The
    # metric must be PER-SAMPLE — (B,) values, not a broadcast batch
    # mean — so the engine's pad weighting stays exact for any
    # eval_batch / test-set size combination
    def eval_loss(params, bx, by):
        logits, _, _ = lm_forward(params, cfg, {"tokens": bx})
        logits = logits.astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(by, 0)[..., None], axis=-1)[..., 0]
        valid = (by >= 0).astype(jnp.float32)
        per_tok = (logz - gold) * valid
        return per_tok.sum(axis=-1) / jnp.maximum(valid.sum(axis=-1), 1.0)

    # peft rides the fused flat path (validate_peft enforces it); the
    # P1 relay still hops the full model — run_pod_training strips the
    # trainable filter for that phase
    spec = PodFLSpec(local_steps=args.local_steps, lr=0.03,
                     update_impl="fused" if peft else "tree", peft=peft)
    t0 = time.time()
    res = run_pod_training(
        cfg, data, cyclic_rounds=args.cyclic_rounds, fl_rounds=args.fl_rounds,
        clients_per_round=4, spec=spec, seed=args.seed,
        eval_fn=eval_loss, eval_batch=16, verbose=True)
    print(f"[llm] eval loss trajectory: "
          f"{[round(h['eval'], 4) for h in res.history]}")
    first, last = res.history[0]["eval"], res.history[-1]["eval"]
    print(f"[llm] eval loss {first:.4f} -> {last:.4f} "
          f"({time.time() - t0:.0f}s)")


if __name__ == "__main__":
    main()
