"""RQ3 in practice: adaptive P1→P2 switch policies.

The paper fixes T_cyc=100 and shows (Fig 5/6) a rise-then-descend
accuracy curve over the switch point.  This example runs the three
switch policies in repro.core.switch on the same budget and compares
where each one switches and where it ends up.

    PYTHONPATH=src python examples/switch_policies.py
"""
import time

from repro.core.cyclic import CyclicConfig
from repro.core.pipeline import run_cyclic_then_federated
from repro.core.switch import AccuracyPlateau, BudgetFraction, FixedRounds
from repro.data.synthetic import DATASETS
from repro.fl.simulation import FLConfig
from repro.fl.task import vision_task

TOTAL = 14


def main():
    t0 = time.time()
    data = DATASETS.get("cifar10-like")(n_clients=16, beta=0.5, seed=0,
                                        n_train=2048, n_test=512)
    task = vision_task("lenet5", n_classes=10, in_ch=3)

    policies = {
        "fixed(4)": FixedRounds(t_cyc=4),
        "plateau": AccuracyPlateau(patience=2, min_delta=0.005, min_rounds=2),
        "budget(25%)": BudgetFraction(total_rounds=TOTAL, fraction=0.25),
    }
    rows = []
    for name, policy in policies.items():
        cyc = CyclicConfig(rounds=TOTAL - 2, participation=0.25,
                           local_steps=10, eval_every=1, seed=0)
        res_p1_probe = run_cyclic_then_federated(
            task, data, cyc,
            FLConfig(algorithm="fedavg", rounds=2, participation=0.25,
                     local_steps=10, eval_every=1, seed=0),
            switch_policy=policy)
        switched_at = len(res_p1_probe.cyclic.history)
        # rerun with the discovered split so P2 gets the remaining budget
        res = run_cyclic_then_federated(
            task, data,
            CyclicConfig(rounds=switched_at, participation=0.25,
                         local_steps=10, eval_every=1, seed=0),
            FLConfig(algorithm="fedavg", rounds=TOTAL - switched_at,
                     participation=0.25, local_steps=10, eval_every=1,
                     seed=0))
        best = res.best_acc()
        rows.append((name, switched_at, best.get("acc", 0.0)))
        print(f"[switch] {name:12s} switched@{switched_at:2d} "
              f"best={best.get('acc', 0):.4f}")
    print(f"total {time.time() - t0:.0f}s")


if __name__ == "__main__":
    main()
