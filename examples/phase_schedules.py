"""Declarative phase schedules — beyond the paper's fixed P1→P2 split.

With the shared round engine a training run is just a list of phases,
so schedules the seed drivers could not express become one-liners.  This
example compares the paper's two-phase pipeline against a multi-cycle
P1↔P2 alternation (re-entering the relay mid-training re-centers the
model on the union data distribution — the cyclic-aggregation idea of
Lee et al. 2022) under the SAME total round budget and one ledger.

    PYTHONPATH=src python examples/phase_schedules.py
"""
import argparse

from repro.core.cyclic import CyclicConfig
from repro.core.pipeline import Phase, run_phase_schedule
from repro.core.switch import AccuracyPlateau
from repro.data.synthetic import DATASETS
from repro.fl.simulation import FLConfig
from repro.fl.task import vision_task


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--clients", type=int, default=16)
    ap.add_argument("--beta", type=float, default=0.5)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    data = DATASETS.get("cifar10-like")(n_clients=args.clients, beta=args.beta,
                                        seed=args.seed, n_train=2048,
                                        n_test=512)
    task = vision_task("lenet5", n_classes=10, in_ch=3)

    def p1(rounds):
        return CyclicConfig(rounds=rounds, participation=0.25, local_steps=10,
                            eval_every=2, seed=args.seed)

    def p2(rounds):
        return FLConfig(algorithm="fedavg", rounds=rounds, participation=0.25,
                        local_steps=10, eval_every=2, seed=args.seed)

    schedules = {
        # the paper's protocol: one pre-training phase, one FL phase
        "paper (P1×4 → P2×12)": [
            Phase("P1", p1(4)), Phase("P2", p2(12))],
        # multi-cycle alternation, same 16-round budget
        "alternating (×2)": [
            Phase("P1", p1(2)), Phase("P2", p2(6)),
            Phase("P1'", p1(2)), Phase("P2'", p2(6))],
        # adaptive: plateau policy ends each relay early, remainder to FL
        "adaptive relay": [
            Phase("P1", p1(6), switch_policy=AccuracyPlateau(
                patience=2, min_delta=0.005, min_rounds=2)),
            Phase("P2", p2(12))],
    }

    print(f"{'schedule':24s} {'best acc':>9s} {'rounds':>7s} {'GiB':>7s}")
    for name, phases in schedules.items():
        res = run_phase_schedule(task, data, phases)
        led = res.ledger.summary()
        rounds = led["p1_rounds"] + led["p2_rounds"]
        print(f"{name:24s} {res.best_acc().get('acc', 0.0):9.4f} "
              f"{rounds:7d} {led['total_bytes'] / 2**30:7.3f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
